from repro.data.datasets import (
    DATASETS,
    Dataset,
    make_credit_card,
    make_expedia,
    make_flights,
    make_hospital,
)
