"""Deterministic sharded LM-token pipeline with host-failure reassignment.

The corpus is a virtual stream of synthetic documents: shard ``s`` of step
``t`` is a pure function of (seed, t, s), so ANY host can (re)produce ANY
shard — this is what makes the loader elastic: when the straggler monitor
marks a host dead, its shards are deterministically reassigned and the global
batch for step t is byte-identical to what it would have been.

Documents are Zipf-token sequences with a planted bigram structure so small
models have signal to learn (loss visibly decreases in the examples).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.distributed.straggler import StragglerMonitor


def _shard_tokens(
    seed: int, step: int, shard: int, n_rows: int, seq_len: int, vocab: int
) -> np.ndarray:
    """Pure function (seed, step, shard) → (n_rows, seq_len+1) int32."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]).generate_state(4)
    )
    # planted bigram chain: next token ~ 0.6 * (prev*17+3 mod V) + 0.4 * Zipf
    z = rng.zipf(1.5, size=(n_rows, seq_len + 1)) % vocab
    out = np.empty((n_rows, seq_len + 1), dtype=np.int32)
    out[:, 0] = z[:, 0]
    follow = rng.random((n_rows, seq_len)) < 0.6
    for j in range(1, seq_len + 1):
        det = (out[:, j - 1] * 17 + 3) % vocab
        out[:, j] = np.where(follow[:, j - 1], det, z[:, j])
    return out


@dataclass
class TokenLoader:
    """Global-batch iterator over deterministic shards.

    ``global_batch`` rows per step, split into ``n_shards`` shards; each host
    materializes the shards the monitor's plan assigns it. On a single-host
    run (tests/examples) all shards are local, but the shard math is identical
    to the 1000-node layout.
    """

    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    n_shards: int = 8
    host: int = 0
    monitor: Optional[StragglerMonitor] = None

    def __post_init__(self):
        import math

        if self.global_batch % self.n_shards:
            # clamp to the largest shard count dividing the batch
            self.n_shards = math.gcd(self.n_shards, self.global_batch) or 1
        self.rows_per_shard = self.global_batch // self.n_shards

    def shards_for_step(self, step: int) -> list[int]:
        if self.monitor is None:
            return list(range(self.n_shards))
        plan = self.monitor.plan_shards(self.n_shards)
        return plan.get(self.host, [])

    def load_shard(self, step: int, shard: int) -> np.ndarray:
        return _shard_tokens(
            self.seed, step, shard, self.rows_per_shard, self.seq_len, self.vocab
        )

    def batch(self, step: int, shards: Optional[list[int]] = None) -> dict:
        """Assemble (this host's view of) the global batch for ``step``."""
        shards = self.shards_for_step(step) if shards is None else shards
        rows = np.concatenate([self.load_shard(step, s) for s in shards], axis=0)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
