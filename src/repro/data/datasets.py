"""Synthetic dataset generators shaped to the paper's Table 1.

| dataset     | tables | inputs (num/cat) | features after encoding |
|-------------|--------|------------------|--------------------------|
| Credit Card | 1      | 28 (28/0)        | 28                       |
| Hospital    | 1      | 24 (9/15)        | 59  (9 num + 50 binary)  |
| Expedia     | 3      | 28 (8/20)        | 3965 (8 + 3957)          |
| Flights     | 4      | 37 (4/33)        | 6475 (4 + 6471)          |

Real datasets are unavailable offline, so each generator plants a ground-truth
decision structure (a random sparse logit over scaled numerics + a few
categorical indicator effects) so trained models learn non-trivial,
*partially-sparse* functions — reproducing the paper's observation that a
large fraction of features go unused at inference time.

Multi-table datasets return a fact table plus dimension tables with integer
join keys, so prediction queries exercise 3-way / 4-way joins.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Dataset:
    name: str
    tables: dict[str, dict[str, np.ndarray]]  # table -> column -> values
    fact: str  # fact-table name
    join_keys: list[tuple[str, str, str]]  # (fact_col, dim_table, dim_col)
    numeric: list[str]  # model input columns (on the joined view)
    categorical: list[str]
    label: np.ndarray
    _card: dict[str, int] = field(default_factory=dict)  # declared cardinalities

    def joined_columns(self) -> dict[str, np.ndarray]:
        """Materialize the joined view (oracle for testing the engine)."""
        out = dict(self.tables[self.fact])
        for fact_col, dim_table, dim_col in self.join_keys:
            keys = out[fact_col]
            dim = self.tables[dim_table]
            order = np.argsort(dim[dim_col])
            pos = order[np.searchsorted(dim[dim_col], keys, sorter=order)]
            for c, v in dim.items():
                if c != dim_col:
                    out[c] = v[pos]
        return out

    def n_rows(self) -> int:
        return len(self.label)

    def categories(self) -> dict[str, np.ndarray]:
        """Declared category domains (full cardinality, independent of sample
        size) so encoded feature widths match the paper's Table 1."""
        joined = self.joined_columns()
        return {
            c: np.arange(int(self._card[c])) if c in self._card
            else np.unique(joined[c])
            for c in self.categorical
        }


def _planted_label(
    rng: np.random.Generator,
    num_cols: dict[str, np.ndarray],
    cat_cols: dict[str, np.ndarray],
    sparsity: float = 0.5,
) -> np.ndarray:
    """Sparse planted logit: only ~(1-sparsity) of inputs matter."""
    n = len(next(iter({**num_cols, **cat_cols}.values())))
    z = np.zeros(n)
    for v in num_cols.values():
        if rng.random() > sparsity:
            w = rng.normal(0, 1.5)
            z += w * (v - v.mean()) / (v.std() + 1e-9)
    for v in cat_cols.values():
        if rng.random() > sparsity:
            hot = rng.integers(0, max(1, v.max() + 1))
            z += rng.normal(0, 2.0) * (v == hot)
    z += rng.normal(0, 0.25, size=n)  # noise
    p = 1 / (1 + np.exp(-(z - np.median(z))))
    return (rng.random(n) < p).astype(np.int64)


def make_credit_card(n: int = 4096, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    cols = {f"v{i}": rng.normal(0, 1 + i * 0.05, n) for i in range(28)}
    label = _planted_label(rng, cols, {}, sparsity=0.6)
    return Dataset(
        name="credit_card",
        tables={"transactions": cols},
        fact="transactions",
        join_keys=[],
        numeric=list(cols),
        categorical=[],
        label=label,
    )


def make_hospital(n: int = 4096, seed: int = 1) -> Dataset:
    rng = np.random.default_rng(seed)
    numeric_names = [
        "age", "bmi", "pulse", "bpm", "respiration",
        "glucose", "sodium", "creatinine", "hematocrit",
    ]
    num = {
        "age": rng.integers(18, 95, n).astype(np.float64),
        "bmi": rng.normal(27, 5, n),
        "pulse": rng.normal(75, 12, n),
        "bpm": rng.normal(120, 18, n),
        "respiration": rng.normal(16, 3, n),
        "glucose": rng.normal(105, 25, n),
        "sodium": rng.normal(139, 4, n),
        "creatinine": rng.normal(1.1, 0.4, n),
        "hematocrit": rng.normal(42, 5, n),
    }
    # 15 categorical columns; cardinalities sum so one-hot width = 50
    cat_cards = [2, 2, 2, 2, 2, 2, 2, 2, 2, 3, 3, 4, 6, 7, 9]
    assert sum(cat_cards) == 50
    cat_names = [
        "asthma", "diabetes", "smoker", "hypertension", "copd",
        "dialysis", "stroke", "obesity", "depression", "gender3",
        "admission_type", "blood_type", "rcount", "ward", "num_issues",
    ]
    cat = {
        name: rng.integers(0, card, n)
        for name, card in zip(cat_names, cat_cards)
    }
    label = _planted_label(rng, num, cat, sparsity=0.45)
    return Dataset(
        name="hospital",
        tables={"patients": {**num, **cat}},
        fact="patients",
        join_keys=[],
        numeric=numeric_names,
        categorical=cat_names,
        label=label,
        _card={n: c for n, c in zip(cat_names, cat_cards)},
    )


def _split_cards(total: int, k: int, rng) -> list[int]:
    """k positive ints summing to total, heavy-tailed like real cat columns."""
    w = rng.pareto(1.5, k) + 1.0
    c = np.maximum(2, np.round(w / w.sum() * total).astype(int))
    while c.sum() != total:
        i = rng.integers(0, k)
        if c.sum() > total and c[i] > 2:
            c[i] -= 1
        elif c.sum() < total:
            c[i] += 1
    return list(c)


def make_expedia(n: int = 4096, seed: int = 2) -> Dataset:
    """3 tables: searches (fact) ⋈ hotels ⋈ destinations. 8 num / 20 cat,
    3957 one-hot columns."""
    rng = np.random.default_rng(seed)
    n_hotel, n_dest = max(16, n // 64), max(8, n // 128)
    cards = _split_cards(3957, 20, rng)
    # distribute cat columns: 8 on fact, 6 on hotels, 6 on destinations
    fact_num = {f"s_num{i}": rng.normal(0, 1, n) for i in range(4)}
    fact_cat = {
        f"s_cat{i}": rng.integers(0, cards[i], n) for i in range(8)
    }
    hotel_num = {f"h_num{i}": rng.normal(0, 1, n_hotel) for i in range(2)}
    hotel_cat = {
        f"h_cat{i}": rng.integers(0, cards[8 + i], n_hotel) for i in range(6)
    }
    dest_num = {f"d_num{i}": rng.normal(0, 1, n_dest) for i in range(2)}
    dest_cat = {
        f"d_cat{i}": rng.integers(0, cards[14 + i], n_dest) for i in range(6)
    }
    fact = {
        **fact_num,
        **fact_cat,
        "hotel_id": rng.integers(0, n_hotel, n),
        "dest_id": rng.integers(0, n_dest, n),
    }
    hotels = {"hotel_id": np.arange(n_hotel), **hotel_num, **hotel_cat}
    dests = {"dest_id": np.arange(n_dest), **dest_num, **dest_cat}
    ds = Dataset(
        name="expedia",
        tables={"searches": fact, "hotels": hotels, "destinations": dests},
        fact="searches",
        join_keys=[("hotel_id", "hotels", "hotel_id"), ("dest_id", "destinations", "dest_id")],
        numeric=list(fact_num) + list(hotel_num) + list(dest_num),
        categorical=list(fact_cat) + list(hotel_cat) + list(dest_cat),
        label=np.zeros(n, dtype=np.int64),
        _card={
            **{f"s_cat{i}": cards[i] for i in range(8)},
            **{f"h_cat{i}": cards[8 + i] for i in range(6)},
            **{f"d_cat{i}": cards[14 + i] for i in range(6)},
        },
    )
    joined = ds.joined_columns()
    ds.label = _planted_label(
        rng,
        {c: joined[c] for c in ds.numeric},
        {c: joined[c] for c in ds.categorical[:6]},
        sparsity=0.5,
    )
    return ds


def make_flights(n: int = 4096, seed: int = 3) -> Dataset:
    """4 tables: flights ⋈ airlines ⋈ src_airport ⋈ dst_airport.
    4 num / 33 cat, 6471 one-hot columns."""
    rng = np.random.default_rng(seed)
    n_air, n_ap = max(8, n // 256), max(16, n // 64)
    cards = _split_cards(6471, 33, rng)
    fact_num = {"dep_delay": rng.normal(5, 20, n), "distance": rng.normal(900, 500, n)}
    fact_cat = {f"f_cat{i}": rng.integers(0, cards[i], n) for i in range(13)}
    airline_num = {"fleet_age": rng.normal(10, 4, n_air)}
    airline_cat = {f"a_cat{i}": rng.integers(0, cards[13 + i], n_air) for i in range(6)}
    src_num = {"src_elev": rng.normal(300, 200, n_ap)}
    src_cat = {f"s_cat{i}": rng.integers(0, cards[19 + i], n_ap) for i in range(7)}
    dst_cat = {f"d_cat{i}": rng.integers(0, cards[26 + i], n_ap) for i in range(7)}
    fact = {
        **fact_num,
        **fact_cat,
        "airline_id": rng.integers(0, n_air, n),
        "src_id": rng.integers(0, n_ap, n),
        "dst_id": rng.integers(0, n_ap, n),
    }
    airlines = {"airline_id": np.arange(n_air), **airline_num, **airline_cat}
    srcs = {"src_id": np.arange(n_ap), **src_num, **src_cat}
    dsts = {"dst_id": np.arange(n_ap), **dst_cat}
    ds = Dataset(
        name="flights",
        tables={"flights": fact, "airlines": airlines, "src_airports": srcs, "dst_airports": dsts},
        fact="flights",
        join_keys=[
            ("airline_id", "airlines", "airline_id"),
            ("src_id", "src_airports", "src_id"),
            ("dst_id", "dst_airports", "dst_id"),
        ],
        numeric=list(fact_num) + list(airline_num) + list(src_num),
        categorical=list(fact_cat) + list(airline_cat) + list(src_cat) + list(dst_cat),
        label=np.zeros(n, dtype=np.int64),
        _card={
            **{f"f_cat{i}": cards[i] for i in range(13)},
            **{f"a_cat{i}": cards[13 + i] for i in range(6)},
            **{f"s_cat{i}": cards[19 + i] for i in range(7)},
            **{f"d_cat{i}": cards[26 + i] for i in range(7)},
        },
    )
    joined = ds.joined_columns()
    ds.label = _planted_label(
        rng,
        {c: joined[c] for c in ds.numeric},
        {c: joined[c] for c in ds.categorical[:5]},
        sparsity=0.5,
    )
    return ds


DATASETS = {
    "credit_card": make_credit_card,
    "hospital": make_hospital,
    "expedia": make_expedia,
    "flights": make_flights,
}
