"""Featurization operators (scikit-learn-style fit/transform).

Each featurizer has a direct pipeline-node encoding (see
:mod:`repro.ml.pipeline`) so the optimizer can propagate predicate constants
and projections *through* it, exactly as the paper's §4.1 requires
(e.g. a constant pushed through a Scaler becomes ``(c - offset) * scale``;
through a OneHotEncoder it becomes the constant indicator vector).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class StandardScaler:
    """y = (x - offset) * scale, per column."""

    offset: Optional[np.ndarray] = field(default=None, repr=False)
    scale: Optional[np.ndarray] = field(default=None, repr=False)

    def fit(self, X) -> "StandardScaler":
        X = np.asarray(X, dtype=np.float64)
        self.offset = X.mean(axis=0)
        std = X.std(axis=0)
        std = np.where(std == 0.0, 1.0, std)
        self.scale = 1.0 / std
        return self

    def transform(self, X) -> np.ndarray:
        return (np.asarray(X, dtype=np.float64) - self.offset) * self.scale


@dataclass
class Normalizer:
    """Row-wise normalization: l1 | l2 | max."""

    norm: str = "l2"

    def fit(self, X) -> "Normalizer":
        return self

    def transform(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        if self.norm == "l1":
            d = np.abs(X).sum(axis=1, keepdims=True)
        elif self.norm == "l2":
            d = np.sqrt((X * X).sum(axis=1, keepdims=True))
        elif self.norm == "max":
            d = np.abs(X).max(axis=1, keepdims=True)
        else:
            raise ValueError(self.norm)
        return X / np.where(d == 0.0, 1.0, d)


@dataclass
class LabelEncoder:
    """Maps arbitrary integer category values to dense codes [0, V)."""

    classes: Optional[np.ndarray] = field(default=None, repr=False)

    def fit(self, x) -> "LabelEncoder":
        self.classes = np.unique(np.asarray(x))
        return self

    def transform(self, x) -> np.ndarray:
        return np.searchsorted(self.classes, np.asarray(x))


@dataclass
class OneHotEncoder:
    """Single-column one-hot over known category values.

    Unknown values encode to all-zeros (handle_unknown='ignore' semantics).
    """

    categories: Optional[np.ndarray] = field(default=None, repr=False)

    def fit(self, x) -> "OneHotEncoder":
        self.categories = np.unique(np.asarray(x))
        return self

    def transform(self, x) -> np.ndarray:
        x = np.asarray(x).reshape(-1)
        out = (x[:, None] == self.categories[None, :]).astype(np.float64)
        return out

    @property
    def n_categories(self) -> int:
        return len(self.categories)
