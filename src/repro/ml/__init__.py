"""Traditional-ML substrate: training + interpreted inference.

This package is the analog of the paper's "ML runtime" (ONNX Runtime): trained
pipelines are DAGs of featurizers + tree/linear models, executed op-at-a-time
by :mod:`repro.ml.pipeline`. Training is implemented natively (numpy CART /
GBDT / logistic regression) since no external ML library is assumed.
"""
from repro.ml.trees import (
    DecisionTreeClassifier,
    GradientBoostingClassifier,
    RandomForestClassifier,
    TreeEnsemble,
)
from repro.ml.linear import LinearRegression, LogisticRegression
from repro.ml.featurizers import (
    LabelEncoder,
    Normalizer,
    OneHotEncoder,
    StandardScaler,
)
from repro.ml.pipeline import (
    PipelineNode,
    TrainedPipeline,
    fit_pipeline,
    run_pipeline,
)

__all__ = [
    "DecisionTreeClassifier",
    "GradientBoostingClassifier",
    "RandomForestClassifier",
    "TreeEnsemble",
    "LinearRegression",
    "LogisticRegression",
    "LabelEncoder",
    "Normalizer",
    "OneHotEncoder",
    "StandardScaler",
    "PipelineNode",
    "TrainedPipeline",
    "fit_pipeline",
    "run_pipeline",
]
