"""Tree-based models: CART decision trees, random forests, gradient boosting.

Trained models are stored in a flattened, ONNX-TreeEnsemble-like array form
(:class:`TreeEnsemble`) which is the single representation consumed by

  * the interpreted "ML runtime" (vectorized level-stepping, numpy),
  * the optimizer rules (predicate-based pruning, densification),
  * the MLtoSQL compiler (nested CASE / jnp.where chains),
  * the MLtoDNN compiler (Hummingbird-style GEMM / gather tensor programs).

Training is exact greedy CART with quantile-binned candidate thresholds —
fast enough for the synthetic corpora used here, and producing trees with the
same structural statistics the paper's OpenML study reports (depth, #nodes,
unused-feature fraction).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

LEAF = -1  # sentinel feature id for leaf nodes


# ---------------------------------------------------------------------------
# Flattened ensemble representation
# ---------------------------------------------------------------------------


@dataclass
class TreeEnsemble:
    """Flattened forest. All node arrays are concatenated over trees.

    feature[i]   — split feature index, or LEAF (-1) for leaves
    threshold[i] — split threshold (go left iff x[f] <= t)
    left[i], right[i] — absolute child node ids (undefined for leaves)
    leaf_value[i] — per-node contribution (only meaningful at leaves)
    tree_offsets — start node id of each tree; len == n_trees + 1
    tree_weight  — per-tree multiplier (1/n_trees for RF mean, lr for GBDT)
    base_score   — added to the aggregated raw score
    post_transform — "none" | "logistic"
    n_features   — input feature dimensionality the trees index into
    """

    feature: np.ndarray
    threshold: np.ndarray
    left: np.ndarray
    right: np.ndarray
    leaf_value: np.ndarray
    tree_offsets: np.ndarray
    tree_weight: np.ndarray
    base_score: float
    post_transform: str
    n_features: int

    @property
    def n_trees(self) -> int:
        return len(self.tree_offsets) - 1

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    def tree_slices(self) -> list[slice]:
        return [
            slice(int(self.tree_offsets[t]), int(self.tree_offsets[t + 1]))
            for t in range(self.n_trees)
        ]

    def max_depth(self) -> int:
        """Max depth over trees (root = depth 0)."""
        depth = np.zeros(self.n_nodes, dtype=np.int32)
        out = 0
        for sl in self.tree_slices():
            root = sl.start
            depth[root] = 0
            # nodes are emitted parent-before-child inside each tree
            for i in range(sl.start, sl.stop):
                if self.feature[i] != LEAF:
                    depth[self.left[i]] = depth[i] + 1
                    depth[self.right[i]] = depth[i] + 1
                    out = max(out, int(depth[i]) + 1)
        return out

    def depths(self) -> np.ndarray:
        """Per-tree max depth."""
        depth = np.zeros(self.n_nodes, dtype=np.int32)
        out = []
        for sl in self.tree_slices():
            d = 0
            for i in range(sl.start, sl.stop):
                if self.feature[i] != LEAF:
                    depth[self.left[i]] = depth[i] + 1
                    depth[self.right[i]] = depth[i] + 1
                    d = max(d, int(depth[i]) + 1)
            out.append(d)
        return np.asarray(out, dtype=np.int32)

    def used_features(self) -> np.ndarray:
        """Sorted unique feature indices used by any internal node."""
        internal = self.feature[self.feature != LEAF]
        return np.unique(internal)

    def raw_scores(self, X: np.ndarray) -> np.ndarray:
        """Interpreted inference: vectorized gather-stepping, per-tree loop.

        This is the "ML runtime" execution path — intentionally op-at-a-time
        (one pass per tree) like a generic runtime would do, as opposed to the
        fused tensor programs produced by MLtoDNN.
        """
        # f32 features (thresholds live on the f32 grid — see _concat_trees)
        X = np.asarray(X, dtype=np.float32)
        n = X.shape[0]
        acc = np.full(n, self.base_score, dtype=np.float64)
        feature = self.feature
        threshold = self.threshold
        left = self.left
        right = self.right
        leaf_value = self.leaf_value
        for t, sl in enumerate(self.tree_slices()):
            node = np.full(n, sl.start, dtype=np.int64)
            active = feature[node] != LEAF
            while active.any():
                f = feature[node]
                go_left = X[np.arange(n), np.maximum(f, 0)] <= threshold[node]
                nxt = np.where(go_left, left[node], right[node])
                node = np.where(active, nxt, node)
                active = feature[node] != LEAF
            acc += self.tree_weight[t] * leaf_value[node]
        return acc

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        raw = self.raw_scores(X)
        if self.post_transform == "logistic":
            return 1.0 / (1.0 + np.exp(-raw))
        return raw

    def predict(self, X: np.ndarray) -> np.ndarray:
        p = self.decision_function(X)
        if self.post_transform == "logistic":
            return (p >= 0.5).astype(np.int64)
        return p

    def copy(self) -> "TreeEnsemble":
        return TreeEnsemble(
            feature=self.feature.copy(),
            threshold=self.threshold.copy(),
            left=self.left.copy(),
            right=self.right.copy(),
            leaf_value=self.leaf_value.copy(),
            tree_offsets=self.tree_offsets.copy(),
            tree_weight=self.tree_weight.copy(),
            base_score=self.base_score,
            post_transform=self.post_transform,
            n_features=self.n_features,
        )


def _concat_trees(
    trees: list[dict],
    tree_weight: np.ndarray,
    base_score: float,
    post_transform: str,
    n_features: int,
) -> TreeEnsemble:
    """Concatenate per-tree dict-of-arrays into one TreeEnsemble."""
    offsets = [0]
    for t in trees:
        offsets.append(offsets[-1] + len(t["feature"]))
    off = np.asarray(offsets, dtype=np.int64)
    feature = np.concatenate([t["feature"] for t in trees])
    threshold = np.concatenate([t["threshold"] for t in trees])
    left = np.concatenate(
        [t["left"] + off[i] for i, t in enumerate(trees)]
    )
    right = np.concatenate(
        [t["right"] + off[i] for i, t in enumerate(trees)]
    )
    leaf_value = np.concatenate([t["leaf_value"] for t in trees])
    # children of leaves point at themselves so gather-stepping is total
    is_leaf = feature == LEAF
    idx = np.arange(len(feature))
    left = np.where(is_leaf, idx, left).astype(np.int64)
    right = np.where(is_leaf, idx, right).astype(np.int64)
    return TreeEnsemble(
        feature=feature.astype(np.int64),
        # thresholds live on the f32 grid (stored f64): every execution path
        # — interpreted runtime, MLtoSQL f32 engine, MLtoDNN tensor programs —
        # then performs the *same* f32 comparison, so compiled plans flip no
        # predictions vs the runtime beyond genuine f32-feature ties
        threshold=threshold.astype(np.float32).astype(np.float64),
        left=left,
        right=right,
        leaf_value=leaf_value.astype(np.float64),
        tree_offsets=off,
        tree_weight=np.asarray(tree_weight, dtype=np.float64),
        base_score=float(base_score),
        post_transform=post_transform,
        n_features=int(n_features),
    )


# ---------------------------------------------------------------------------
# CART training
# ---------------------------------------------------------------------------


def _candidate_thresholds(col: np.ndarray, max_bins: int) -> np.ndarray:
    u = np.unique(col)
    if len(u) <= 1:
        return np.empty(0)
    if len(u) <= max_bins:
        return (u[:-1] + u[1:]) / 2.0
    qs = np.quantile(col, np.linspace(0, 1, max_bins + 1)[1:-1])
    return np.unique(qs)


def _best_split_gini(X, y, sample_idx, feat_idx, max_bins):
    """Best (feature, threshold, gain) under gini impurity for a node."""
    ys = y[sample_idx]
    n = len(ys)
    pos = ys.sum()
    parent_gini = 1.0 - (pos / n) ** 2 - ((n - pos) / n) ** 2
    best = (None, None, 0.0)
    for f in feat_idx:
        col = X[sample_idx, f]
        for t in _candidate_thresholds(col, max_bins):
            mask = col <= t
            nl = mask.sum()
            if nl == 0 or nl == n:
                continue
            pl = ys[mask].sum()
            pr = pos - pl
            nr = n - nl
            gl = 1.0 - (pl / nl) ** 2 - ((nl - pl) / nl) ** 2
            gr = 1.0 - (pr / nr) ** 2 - ((nr - pr) / nr) ** 2
            gain = parent_gini - (nl / n) * gl - (nr / n) * gr
            if gain > best[2] + 1e-12:
                best = (f, float(t), float(gain))
    return best


def _best_split_mse(X, g, h, sample_idx, feat_idx, max_bins, lam=1.0):
    """Best split by (gradient, hessian) gain — XGBoost-style objective."""
    gs = g[sample_idx]
    hs = h[sample_idx]
    G, H = gs.sum(), hs.sum()
    parent = G * G / (H + lam)
    best = (None, None, 0.0)
    for f in feat_idx:
        col = X[sample_idx, f]
        order = np.argsort(col, kind="stable")
        cg = np.cumsum(gs[order])
        ch = np.cumsum(hs[order])
        sorted_col = col[order]
        for t in _candidate_thresholds(col, max_bins):
            k = np.searchsorted(sorted_col, t, side="right")
            if k == 0 or k == len(sorted_col):
                continue
            Gl, Hl = cg[k - 1], ch[k - 1]
            Gr, Hr = G - Gl, H - Hl
            gain = Gl * Gl / (Hl + lam) + Gr * Gr / (Hr + lam) - parent
            if gain > best[2] + 1e-9:
                best = (f, float(t), float(gain))
    return best


def _grow_tree(
    X: np.ndarray,
    target,
    *,
    max_depth: int,
    min_samples_split: int,
    max_bins: int,
    rng: Optional[np.random.Generator],
    max_features: Optional[int],
    mode: str,  # "gini" (target=y) | "grad" (target=(g, h))
) -> dict:
    """Grow one tree; returns flattened arrays (parent emitted before child)."""
    n_features = X.shape[1]
    feature, threshold, left, right, leaf_value = [], [], [], [], []

    def new_node():
        feature.append(LEAF)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        leaf_value.append(0.0)
        return len(feature) - 1

    def leaf_val(sample_idx):
        if mode == "gini":
            y = target[sample_idx]
            return float(y.mean())  # P(class=1); caller binarizes
        g, h = target
        return float(g[sample_idx].sum() / (h[sample_idx].sum() + 1.0))

    def build(sample_idx, depth):
        node = new_node()
        done = (
            depth >= max_depth
            or len(sample_idx) < min_samples_split
        )
        if not done and mode == "gini":
            done = target[sample_idx].min() == target[sample_idx].max()
        if done:
            leaf_value[node] = leaf_val(sample_idx)
            return node
        if max_features is not None and max_features < n_features:
            feat_idx = rng.choice(n_features, size=max_features, replace=False)
        else:
            feat_idx = np.arange(n_features)
        if mode == "gini":
            f, t, gain = _best_split_gini(X, target, sample_idx, feat_idx, max_bins)
        else:
            g, h = target
            f, t, gain = _best_split_mse(X, g, h, sample_idx, feat_idx, max_bins)
        if f is None or gain <= 0.0:
            leaf_value[node] = leaf_val(sample_idx)
            return node
        mask = X[sample_idx, f] <= t
        feature[node] = int(f)
        threshold[node] = float(t)
        left[node] = build(sample_idx[mask], depth + 1)
        right[node] = build(sample_idx[~mask], depth + 1)
        return node

    idx = np.arange(X.shape[0])
    if rng is not None and max_features is None and mode == "gini":
        pass
    build(idx, 0)
    return {
        "feature": np.asarray(feature, dtype=np.int64),
        "threshold": np.asarray(threshold, dtype=np.float64),
        "left": np.asarray(left, dtype=np.int64),
        "right": np.asarray(right, dtype=np.int64),
        "leaf_value": np.asarray(leaf_value, dtype=np.float64),
    }


# ---------------------------------------------------------------------------
# Estimators
# ---------------------------------------------------------------------------


@dataclass
class DecisionTreeClassifier:
    """Binary CART classifier. Leaf value = P(y=1); post_transform='none'
    with a 0.5 decision threshold (scores are already probabilities)."""

    max_depth: int = 8
    min_samples_split: int = 2
    max_bins: int = 32
    ensemble: Optional[TreeEnsemble] = field(default=None, repr=False)

    def fit(self, X, y) -> "DecisionTreeClassifier":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        tree = _grow_tree(
            X,
            y,
            max_depth=self.max_depth,
            min_samples_split=self.min_samples_split,
            max_bins=self.max_bins,
            rng=None,
            max_features=None,
            mode="gini",
        )
        self.ensemble = _concat_trees(
            [tree], np.ones(1), 0.0, "none", X.shape[1]
        )
        return self

    def predict_proba(self, X) -> np.ndarray:
        return self.ensemble.decision_function(np.asarray(X, dtype=np.float64))

    def predict(self, X) -> np.ndarray:
        return (self.predict_proba(X) >= 0.5).astype(np.int64)


@dataclass
class RandomForestClassifier:
    n_estimators: int = 10
    max_depth: int = 8
    min_samples_split: int = 2
    max_bins: int = 32
    max_features: str = "sqrt"
    seed: int = 0
    ensemble: Optional[TreeEnsemble] = field(default=None, repr=False)

    def fit(self, X, y) -> "RandomForestClassifier":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        mf = (
            max(1, int(np.sqrt(X.shape[1])))
            if self.max_features == "sqrt"
            else X.shape[1]
        )
        trees = []
        for _ in range(self.n_estimators):
            boot = rng.integers(0, n, size=n)
            trees.append(
                _grow_tree(
                    X[boot],
                    y[boot],
                    max_depth=self.max_depth,
                    min_samples_split=self.min_samples_split,
                    max_bins=self.max_bins,
                    rng=rng,
                    max_features=mf,
                    mode="gini",
                )
            )
        self.ensemble = _concat_trees(
            trees,
            np.full(self.n_estimators, 1.0 / self.n_estimators),
            0.0,
            "none",
            X.shape[1],
        )
        return self

    def predict_proba(self, X) -> np.ndarray:
        return self.ensemble.decision_function(np.asarray(X, dtype=np.float64))

    def predict(self, X) -> np.ndarray:
        return (self.predict_proba(X) >= 0.5).astype(np.int64)


@dataclass
class GradientBoostingClassifier:
    """Binary GBDT with logistic loss and Newton leaf values."""

    n_estimators: int = 20
    max_depth: int = 3
    learning_rate: float = 0.3
    min_samples_split: int = 2
    max_bins: int = 32
    subsample: float = 1.0
    seed: int = 0
    ensemble: Optional[TreeEnsemble] = field(default=None, repr=False)

    def fit(self, X, y) -> "GradientBoostingClassifier":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        p0 = np.clip(y.mean(), 1e-6, 1 - 1e-6)
        base = float(np.log(p0 / (1 - p0)))
        F = np.full(n, base)
        trees = []
        for _ in range(self.n_estimators):
            p = 1.0 / (1.0 + np.exp(-F))
            g = y - p
            h = p * (1 - p)
            if self.subsample < 1.0:
                sub = rng.random(n) < self.subsample
            else:
                sub = np.ones(n, dtype=bool)
            Xs = X[sub]
            tree = _grow_tree(
                Xs,
                (g[sub], h[sub]),
                max_depth=self.max_depth,
                min_samples_split=self.min_samples_split,
                max_bins=self.max_bins,
                rng=rng,
                max_features=None,
                mode="grad",
            )
            single = _concat_trees([tree], np.ones(1), 0.0, "none", X.shape[1])
            F = F + self.learning_rate * single.raw_scores(X)
            trees.append(tree)
        self.ensemble = _concat_trees(
            trees,
            np.full(self.n_estimators, self.learning_rate),
            base,
            "logistic",
            X.shape[1],
        )
        return self

    def predict_proba(self, X) -> np.ndarray:
        return self.ensemble.decision_function(np.asarray(X, dtype=np.float64))

    def predict(self, X) -> np.ndarray:
        return (self.predict_proba(X) >= 0.5).astype(np.int64)
