"""Linear models with optional L1 regularization (proximal gradient).

L1 matters to the reproduction: the paper's Fig. 9 sweeps the regularization
strength to create zero weights, which the model-projection-pushdown rule then
exploits (zero-weight inputs never need to be read).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def _soft_threshold(w: np.ndarray, t: float) -> np.ndarray:
    return np.sign(w) * np.maximum(np.abs(w) - t, 0.0)


@dataclass
class LogisticRegression:
    """Binary logistic regression trained with proximal gradient descent
    (ISTA) so that L1 produces exact zeros."""

    alpha: float = 0.0  # L1 strength
    lr: float = 0.5
    n_iter: int = 400
    weights: Optional[np.ndarray] = field(default=None, repr=False)
    bias: float = 0.0

    def fit(self, X, y) -> "LogisticRegression":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, d = X.shape
        w = np.zeros(d)
        b = 0.0
        # Lipschitz-ish step scaling
        scale = max(1.0, float(np.mean(np.sum(X * X, axis=1))) / 4.0)
        step = self.lr / scale
        for _ in range(self.n_iter):
            z = X @ w + b
            p = 1.0 / (1.0 + np.exp(-z))
            err = p - y
            gw = X.T @ err / n
            gb = err.mean()
            w = _soft_threshold(w - step * gw, step * self.alpha)
            b -= step * gb
        self.weights = w
        self.bias = float(b)
        return self

    def decision_function(self, X) -> np.ndarray:
        z = np.asarray(X, dtype=np.float64) @ self.weights + self.bias
        return 1.0 / (1.0 + np.exp(-z))

    def predict_proba(self, X) -> np.ndarray:
        return self.decision_function(X)

    def predict(self, X) -> np.ndarray:
        return (self.decision_function(X) >= 0.5).astype(np.int64)

    @property
    def n_zero_weights(self) -> int:
        return int(np.sum(self.weights == 0.0))


@dataclass
class LinearRegression:
    """Ridge-regularized least squares (closed form)."""

    l2: float = 1e-6
    weights: Optional[np.ndarray] = field(default=None, repr=False)
    bias: float = 0.0

    def fit(self, X, y) -> "LinearRegression":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        Xb = np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)
        A = Xb.T @ Xb + self.l2 * np.eye(Xb.shape[1])
        wb = np.linalg.solve(A, Xb.T @ y)
        self.weights = wb[:-1]
        self.bias = float(wb[-1])
        return self

    def predict(self, X) -> np.ndarray:
        return np.asarray(X, dtype=np.float64) @ self.weights + self.bias
