"""Trained-pipeline graphs: the ONNX-analog model format.

A :class:`TrainedPipeline` is a topologically sorted DAG of
:class:`PipelineNode` ops over named values, mirroring how ONNX-ML encodes
scikit-learn pipelines (featurizers + a model op).  Supported ops:

  scaler            y = (x - offset) * scale                (N,k) -> (N,k)
  normalizer        row-wise l1/l2/max                      (N,k) -> (N,k)
  label_encode      value -> dense code                     (N,)  -> (N,)
  one_hot           single column -> indicator matrix       (N,)  -> (N,V)
  concat            horizontal concat                       ...   -> (N,F)
  feature_extractor column subset (attrs['indices'])        (N,F) -> (N,k)
  constant          broadcast constant columns              ()    -> (N,k)
  tree_ensemble     TreeEnsemble inference -> score, label
  linear            w·x + b (+ logistic)    -> score, label

The same graph is (a) executed op-at-a-time by :func:`run_pipeline` (the
"ML runtime"), (b) rewritten by the optimizer rules in ``repro.core.rules``,
(c) compiled by MLtoSQL / MLtoDNN.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.ml.featurizers import Normalizer
from repro.ml.trees import TreeEnsemble

MODEL_OPS = ("tree_ensemble", "linear")
FEATURIZER_OPS = (
    "scaler",
    "normalizer",
    "label_encode",
    "one_hot",
    "concat",
    "feature_extractor",
    "constant",
)
# ops only the interpreted host runtime can execute: an opaque python
# callable over the feature block (sklearn FunctionTransformer / ONNX custom
# op analog). attrs: {"fn": callable}. The callable may carry
# ``__fingerprint_token__`` to make pipelines embedding it content-stable.
HOST_ONLY_OPS = ("python_udf",)


@dataclass
class PipelineNode:
    op: str
    inputs: list[str]
    outputs: list[str]
    attrs: dict[str, Any] = field(default_factory=dict)

    def copy(self) -> "PipelineNode":
        return PipelineNode(
            op=self.op,
            inputs=list(self.inputs),
            outputs=list(self.outputs),
            attrs=dict(self.attrs),
        )


@dataclass
class InputSpec:
    name: str
    kind: str  # "numeric" | "categorical"


@dataclass
class TrainedPipeline:
    """Topo-sorted op DAG with named graph inputs/outputs."""

    inputs: list[InputSpec]
    outputs: list[str]
    nodes: list[PipelineNode]

    # ---- structure helpers -------------------------------------------------

    def input_names(self) -> list[str]:
        return [s.name for s in self.inputs]

    def producer_of(self, value: str) -> Optional[PipelineNode]:
        for n in self.nodes:
            if value in n.outputs:
                return n
        return None

    def consumers_of(self, value: str) -> list[PipelineNode]:
        return [n for n in self.nodes if value in n.inputs]

    def model_nodes(self) -> list[PipelineNode]:
        return [n for n in self.nodes if n.op in MODEL_OPS]

    def toposort(self) -> None:
        """Re-establish topological order after rewrites."""
        produced = {s.name for s in self.inputs}
        remaining = list(self.nodes)
        order: list[PipelineNode] = []
        while remaining:
            progressed = False
            for n in list(remaining):
                if all(i in produced for i in n.inputs):
                    order.append(n)
                    produced.update(n.outputs)
                    remaining.remove(n)
                    progressed = True
            if not progressed:
                raise ValueError("cycle or missing producer in pipeline graph")
        self.nodes = order

    def prune_dead(self) -> None:
        """Drop nodes whose outputs reach no graph output (after rewrites)."""
        live: set[str] = set(self.outputs)
        changed = True
        while changed:
            changed = False
            for n in self.nodes:
                if any(o in live for o in n.outputs):
                    for i in n.inputs:
                        if i not in live:
                            live.add(i)
                            changed = True
        self.nodes = [n for n in self.nodes if any(o in live for o in n.outputs)]
        self.inputs = [s for s in self.inputs if s.name in live]

    def copy(self) -> "TrainedPipeline":
        return TrainedPipeline(
            inputs=[dataclasses.replace(s) for s in self.inputs],
            outputs=list(self.outputs),
            nodes=[n.copy() for n in self.nodes],
        )

    def n_ops(self) -> int:
        return len(self.nodes)


# ---------------------------------------------------------------------------
# Interpreted execution — the "ML runtime"
# ---------------------------------------------------------------------------


def _as_2d(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x)
    # a 1-D value is one column; reshape(n, 1) (not -1) stays valid at n == 0
    return x.reshape(x.shape[0], 1) if x.ndim == 1 else x


def _eval_node(node: PipelineNode, vals: dict[str, np.ndarray], n_rows: int):
    # Featurization runs in float32 — exactly like the real ML runtime this
    # models (ONNX Runtime tensors are f32) and like the compiled MLtoSQL /
    # MLtoDNN paths, so threshold comparisons agree bit-for-bit across all
    # three execution paths.
    a = node.attrs
    if node.op == "scaler":
        x = _as_2d(vals[node.inputs[0]]).astype(np.float32)
        vals[node.outputs[0]] = (
            x - a["offset"].astype(np.float32)
        ) * a["scale"].astype(np.float32)
    elif node.op == "normalizer":
        x = _as_2d(vals[node.inputs[0]]).astype(np.float32)
        vals[node.outputs[0]] = Normalizer(a["norm"]).transform(x).astype(np.float32)
    elif node.op == "label_encode":
        x = np.asarray(vals[node.inputs[0]]).reshape(-1)
        vals[node.outputs[0]] = np.searchsorted(a["classes"], x)
    elif node.op == "one_hot":
        x = np.asarray(vals[node.inputs[0]]).reshape(-1)
        cats = a["categories"]
        vals[node.outputs[0]] = (x[:, None] == cats[None, :]).astype(np.float32)
    elif node.op == "concat":
        parts = [_as_2d(vals[i]).astype(np.float32) for i in node.inputs]
        vals[node.outputs[0]] = np.concatenate(parts, axis=1)
    elif node.op == "feature_extractor":
        x = _as_2d(vals[node.inputs[0]])
        vals[node.outputs[0]] = x[:, a["indices"]]
    elif node.op == "constant":
        v = np.asarray(a["value"], dtype=np.float32).reshape(1, -1)
        vals[node.outputs[0]] = np.broadcast_to(v, (n_rows, v.shape[1]))
    elif node.op == "tree_ensemble":
        ens: TreeEnsemble = a["ensemble"]
        X = _as_2d(vals[node.inputs[0]])
        score = ens.decision_function(X)
        vals[node.outputs[0]] = score
        if len(node.outputs) > 1:
            thr = a.get("decision_threshold", 0.5)
            vals[node.outputs[1]] = (score >= thr).astype(np.int64)
    elif node.op == "linear":
        X = _as_2d(vals[node.inputs[0]]).astype(np.float32)
        z = X @ a["weights"].astype(np.float32) + np.float32(a["bias"])
        if a.get("post", "none") == "logistic":
            z = 1.0 / (1.0 + np.exp(-z))
        vals[node.outputs[0]] = z
        if len(node.outputs) > 1:
            thr = a.get("decision_threshold", 0.5)
            vals[node.outputs[1]] = (z >= thr).astype(np.int64)
    elif node.op == "python_udf":
        X = _as_2d(vals[node.inputs[0]]).astype(np.float32)
        vals[node.outputs[0]] = _as_2d(
            np.asarray(a["fn"](X), dtype=np.float32)
        )
    else:
        raise ValueError(f"unknown op {node.op}")


def run_pipeline(
    pipeline: TrainedPipeline, inputs: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Op-at-a-time interpreted execution (ONNX Runtime analog)."""
    n_rows = len(next(iter(inputs.values())))
    vals: dict[str, np.ndarray] = {}
    for spec in pipeline.inputs:
        vals[spec.name] = np.asarray(inputs[spec.name])
    for node in pipeline.nodes:
        _eval_node(node, vals, n_rows)
    return {o: vals[o] for o in pipeline.outputs}


# ---------------------------------------------------------------------------
# Coverage/frontier analysis: split a partially-supported pipeline
# ---------------------------------------------------------------------------
#
# MLtoDNN used to be whole-pipeline-or-fail: one unsupported node and the
# entire pipeline fell back to a host MLUdf. The split analysis instead cuts
# the DAG into three standalone pipelines:
#
#   prefix   — the maximal supported slice reachable from the graph inputs
#              without passing through an unsupported node (lowered to the
#              tensor runtime),
#   residual — the minimal host slice: every unsupported node plus any
#              supported node sandwiched between unsupported ones,
#   suffix   — supported nodes all of whose consumers already sit in the
#              suffix (lowered back to the tensor runtime after the host
#              residual).
#
# Values crossing a segment boundary become reserved "block" columns named
# ``__pv_<value>`` (2-D (N,k) arrays threaded through the relational engine
# like any other column and dropped by their last consumer); graph outputs
# keep their query-visible names via the ``rename`` map.

SEGMENTS = ("prefix", "residual", "suffix")
_SEG_RANK = {s: i for i, s in enumerate(SEGMENTS)}


def cut_column(value: str) -> str:
    """Reserved column name for a pipeline value crossing a split boundary."""
    return f"__pv_{value}"


@dataclass
class SplitSegment:
    """One slice of a split pipeline, ready for plan emission.

    ``out_cols`` are the engine column names aligned 1:1 with
    ``pipeline.outputs``; ``consumes`` are upstream block columns this
    segment is the last consumer of (the plan node drops them).
    """

    pipeline: TrainedPipeline
    out_cols: list[str]
    consumes: list[str]


@dataclass
class PipelineSplit:
    prefix: Optional[SplitSegment]
    residual: Optional[SplitSegment]
    suffix: Optional[SplitSegment]
    # (node label, segment) per original node, topo order — the optimizer's
    # per-node runtime-placement annotation
    placement: list[tuple[str, str]]

    @property
    def fully_supported(self) -> bool:
        return self.residual is None


def _node_label(n: PipelineNode) -> str:
    return f"{n.op}[{', '.join(n.outputs)}]"


def split_pipeline(
    pipe: TrainedPipeline,
    supported,
    rename: Optional[dict[str, str]] = None,
) -> PipelineSplit:
    """Cut ``pipe`` into prefix/residual/suffix around ``supported``.

    ``supported(node) -> bool`` is the target runtime's coverage predicate;
    ``rename`` maps graph outputs to their engine column names (plan
    ``output_names``). Each returned segment is a standalone
    :class:`TrainedPipeline` executable by :func:`run_pipeline` (residual)
    or any pipeline compiler (prefix/suffix).
    """
    rename = dict(rename or {})
    nodes = pipe.nodes
    produced: dict[str, int] = {}
    for i, n in enumerate(nodes):
        for o in n.outputs:
            produced[o] = i
    consumers_idx: dict[str, list[int]] = {
        v: [j for j, m in enumerate(nodes) if v in m.inputs] for v in produced
    }

    # taint: unsupported, or transitively fed by a tainted node
    tainted = [False] * len(nodes)
    for i, n in enumerate(nodes):
        dep = any(tainted[produced[v]] for v in n.inputs if v in produced)
        tainted[i] = dep or not supported(n)
    if not any(tainted):
        return PipelineSplit(
            None, None, None, [(_node_label(n), "prefix") for n in nodes]
        )

    # suffix closure (reverse topo): a supported tainted node re-enters the
    # tensor runtime iff everything it feeds already has
    in_suffix = [False] * len(nodes)
    for i in reversed(range(len(nodes))):
        n = nodes[i]
        if tainted[i] and supported(n):
            in_suffix[i] = all(
                in_suffix[j]
                for o in n.outputs
                for j in consumers_idx.get(o, [])
            )
    seg_of = [
        "prefix" if not tainted[i] else ("suffix" if in_suffix[i] else "residual")
        for i in range(len(nodes))
    ]
    seg_rank = [_SEG_RANK[s] for s in seg_of]

    graph_inputs = {s.name for s in pipe.inputs}
    spec_of = {s.name: s for s in pipe.inputs}
    out_set = set(pipe.outputs)

    def _crossing(v: str) -> bool:
        pi = produced[v]
        return any(seg_rank[j] > seg_rank[pi] for j in consumers_idx.get(v, []))

    colname: dict[str, str] = {}
    last_rank: dict[str, int] = {}
    for v in produced:
        if v in out_set:
            colname[v] = rename.get(v, v)
        elif _crossing(v):
            colname[v] = cut_column(v)
            last_rank[v] = max(seg_rank[j] for j in consumers_idx[v])

    segments: dict[str, Optional[SplitSegment]] = {}
    for seg in SEGMENTS:
        idxs = [i for i, s in enumerate(seg_of) if s == seg]
        if not idxs:
            segments[seg] = None
            continue
        here = {o for i in idxs for o in nodes[i].outputs}
        sub_nodes = []
        specs: list[InputSpec] = []
        seen: set[str] = set()
        consumes: list[str] = []
        for i in idxs:
            n = nodes[i].copy()
            renamed_inputs = []
            for v in n.inputs:
                if v in produced and seg_of[produced[v]] != seg:
                    renamed_inputs.append(colname[v])
                else:
                    renamed_inputs.append(v)
            for orig, name in zip(n.inputs, renamed_inputs):
                if orig in here or name in seen:
                    continue
                seen.add(name)
                if orig in produced:  # an earlier segment's block column
                    specs.append(InputSpec(name, "block"))
                    if orig not in out_set and last_rank[orig] == _SEG_RANK[seg]:
                        consumes.append(name)
                else:
                    specs.append(dataclasses.replace(spec_of[orig]))
            n.inputs = renamed_inputs
            sub_nodes.append(n)
        outs_vals = []
        for i in idxs:
            for o in nodes[i].outputs:
                if o in colname and o not in outs_vals:
                    outs_vals.append(o)
        sub = TrainedPipeline(inputs=specs, outputs=outs_vals, nodes=sub_nodes)
        segments[seg] = SplitSegment(
            pipeline=sub,
            out_cols=[colname[v] for v in outs_vals],
            consumes=consumes,
        )
    return PipelineSplit(
        prefix=segments["prefix"],
        residual=segments["residual"],
        suffix=segments["suffix"],
        placement=[(_node_label(n), seg_of[i]) for i, n in enumerate(nodes)],
    )


def select_cut(
    pipeline: TrainedPipeline,
    supported,
    rename: dict[str, str] | None = None,
    cost_model=None,
    rows: int | None = None,
):
    """Cost-based cut selection: ``split_pipeline`` generates the structural
    (coverage-maximizing) cut, and a :class:`repro.core.cost.CostModel`
    judges it against the monolithic host lowering — the only other shape
    the verifier's ``residual-minimal`` rule admits. Returns
    ``(PipelineSplit, CutDecision | None)``; the decision is ``None`` when
    the pipeline is fully supported (nothing to trade off — there is no
    host boundary to price)."""
    split = split_pipeline(pipeline, supported, rename=rename)
    if split.fully_supported:
        return split, None
    from repro.core.cost import CostModel

    model = cost_model if cost_model is not None else CostModel.default()
    decision = model.choose_cut(split, pipeline.nodes, rows=rows)
    return split, decision


# ---------------------------------------------------------------------------
# Pipeline construction (the "training" front-end)
# ---------------------------------------------------------------------------


def fit_pipeline(
    columns: dict[str, np.ndarray],
    label: np.ndarray,
    numeric: list[str],
    categorical: list[str],
    estimator,
    categories: Optional[dict[str, np.ndarray]] = None,
) -> TrainedPipeline:
    """Standard enterprise pipeline: scale numerics, one-hot categoricals,
    concat, model. Mirrors the paper's trained pipelines (§7 'Trained
    pipelines')."""
    from repro.ml.featurizers import OneHotEncoder, StandardScaler

    nodes: list[PipelineNode] = []
    feat_parts: list[str] = []
    specs: list[InputSpec] = []

    if numeric:
        for c in numeric:
            specs.append(InputSpec(c, "numeric"))
        nodes.append(
            PipelineNode("concat", list(numeric), ["num_raw"], {})
        )
        Xnum = np.stack([columns[c] for c in numeric], axis=1).astype(np.float64)
        sc = StandardScaler().fit(Xnum)
        nodes.append(
            PipelineNode(
                "scaler",
                ["num_raw"],
                ["num_scaled"],
                {"offset": sc.offset, "scale": sc.scale},
            )
        )
        feat_parts.append("num_scaled")

    encoders: dict[str, OneHotEncoder] = {}
    for c in categorical:
        specs.append(InputSpec(c, "categorical"))
        if categories is not None and c in categories:
            enc = OneHotEncoder(categories=np.asarray(categories[c]))
        else:
            enc = OneHotEncoder().fit(columns[c])
        encoders[c] = enc
        nodes.append(
            PipelineNode(
                "one_hot", [c], [f"{c}_oh"], {"categories": enc.categories}
            )
        )
        feat_parts.append(f"{c}_oh")

    nodes.append(PipelineNode("concat", feat_parts, ["features"], {}))

    # featurize training data to fit the model
    parts = []
    if numeric:
        parts.append(sc.transform(Xnum))
    for c in categorical:
        parts.append(encoders[c].transform(columns[c]))
    X = np.concatenate(parts, axis=1)
    estimator.fit(X, label)

    if hasattr(estimator, "ensemble") and estimator.ensemble is not None:
        nodes.append(
            PipelineNode(
                "tree_ensemble",
                ["features"],
                ["score", "label"],
                {"ensemble": estimator.ensemble},
            )
        )
    else:
        nodes.append(
            PipelineNode(
                "linear",
                ["features"],
                ["score", "label"],
                {
                    "weights": estimator.weights,
                    "bias": estimator.bias,
                    "post": "logistic",
                },
            )
        )
    pipe = TrainedPipeline(inputs=specs, outputs=["score", "label"], nodes=nodes)
    pipe.toposort()
    return pipe


# ---------------------------------------------------------------------------
# (De)serialization — the on-disk "model format" (npz + json header)
# ---------------------------------------------------------------------------

try:  # orjson is an optional speedup (see requirements-optional.txt)
    import orjson as _json_impl

    def _json_dumps(obj) -> bytes:
        # OPT_SERIALIZE_NUMPY: accept numpy scalars in node attrs, matching
        # the stdlib fallback's _json_default behavior
        return _json_impl.dumps(obj, option=_json_impl.OPT_SERIALIZE_NUMPY)

    def _json_loads(data: bytes):
        return _json_impl.loads(data)

except ModuleNotFoundError:
    import json as _json_impl

    def _json_default(o):
        if isinstance(o, np.generic):
            return o.item()
        raise TypeError(f"not JSON-serializable: {type(o)}")

    def _json_dumps(obj) -> bytes:
        return _json_impl.dumps(obj, default=_json_default).encode()

    def _json_loads(data: bytes):
        return _json_impl.loads(data.decode())


def save_pipeline(pipeline: TrainedPipeline, path: str) -> None:
    arrays: dict[str, np.ndarray] = {}
    meta_nodes = []
    for i, n in enumerate(pipeline.nodes):
        attrs_meta: dict[str, Any] = {}
        for k, v in n.attrs.items():
            if isinstance(v, TreeEnsemble):
                for f in dataclasses.fields(v):
                    val = getattr(v, f.name)
                    if isinstance(val, np.ndarray):
                        arrays[f"n{i}.{k}.{f.name}"] = val
                    else:
                        attrs_meta.setdefault(f"{k}.__scalars__", {})[f.name] = val
                attrs_meta[k] = "__tree_ensemble__"
            elif isinstance(v, np.ndarray):
                arrays[f"n{i}.{k}"] = v
                attrs_meta[k] = "__array__"
            else:
                attrs_meta[k] = v
        meta_nodes.append(
            {"op": n.op, "inputs": n.inputs, "outputs": n.outputs, "attrs": attrs_meta}
        )
    meta = {
        "inputs": [[s.name, s.kind] for s in pipeline.inputs],
        "outputs": pipeline.outputs,
        "nodes": meta_nodes,
    }
    arrays["__meta__"] = np.frombuffer(_json_dumps(meta), dtype=np.uint8)
    np.savez(path, **arrays)


def load_pipeline(path: str) -> TrainedPipeline:
    data = np.load(path, allow_pickle=False)
    meta = _json_loads(bytes(data["__meta__"].tobytes()))
    nodes = []
    for i, nm in enumerate(meta["nodes"]):
        attrs: dict[str, Any] = {}
        for k, v in nm["attrs"].items():
            if k.endswith(".__scalars__"):
                continue
            if v == "__tree_ensemble__":
                scalars = nm["attrs"].get(f"{k}.__scalars__", {})
                kw = dict(scalars)
                for f in dataclasses.fields(TreeEnsemble):
                    key = f"n{i}.{k}.{f.name}"
                    if key in data:
                        kw[f.name] = data[key]
                attrs[k] = TreeEnsemble(**kw)
            elif v == "__array__":
                attrs[k] = data[f"n{i}.{k}"]
            else:
                attrs[k] = v
        nodes.append(PipelineNode(nm["op"], nm["inputs"], nm["outputs"], attrs))
    return TrainedPipeline(
        inputs=[InputSpec(n, k) for n, k in meta["inputs"]],
        outputs=meta["outputs"],
        nodes=nodes,
    )
