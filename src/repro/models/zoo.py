"""Model zoo: assembles the 10 assigned architectures from the substrate.

Every model exposes the same surface:

  shapes   — nested dict of param shapes (+ per-leaf dtype via cfg.dtype)
  init     — materialize params (smoke tests / small training runs)
  loss     — train-mode forward → scalar loss        (train_4k)
  prefill  — full-prompt forward → (last logits, caches)  (prefill_32k)
  decode   — one-token step over caches → (logits, caches) (decode_*)
  input_specs / decode_state_specs — ShapeDtypeStruct stand-ins for the
  dry-run (weak-type-correct, shardable, no allocation).

Family notes (see DESIGN.md §4 for skips / deviations):
  whisper   enc-dec; conv frontend is a STUB (precomputed frame embeddings);
            encoder uses sinusoidal positions, decoder RoPE (deviation noted).
  llava     decoder LM; vision patches arrive as precomputed embeddings and a
            learned projector prepends them to the token sequence.
  xlstm     grouped stacks: (slstm_every-1) mLSTM + 1 sLSTM per group.
  zamba2    Mamba2 stack with ONE shared attention+MLP block applied after
            every `attn_every` SSM layers (weight sharing), sliding-window KV.
  arctic    MoE with a dense-FFN residual in parallel; qwen2-moe adds shared
            experts. Experts are EP-sharded over `model`.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.base import ArchConfig, ShapeSpec, struct
from repro.models.transformer import (
    attn_param_shapes,
    decoder_decode_step,
    decoder_forward,
    decoder_prefill,
    decoder_layer_shapes,
    embed_lookup,
    encdec_decoder_forward,
    encoder_forward,
    mlp_param_shapes,
    stack_shapes,
)

def _enc_frames(cfg):  # whisper audio frames (30 s) — stub frontend length
    return cfg.frontend_tokens or 1500


def _vlm_patches(cfg):  # llava patch embeddings per image — stub frontend
    return cfg.frontend_tokens or 576


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _vp(cfg: ArchConfig) -> int:
    """Vocab padded to a mesh-divisible multiple (MaxText-style)."""
    return ((cfg.vocab_size + 255) // 256) * 256


def _head(h, params, cfg):
    """LM head with padded-vocab masking. h: (..., D) -> (..., Vp)."""
    z = jnp.einsum("...d,dv->...v", h, params["out_embed"])
    V, Vp = cfg.vocab_size, params["out_embed"].shape[1]
    if Vp > V:
        z = jnp.where(jnp.arange(Vp) >= V, jnp.asarray(-1e30, z.dtype), z)
    return z


@dataclass
class Model:
    cfg: ArchConfig
    shapes: dict
    loss: Callable  # (params, batch, mesh=None) -> scalar
    prefill: Callable  # (params, batch, mesh=None) -> (logits, caches)
    decode: Callable  # (params, batch, caches, mesh=None) -> (logits, caches)
    input_specs: Callable  # (ShapeSpec) -> dict[str, ShapeDtypeStruct]

    def init(self, key, dtype=None) -> dict:
        dt = dtype or _dtype(self.cfg)

        leaves = []

        def rec(t, path):
            if isinstance(t, dict):
                return {k: rec(v, f"{path}/{k}") for k, v in t.items()}
            leaves.append(path)
            return path

        skeleton = rec(self.shapes, "")
        keys = dict(zip(leaves, jax.random.split(key, max(len(leaves), 2))))

        def make(t, sk):
            if isinstance(t, dict):
                return {k: make(t[k], sk[k]) for k in t}
            shape = t
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 0.02 if len(shape) < 2 else min(0.02, (1.0 / fan_in) ** 0.5)
            name = sk.split("/")[-1]
            if name in ("ln1", "ln2", "ln", "ln_x", "final_norm", "d_skip"):
                return jnp.ones(shape, dt)
            if name in ("dt_bias",):
                return jnp.zeros(shape, jnp.float32)
            if name in ("a_log",):
                return jnp.zeros(shape, jnp.float32)  # A = -1
            return (
                jax.random.normal(keys[sk], shape, jnp.float32) * scale
            ).astype(dt)

        return make(self.shapes, skeleton)


# ---------------------------------------------------------------------------
# Decoder-LM family (dense / moe / vlm)
# ---------------------------------------------------------------------------


def _lm_shapes(cfg: ArchConfig) -> dict:
    shapes = {
        "embed": (_vp(cfg), cfg.d_model),
        "out_embed": (cfg.d_model, _vp(cfg)),
        "final_norm": (cfg.d_model,),
        "layers": stack_shapes(decoder_layer_shapes(cfg), cfg.n_layers),
    }
    if cfg.frontend == "vision":
        shapes["vision_proj_col"] = (cfg.d_model, cfg.d_model)
    return shapes


def _lm_embed_inputs(params, batch, cfg, mesh):
    tok_emb = embed_lookup(params["embed"], batch["tokens"], mesh)
    tok_emb = tok_emb.astype(_dtype(cfg))
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(_dtype(cfg))
        proj = jnp.einsum("bpd,de->bpe", patches, params["vision_proj_col"])
        return jnp.concatenate([proj, tok_emb], axis=1)
    return tok_emb


def _lm_loss(params, batch, cfg: ArchConfig, mesh=None):
    h = _lm_embed_inputs(params, batch, cfg, mesh)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    h = decoder_forward(
        params["layers"], h, cfg, positions=positions,
        window=cfg.sliding_window, mesh=mesh,
    )
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "vision":  # loss over text positions only
        h = h[:, _vlm_patches(cfg):]
    return L.xent_loss_chunked(h, params["out_embed"], batch["labels"], vocab_size=cfg.vocab_size)


def _lm_prefill(params, batch, cfg: ArchConfig, mesh=None, cache_len=None):
    h = _lm_embed_inputs(params, batch, cfg, mesh)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    cache_len = cache_len or S
    h, caches = decoder_prefill(
        params["layers"], h, cfg, positions=positions, cache_len=cache_len,
        window=cfg.sliding_window,
    )
    h = L.rmsnorm(h[:, -1], params["final_norm"], cfg.norm_eps)
    logits = _head(h, params, cfg)
    return logits, caches


def _lm_decode(params, batch, caches, cfg: ArchConfig, mesh=None):
    tokens, lengths = batch["tokens"], batch["lengths"]
    h = embed_lookup(params["embed"], tokens[:, None], mesh)[:, 0]
    h = h.astype(_dtype(cfg))
    h, caches = decoder_decode_step(
        params["layers"], h, caches, lengths, cfg, window=cfg.sliding_window
    )
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(h, params, cfg)
    return logits, caches


def _lm_input_specs(cfg: ArchConfig, sp: ShapeSpec) -> dict:
    B, Ss = sp.global_batch, sp.seq_len
    dt = _dtype(cfg)
    KH, hd, Ld = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    text = Ss - (_vlm_patches(cfg) if cfg.frontend == "vision" else 0)
    out: dict[str, Any] = {}
    if sp.kind == "train":
        out["tokens"] = struct((B, text), jnp.int32)
        out["labels"] = struct((B, text), jnp.int32)
        if cfg.frontend == "vision":
            out["patches"] = struct((B, _vlm_patches(cfg), cfg.d_model), dt)
    elif sp.kind == "prefill":
        out["tokens"] = struct((B, text), jnp.int32)
        if cfg.frontend == "vision":
            out["patches"] = struct((B, _vlm_patches(cfg), cfg.d_model), dt)
    else:  # decode
        Sc = sp.seq_len if cfg.sliding_window == 0 else min(
            sp.seq_len, cfg.sliding_window
        )
        out["tokens"] = struct((B,), jnp.int32)
        out["lengths"] = struct((B,), jnp.int32)
        out["k_cache"] = struct((Ld, B, Sc, KH, hd), dt)
        out["v_cache"] = struct((Ld, B, Sc, KH, hd), dt)
    return out


# ---------------------------------------------------------------------------
# Whisper (enc-dec)
# ---------------------------------------------------------------------------


def _whisper_shapes(cfg: ArchConfig) -> dict:
    enc_layer = {
        "ln1": (cfg.d_model,),
        "ln2": (cfg.d_model,),
        "attn": attn_param_shapes(cfg),
        "mlp": mlp_param_shapes(cfg),
    }
    return {
        "embed": (_vp(cfg), cfg.d_model),
        "out_embed": (cfg.d_model, _vp(cfg)),
        "final_norm": (cfg.d_model,),
        "enc_final_norm": (cfg.d_model,),
        "encoder_layers": stack_shapes(enc_layer, cfg.encoder_layers),
        "layers": stack_shapes(decoder_layer_shapes(cfg, cross=True), cfg.n_layers),
    }


def _sinusoid(S: int, D: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / (10000 ** (2 * i / D))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def _whisper_encode(params, frames, cfg, mesh=None):
    B, Se, D = frames.shape
    h = frames.astype(_dtype(cfg)) + jnp.asarray(
        _sinusoid(Se, D), _dtype(cfg)
    )[None]
    positions = jnp.arange(Se)[None, :].repeat(B, 0)
    h = encoder_forward(params["encoder_layers"], h, cfg, positions)
    return L.rmsnorm(h, params["enc_final_norm"], cfg.norm_eps)


def _whisper_loss(params, batch, cfg, mesh=None):
    enc = _whisper_encode(params, batch["frames"], cfg, mesh)
    tok = embed_lookup(params["embed"], batch["tokens"], mesh).astype(_dtype(cfg))
    B, S, _ = tok.shape
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    enc_positions = jnp.arange(enc.shape[1])[None, :].repeat(B, 0)
    h = encdec_decoder_forward(
        params["layers"], tok, enc, cfg,
        positions=positions, enc_positions=enc_positions,
    )
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.xent_loss_chunked(h, params["out_embed"], batch["labels"], vocab_size=cfg.vocab_size)


def _whisper_prefill(params, batch, cfg, mesh=None, cache_len=None):
    """Encode audio + run decoder prompt; emit self-KV and cross-KV caches."""
    enc = _whisper_encode(params, batch["frames"], cfg, mesh)
    B = enc.shape[0]
    # cross K/V per decoder layer (scan over stacked xattn params)
    def xkv(carry, lp):
        _, xk, xv = L.attn_proj_qkv(lp["xattn"], enc, cfg)
        return carry, (xk, xv)

    _, (xk, xv) = jax.lax.scan(xkv, None, params["layers"])

    tok = embed_lookup(params["embed"], batch["tokens"], mesh).astype(_dtype(cfg))
    S = tok.shape[1]
    positions = jnp.arange(S)[None, :].repeat(B, 0)
    cache_len = cache_len or S

    def body(carry, xs):
        hh = carry
        lp, xkl, xvl = xs
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_proj_qkv(lp["attn"], hn, cfg)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        att = L.attention_chunked(q, k, v, causal=True)
        hh = hh + jnp.einsum(
            "bsh,hd->bsd", att.reshape(B, S, -1), lp["attn"]["wo_row"]
        )
        hn = L.rmsnorm(hh, lp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dh->bsh", hn, lp["xattn"]["wq_col"]).reshape(
            B, S, cfg.n_heads, cfg.hd
        )
        attx = L.attention_chunked(qx, xkl, xvl, causal=False)
        hh = hh + jnp.einsum(
            "bsh,hd->bsd", attx.reshape(B, S, -1), lp["xattn"]["wo_row"]
        )
        m = L.mlp_block(lp["mlp"], L.rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg)
        kc = jnp.pad(k, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))
        return hh + m, (kc, vc)

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (kcs, vcs) = jax.lax.scan(body, tok, (params["layers"], xk, xv))
    h = L.rmsnorm(h[:, -1], params["final_norm"], cfg.norm_eps)
    logits = _head(h, params, cfg)
    return logits, (kcs, vcs, xk, xv)


def _whisper_decode(params, batch, caches, cfg, mesh=None):
    kcs, vcs, xk, xv = caches
    tokens, lengths = batch["tokens"], batch["lengths"]
    B = tokens.shape[0]
    h = embed_lookup(params["embed"], tokens[:, None], mesh)[:, 0].astype(
        _dtype(cfg)
    )
    pos = lengths

    def body(carry, xs):
        hh = carry
        lp, kc, vc, xkl, xvl = xs
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)[:, None]
        q, k, v = L.attn_proj_qkv(lp["attn"], hn, cfg)
        q = L.rope(q, pos[:, None], cfg.rope_theta)
        k = L.rope(k, pos[:, None], cfg.rope_theta)
        kc = kc.at[jnp.arange(B), pos].set(k[:, 0])
        vc = vc.at[jnp.arange(B), pos].set(v[:, 0])
        att = L.attention_decode(q[:, 0], kc, vc, lengths + 1)
        hh = hh + jnp.einsum("bh,hd->bd", att.reshape(B, -1), lp["attn"]["wo_row"])
        hn = L.rmsnorm(hh, lp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bd,dh->bh", hn, lp["xattn"]["wq_col"]).reshape(
            B, cfg.n_heads, cfg.hd
        )
        enc_len = jnp.full((B,), xkl.shape[1], jnp.int32)
        attx = L.attention_decode(qx, xkl, xvl, enc_len)
        hh = hh + jnp.einsum(
            "bh,hd->bd", attx.reshape(B, -1), lp["xattn"]["wo_row"]
        )
        m = L.mlp_block(
            lp["mlp"], L.rmsnorm(hh, lp["ln2"], cfg.norm_eps)[:, None], cfg
        )[:, 0]
        return hh + m, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h, (params["layers"], kcs, vcs, xk, xv))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(h, params, cfg)
    return logits, (kcs, vcs, xk, xv)


def _whisper_input_specs(cfg: ArchConfig, sp: ShapeSpec) -> dict:
    B, Ss = sp.global_batch, sp.seq_len
    dt = _dtype(cfg)
    KH, hd, Ld = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    out: dict[str, Any] = {}
    if sp.kind == "train":
        out["frames"] = struct((B, _enc_frames(cfg), cfg.d_model), dt)
        out["tokens"] = struct((B, Ss), jnp.int32)
        out["labels"] = struct((B, Ss), jnp.int32)
    elif sp.kind == "prefill":
        out["frames"] = struct((B, _enc_frames(cfg), cfg.d_model), dt)
        out["tokens"] = struct((B, Ss), jnp.int32)
    else:
        out["tokens"] = struct((B,), jnp.int32)
        out["lengths"] = struct((B,), jnp.int32)
        out["k_cache"] = struct((Ld, B, Ss, KH, hd), dt)
        out["v_cache"] = struct((Ld, B, Ss, KH, hd), dt)
        out["xk_cache"] = struct((Ld, B, _enc_frames(cfg), KH, hd), dt)
        out["xv_cache"] = struct((Ld, B, _enc_frames(cfg), KH, hd), dt)
    return out


# ---------------------------------------------------------------------------
# xLSTM (ssm family)
# ---------------------------------------------------------------------------


def _xlstm_layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_groups, mlstm_per_group, n_slstm)."""
    k = cfg.slstm_every
    n_groups = cfg.n_layers // k
    return n_groups, k - 1, n_groups


def _xlstm_shapes(cfg: ArchConfig) -> dict:
    ng, mpg, ns = _xlstm_layout(cfg)
    m_layer = {"ln": (cfg.d_model,), **S.mlstm_param_shapes(cfg)}
    s_layer = {"ln": (cfg.d_model,), **S.slstm_param_shapes(cfg)}
    return {
        "embed": (_vp(cfg), cfg.d_model),
        "out_embed": (cfg.d_model, _vp(cfg)),
        "final_norm": (cfg.d_model,),
        "mlayers": stack_shapes(m_layer, ng * mpg),
        "slayers": stack_shapes(s_layer, ns),
    }


def _xlstm_forward(params, h, cfg):
    ng, mpg, _ = _xlstm_layout(cfg)

    def m_body(carry, lp):
        y = S.mlstm_layer(lp, L.rmsnorm(carry, lp["ln"], cfg.norm_eps), cfg)
        return carry + y, None

    def s_body(carry, lp):
        y = S.slstm_layer(lp, L.rmsnorm(carry, lp["ln"], cfg.norm_eps), cfg)
        return carry + y, None

    if cfg.remat:
        m_body = jax.checkpoint(m_body)
        s_body = jax.checkpoint(s_body)

    ml = jax.tree.map(
        lambda a: a.reshape(ng, mpg, *a.shape[1:]), params["mlayers"]
    )
    for g in range(ng):
        h, _ = jax.lax.scan(m_body, h, jax.tree.map(lambda a, g=g: a[g], ml))
        sl = jax.tree.map(lambda a, g=g: a[g], params["slayers"])
        y = S.slstm_layer(sl, L.rmsnorm(h, sl["ln"], cfg.norm_eps), cfg)
        h = h + y
    return h


def _xlstm_loss(params, batch, cfg, mesh=None):
    h = embed_lookup(params["embed"], batch["tokens"], mesh).astype(_dtype(cfg))
    h = _xlstm_forward(params, h, cfg)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.xent_loss_chunked(h, params["out_embed"], batch["labels"], vocab_size=cfg.vocab_size)


def _xlstm_decode(params, batch, caches, cfg, mesh=None):
    ng, mpg, _ = _xlstm_layout(cfg)
    mh, mn, sc, sn, sm, sy = caches
    tokens = batch["tokens"]
    h = embed_lookup(params["embed"], tokens[:, None], mesh)[:, 0].astype(
        _dtype(cfg)
    )

    def m_body(carry, xs):
        hh = carry
        lp, hst, nst = xs
        y, (h2, n2) = S.mlstm_decode(
            lp, L.rmsnorm(hh, lp["ln"], cfg.norm_eps), (hst, nst), cfg
        )
        return hh + y, (h2, n2)

    ml = jax.tree.map(lambda a: a.reshape(ng, mpg, *a.shape[1:]), params["mlayers"])
    mhr = mh.reshape(ng, mpg, *mh.shape[1:])
    mnr = mn.reshape(ng, mpg, *mn.shape[1:])
    new_mh, new_mn, new_s = [], [], []
    for g in range(ng):
        h, (h2, n2) = jax.lax.scan(
            m_body, h, (jax.tree.map(lambda a, g=g: a[g], ml), mhr[g], mnr[g])
        )
        new_mh.append(h2)
        new_mn.append(n2)
        sl = jax.tree.map(lambda a, g=g: a[g], params["slayers"])
        y, st = S.slstm_decode(
            sl, L.rmsnorm(h, sl["ln"], cfg.norm_eps),
            (sc[g], sn[g], sm[g], sy[g]), cfg,
        )
        h = h + y
        new_s.append(st)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(h, params, cfg)
    caches = (
        jnp.concatenate(new_mh).reshape(mh.shape),
        jnp.concatenate(new_mn).reshape(mn.shape),
        jnp.stack([s[0] for s in new_s]),
        jnp.stack([s[1] for s in new_s]),
        jnp.stack([s[2] for s in new_s]),
        jnp.stack([s[3] for s in new_s]),
    )
    return logits, caches


def _xlstm_prefill(params, batch, cfg, mesh=None, cache_len=None):
    """SSM prefill = forward producing final recurrent states.

    For simplicity states are produced by running the chunked forms and
    taking final states; implemented via the same layer code with state
    outputs (full fidelity for dry-run shapes)."""
    # Dry-run-sufficient implementation: run forward, return zeroed states
    # of the right shapes alongside last-token logits.
    h = embed_lookup(params["embed"], batch["tokens"], mesh).astype(_dtype(cfg))
    h = _xlstm_forward(params, h, cfg)
    hl = L.rmsnorm(h[:, -1], params["final_norm"], cfg.norm_eps)
    logits = _head(hl, params, cfg)
    B = h.shape[0]
    caches = _xlstm_zero_state(cfg, B, _dtype(cfg))
    return logits, caches


def _xlstm_zero_state(cfg, B, dt):
    ng, mpg, ns = _xlstm_layout(cfg)
    H = cfg.n_heads
    P = cfg.d_model // H
    nm = ng * mpg
    return (
        jnp.zeros((nm, B * H, 1, P, P), jnp.float32),
        jnp.zeros((nm, B * H, 1, P, 1), jnp.float32),
        jnp.zeros((ns, B, cfg.d_model), jnp.float32),
        jnp.zeros((ns, B, cfg.d_model), jnp.float32),
        jnp.full((ns, B, cfg.d_model), -30.0, jnp.float32),
        jnp.zeros((ns, B, H, P), dt),
    )


def _xlstm_input_specs(cfg: ArchConfig, sp: ShapeSpec) -> dict:
    B, Ss = sp.global_batch, sp.seq_len
    dt = _dtype(cfg)
    ng, mpg, ns = _xlstm_layout(cfg)
    H = cfg.n_heads
    P = cfg.d_model // H
    nm = ng * mpg
    if sp.kind == "train":
        return {
            "tokens": struct((B, Ss), jnp.int32),
            "labels": struct((B, Ss), jnp.int32),
        }
    if sp.kind == "prefill":
        return {"tokens": struct((B, Ss), jnp.int32)}
    return {
        "tokens": struct((B,), jnp.int32),
        "lengths": struct((B,), jnp.int32),
        "mh": struct((nm, B * H, 1, P, P), jnp.float32),
        "mn": struct((nm, B * H, 1, P, 1), jnp.float32),
        "sc": struct((ns, B, cfg.d_model), jnp.float32),
        "sn": struct((ns, B, cfg.d_model), jnp.float32),
        "sm": struct((ns, B, cfg.d_model), jnp.float32),
        "sy": struct((ns, B, H, P), dt),
    }


# ---------------------------------------------------------------------------
# Zamba2 (hybrid: Mamba2 stack + ONE shared attention/MLP block)
# ---------------------------------------------------------------------------


def _zamba_layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_groups, ssm_per_group, remainder)."""
    k = cfg.attn_every
    ng = cfg.n_layers // k
    return ng, k, cfg.n_layers - ng * k


def _zamba_shapes(cfg: ArchConfig) -> dict:
    m_layer = {"ln": (cfg.d_model,), **S.mamba2_param_shapes(cfg)}
    shared = {
        "ln1": (cfg.d_model,),
        "ln2": (cfg.d_model,),
        "attn": attn_param_shapes(cfg),
        "mlp": mlp_param_shapes(cfg),
    }
    return {
        "embed": (_vp(cfg), cfg.d_model),
        "out_embed": (cfg.d_model, _vp(cfg)),
        "final_norm": (cfg.d_model,),
        "layers": stack_shapes(m_layer, cfg.n_layers),
        "shared": shared,
    }


def _zamba_forward(params, h, cfg, positions):
    ng, k, rem = _zamba_layout(cfg)

    def m_body(carry, lp):
        y = S.mamba2_layer(lp, L.rmsnorm(carry, lp["ln"], cfg.norm_eps), cfg)
        return carry + y, None

    if cfg.remat:
        m_body = jax.checkpoint(m_body)
    sh = params["shared"]

    def group(carry, gl):
        hh, _ = jax.lax.scan(m_body, carry, gl)
        a = L.attn_block(
            sh["attn"], L.rmsnorm(hh, sh["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=True, window=cfg.sliding_window,
        )
        hh = hh + a
        m = L.mlp_block(sh["mlp"], L.rmsnorm(hh, sh["ln2"], cfg.norm_eps), cfg)
        return hh + m, None

    grouped = jax.tree.map(
        lambda a: a[: ng * k].reshape(ng, k, *a.shape[1:]), params["layers"]
    )
    h, _ = jax.lax.scan(group, h, grouped)
    if rem:
        tail = jax.tree.map(lambda a: a[ng * k :], params["layers"])
        h, _ = jax.lax.scan(m_body, h, tail)
    return h


def _zamba_loss(params, batch, cfg, mesh=None):
    h = embed_lookup(params["embed"], batch["tokens"], mesh).astype(_dtype(cfg))
    B, Ss, _ = h.shape
    positions = jnp.arange(Ss)[None, :].repeat(B, 0)
    h = _zamba_forward(params, h, cfg, positions)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return L.xent_loss_chunked(h, params["out_embed"], batch["labels"], vocab_size=cfg.vocab_size)


def _zamba_prefill(params, batch, cfg, mesh=None, cache_len=None):
    h = embed_lookup(params["embed"], batch["tokens"], mesh).astype(_dtype(cfg))
    B, Ss, _ = h.shape
    positions = jnp.arange(Ss)[None, :].repeat(B, 0)
    hh = _zamba_forward(params, h, cfg, positions)
    hl = L.rmsnorm(hh[:, -1], params["final_norm"], cfg.norm_eps)
    logits = _head(hl, params, cfg)
    return logits, _zamba_zero_state(cfg, B, Ss, _dtype(cfg))


def _zamba_zero_state(cfg, B, S_cache, dt):
    ng, k, rem = _zamba_layout(cfg)
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = cfg.d_inner // H
    Ck = cfg.d_inner + 2 * N
    Sw = min(S_cache, cfg.sliding_window) if cfg.sliding_window else S_cache
    return (
        jnp.zeros((cfg.n_layers, B, H, N, P), jnp.float32),
        jnp.zeros((cfg.n_layers, B, cfg.ssm_conv - 1, Ck), dt),
        jnp.zeros((ng, B, Sw, cfg.n_kv_heads, cfg.hd), dt),
        jnp.zeros((ng, B, Sw, cfg.n_kv_heads, cfg.hd), dt),
    )


def _zamba_decode(params, batch, caches, cfg, mesh=None):
    ssm_h, conv_buf, kcs, vcs = caches
    tokens, lengths = batch["tokens"], batch["lengths"]
    B = tokens.shape[0]
    Sw = kcs.shape[2]
    h = embed_lookup(params["embed"], tokens[:, None], mesh)[:, 0].astype(
        _dtype(cfg)
    )
    ng, k, rem = _zamba_layout(cfg)
    sh = params["shared"]
    # position within the sliding window cache (ring buffer)
    slot = jnp.mod(lengths, Sw)

    def m_body(carry, xs):
        hh = carry
        lp, hst, cbuf = xs
        y, (h2, c2) = S.mamba2_decode(
            lp, L.rmsnorm(hh, lp["ln"], cfg.norm_eps), (hst, cbuf), cfg
        )
        return hh + y, (h2, c2)

    grouped = jax.tree.map(
        lambda a: a[: ng * k].reshape(ng, k, *a.shape[1:]), params["layers"]
    )
    hr = ssm_h[: ng * k].reshape(ng, k, *ssm_h.shape[1:])
    cr = conv_buf[: ng * k].reshape(ng, k, *conv_buf.shape[1:])

    def group(carry, xs):
        hh = carry
        gl, gh, gc, kc, vc = xs
        hh, (h2, c2) = jax.lax.scan(m_body, hh, (gl, gh, gc))
        hn = L.rmsnorm(hh, sh["ln1"], cfg.norm_eps)[:, None]
        q, kk, vv = L.attn_proj_qkv(sh["attn"], hn, cfg)
        q = L.rope(q, lengths[:, None], cfg.rope_theta)
        kk = L.rope(kk, lengths[:, None], cfg.rope_theta)
        kc = kc.at[jnp.arange(B), slot].set(kk[:, 0])
        vc = vc.at[jnp.arange(B), slot].set(vv[:, 0])
        att = L.attention_decode(
            q[:, 0], kc, vc, jnp.minimum(lengths + 1, Sw)
        )
        hh = hh + jnp.einsum("bh,hd->bd", att.reshape(B, -1), sh["attn"]["wo_row"])
        m = L.mlp_block(
            sh["mlp"], L.rmsnorm(hh, sh["ln2"], cfg.norm_eps)[:, None], cfg
        )[:, 0]
        return hh + m, (h2, c2, kc, vc)

    h, (h2g, c2g, kcs2, vcs2) = jax.lax.scan(group, h, (grouped, hr, cr, kcs, vcs))
    new_h = h2g.reshape(ng * k, *ssm_h.shape[1:])
    new_c = c2g.reshape(ng * k, *conv_buf.shape[1:])
    if rem:
        tail = jax.tree.map(lambda a: a[ng * k :], params["layers"])
        h, (h2t, c2t) = jax.lax.scan(
            m_body, h, (tail, ssm_h[ng * k :], conv_buf[ng * k :])
        )
        new_h = jnp.concatenate([new_h, h2t])
        new_c = jnp.concatenate([new_c, c2t])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(h, params, cfg)
    return logits, (new_h, new_c, kcs2, vcs2)


def _zamba_input_specs(cfg: ArchConfig, sp: ShapeSpec) -> dict:
    B, Ss = sp.global_batch, sp.seq_len
    dt = _dtype(cfg)
    ng, k, rem = _zamba_layout(cfg)
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = cfg.d_inner // H
    Ck = cfg.d_inner + 2 * N
    if sp.kind == "train":
        return {
            "tokens": struct((B, Ss), jnp.int32),
            "labels": struct((B, Ss), jnp.int32),
        }
    if sp.kind == "prefill":
        return {"tokens": struct((B, Ss), jnp.int32)}
    Sw = min(Ss, cfg.sliding_window) if cfg.sliding_window else Ss
    return {
        "tokens": struct((B,), jnp.int32),
        "lengths": struct((B,), jnp.int32),
        "ssm_h": struct((cfg.n_layers, B, H, N, P), jnp.float32),
        "conv_buf": struct((cfg.n_layers, B, cfg.ssm_conv - 1, Ck), dt),
        "k_cache": struct((ng, B, Sw, cfg.n_kv_heads, cfg.hd), dt),
        "v_cache": struct((ng, B, Sw, cfg.n_kv_heads, cfg.hd), dt),
    }


# ---------------------------------------------------------------------------
# build_model dispatch
# ---------------------------------------------------------------------------


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            shapes=_lm_shapes(cfg),
            loss=functools.partial(_lm_loss, cfg=cfg),
            prefill=functools.partial(_lm_prefill, cfg=cfg),
            decode=functools.partial(_lm_decode, cfg=cfg),
            input_specs=functools.partial(_lm_input_specs, cfg),
        )
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            shapes=_whisper_shapes(cfg),
            loss=functools.partial(_whisper_loss, cfg=cfg),
            prefill=functools.partial(_whisper_prefill, cfg=cfg),
            decode=functools.partial(_whisper_decode, cfg=cfg),
            input_specs=functools.partial(_whisper_input_specs, cfg),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            shapes=_xlstm_shapes(cfg),
            loss=functools.partial(_xlstm_loss, cfg=cfg),
            prefill=functools.partial(_xlstm_prefill, cfg=cfg),
            decode=functools.partial(_xlstm_decode, cfg=cfg),
            input_specs=functools.partial(_xlstm_input_specs, cfg),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            shapes=_zamba_shapes(cfg),
            loss=functools.partial(_zamba_loss, cfg=cfg),
            prefill=functools.partial(_zamba_prefill, cfg=cfg),
            decode=functools.partial(_zamba_decode, cfg=cfg),
            input_specs=functools.partial(_zamba_input_specs, cfg),
        )
    raise ValueError(cfg.family)


def decode_caches_from_specs(model: Model, sp: ShapeSpec) -> tuple:
    """Order the decode-state spec dict into the caches tuple each family's
    decode fn expects."""
    specs = model.input_specs(sp)
    fam = model.cfg.family
    if fam in ("dense", "moe", "vlm"):
        return (specs["k_cache"], specs["v_cache"])
    if fam == "encdec":
        return (
            specs["k_cache"], specs["v_cache"],
            specs["xk_cache"], specs["xv_cache"],
        )
    if fam == "ssm":
        return (
            specs["mh"], specs["mn"], specs["sc"], specs["sn"],
            specs["sm"], specs["sy"],
        )
    if fam == "hybrid":
        return (
            specs["ssm_h"], specs["conv_buf"],
            specs["k_cache"], specs["v_cache"],
        )
    raise ValueError(fam)
