from repro.models.base import ArchConfig, Shapes, param_count
from repro.models.zoo import build_model
