"""Transformer stacks: dense / MoE decoders, encoder, enc-dec composition.

Layer stacks scan over stacked params (lax.scan with the param tree as the
scanned xs) with optional remat — one traced body regardless of depth, which
is what keeps the 126-layer llama3-405b dry-run compile tractable and bounds
live activations.

Vocab-sharded embedding lookups use a shard_map masked-gather + psum over the
``model`` axis (Megatron-style) when a mesh is provided; logits/loss keep the
vocab dimension sharded end-to-end (the chunked cross-entropy reduces over
the sharded vocab axis with an automatic psum).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.base import ArchConfig, fsdp_axes
from repro.models.moe import moe_ffn, moe_param_shapes


# ---------------------------------------------------------------------------
# Param shape trees
# ---------------------------------------------------------------------------


def attn_param_shapes(cfg: ArchConfig) -> dict:
    D, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq_col": (D, H * hd),
        "wk_col": (D, KH * hd),
        "wv_col": (D, KH * hd),
        "wo_row": (H * hd, D),
    }
    if cfg.qkv_bias:
        s.update({"bq_col": (H * hd,), "bk_col": (KH * hd,), "bv_col": (KH * hd,)})
    return s


def mlp_param_shapes(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act == "silu_gated":
        return {"wg_col": (D, F), "wu_col": (D, F), "wd_row": (F, D)}
    return {"wu_col": (D, F), "wd_row": (F, D)}


def decoder_layer_shapes(cfg: ArchConfig, cross: bool = False) -> dict:
    s: dict[str, Any] = {
        "ln1": (cfg.d_model,),
        "ln2": (cfg.d_model,),
        "attn": attn_param_shapes(cfg),
    }
    if cross:
        s["ln_x"] = (cfg.d_model,)
        s["xattn"] = attn_param_shapes(cfg)
    if cfg.family == "moe":
        s["moe"] = moe_param_shapes(cfg)
    else:
        s["mlp"] = mlp_param_shapes(cfg)
    return s


def stack_shapes(layer_shapes: dict, n: int) -> dict:
    def rec(t):
        if isinstance(t, dict):
            return {k: rec(v) for k, v in t.items()}
        return (n, *t)

    return rec(layer_shapes)


# ---------------------------------------------------------------------------
# Embedding with vocab sharding
# ---------------------------------------------------------------------------


def embed_lookup(embed: jnp.ndarray, tokens: jnp.ndarray, mesh) -> jnp.ndarray:
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return jnp.take(embed, tokens, axis=0)
    from jax.experimental.shard_map import shard_map

    ax = fsdp_axes(mesh)
    # batch stays replicated when it doesn't divide the data axes (e.g. the
    # B=1 long_500k decode cells) — vocab sharding over `model` still applies.
    dsz = int(
        np.prod(
            [
                mesh.shape[a]
                for a in (ax.data if isinstance(ax.data, tuple) else (ax.data,))
            ]
        )
    )
    b_ax = ax.data if tokens.shape[0] % dsz == 0 else None

    def local(e, t):  # e: (V/m, D) local shard; t: (B/d, S) local batch
        Vl = e.shape[0]
        lo = jax.lax.axis_index("model") * Vl
        ids = t - lo
        ok = (ids >= 0) & (ids < Vl)
        out = jnp.take(e, jnp.clip(ids, 0, Vl - 1), axis=0)
        out = jnp.where(ok[..., None], out, jnp.zeros((), e.dtype))
        return jax.lax.psum(out, "model")

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P(b_ax, None)),
        out_specs=P(b_ax, None, None),
        check_rep=False,
    )(embed, tokens)


# ---------------------------------------------------------------------------
# Decoder stack (dense or MoE), scan-over-layers, train/prefill/decode modes
# ---------------------------------------------------------------------------


def _layer_fwd(lp: dict, h: jnp.ndarray, cfg: ArchConfig, positions, causal, window):
    a = L.attn_block(
        lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg,
        positions=positions, causal=causal, window=window,
    )
    h = h + a
    hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m = moe_ffn(lp["moe"], hn, cfg)
    else:
        m = L.mlp_block(lp["mlp"], hn, cfg)
    return h + m


def decoder_forward(
    layers_params: dict,
    h: jnp.ndarray,
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    mesh=None,
) -> jnp.ndarray:
    from repro.models.layers import seq_gather, seq_shard

    def body(carry, lp):
        # gather seq at entry (clean Megatron layouts inside the block),
        # re-shard at exit (remat-saved carries are 1/TP-size)
        carry = seq_gather(carry, cfg, mesh)
        out = _layer_fwd(lp, carry, cfg, positions, causal, window)
        return seq_shard(out, cfg, mesh), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h = seq_shard(h, cfg, mesh)
    h, _ = jax.lax.scan(body, h, layers_params)
    return h


def decoder_prefill(
    layers_params: dict,
    h: jnp.ndarray,
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,
    cache_len: int,
    window: int = 0,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Forward + emit per-layer K/V caches padded to cache_len."""
    B, S, _ = h.shape
    KH, hd = cfg.n_kv_heads, cfg.hd

    def body(carry, lp):
        hh = carry
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_proj_qkv(lp["attn"], hn, cfg)
        if cfg.rope_theta > 0:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        # caches keep the original KH heads; expansion is attention-local
        qe, ke, ve, Hr = L.expand_heads_for_tp(q, k, v, cfg)
        att = L.attention_chunked(qe, ke, ve, causal=True, window=window)
        att = att[:, :, :Hr].reshape(B, S, cfg.n_heads * hd)
        hh = hh + jnp.einsum("bsh,hd->bsd", att, lp["attn"]["wo_row"])
        hn2 = L.rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            m = moe_ffn(lp["moe"], hn2, cfg)
        else:
            m = L.mlp_block(lp["mlp"], hn2, cfg)
        kc = jnp.pad(k, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, cache_len - S), (0, 0), (0, 0)))
        return hh + m, (kc, vc)

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (kcs, vcs) = jax.lax.scan(body, h, layers_params)
    return h, (kcs, vcs)


def decoder_decode_step(
    layers_params: dict,
    h: jnp.ndarray,  # (B, D) one token's hidden
    kv_caches: tuple[jnp.ndarray, jnp.ndarray],  # (L,B,S,KH,hd) ×2
    lengths: jnp.ndarray,  # (B,)
    cfg: ArchConfig,
    *,
    window: int = 0,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    B = h.shape[0]
    KH, hd = cfg.n_kv_heads, cfg.hd
    pos = lengths  # 0-based position of the new token

    def body(carry, xs):
        hh = carry
        lp, kc, vc = xs
        hn = L.rmsnorm(hh, lp["ln1"], cfg.norm_eps)[:, None, :]  # (B,1,D)
        q, k, v = L.attn_proj_qkv(lp["attn"], hn, cfg)
        if cfg.rope_theta > 0:
            q = L.rope(q, pos[:, None], cfg.rope_theta)
            k = L.rope(k, pos[:, None], cfg.rope_theta)
        kc = kc.at[jnp.arange(B), pos].set(k[:, 0])
        vc = vc.at[jnp.arange(B), pos].set(v[:, 0])
        att = L.attention_decode(q[:, 0], kc, vc, lengths + 1, window=window)
        hh = hh + jnp.einsum("bh,hd->bd", att.reshape(B, -1), lp["attn"]["wo_row"])
        hn2 = L.rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            m = moe_ffn(lp["moe"], hn2[:, None, :], cfg)[:, 0]
        else:
            m = L.mlp_block(lp["mlp"], hn2[:, None, :], cfg)[:, 0]
        return hh + m, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h, (layers_params, *kv_caches))
    return h, (kcs, vcs)


# ---------------------------------------------------------------------------
# Encoder stack (whisper) + cross-attention decoder
# ---------------------------------------------------------------------------


def encoder_forward(layers_params, h, cfg: ArchConfig, positions):
    def body(carry, lp):
        a = L.attn_block(
            lp["attn"], L.rmsnorm(carry, lp["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=False,
        )
        hh = carry + a
        m = L.mlp_block(lp["mlp"], L.rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg)
        return hh + m, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, layers_params)
    return h


def encdec_decoder_forward(
    layers_params, h, enc_out, cfg: ArchConfig, *, positions, enc_positions
):
    """Decoder with self-attn + cross-attn (training / scoring path)."""
    B, S, _ = h.shape

    def body(carry, lp):
        hh = carry
        a = L.attn_block(
            lp["attn"], L.rmsnorm(hh, lp["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=True,
        )
        hh = hh + a
        # cross-attention: keys/values from encoder output
        hn = L.rmsnorm(hh, lp["ln_x"], cfg.norm_eps)
        _, xk, xv = L.attn_proj_qkv(lp["xattn"], enc_out, cfg)
        q = jnp.einsum("bsd,dh->bsh", hn, lp["xattn"]["wq_col"])
        if cfg.qkv_bias:
            q = q + lp["xattn"]["bq_col"]
        q = q.reshape(B, S, cfg.n_heads, cfg.hd)
        att = L.attention_chunked(q, xk, xv, causal=False)
        att = att.reshape(B, S, cfg.n_heads * cfg.hd)
        hh = hh + jnp.einsum("bsh,hd->bsd", att, lp["xattn"]["wo_row"])
        m = L.mlp_block(lp["mlp"], L.rmsnorm(hh, lp["ln2"], cfg.norm_eps), cfg)
        return hh + m, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, layers_params)
    return h
