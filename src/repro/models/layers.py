"""Core layers: norms, RoPE, memory-bounded attention, MLPs, embeddings.

All functions are pure and operate on param sub-dicts whose leaf names carry
their sharding convention (``*_col`` column-parallel, ``*_row`` row-parallel,
``embed`` vocab-sharded — see models/base.py). Attention for long sequences
is the two-level online-softmax form (scan over KV chunks inside a scan over
Q chunks) so live memory is O(chunk²) instead of O(S²) — the XLA analog of
the Pallas flash kernel, used on non-TPU backends and in dry-runs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def seq_shard(h: jnp.ndarray, cfg, mesh) -> jnp.ndarray:
    """Megatron-SP: shard the residual stream's sequence dim over `model`
    between blocks. Applied at layer-scan boundaries so the remat-saved
    carries are 1/TP-size. No-op unless cfg.act_shard == 'seq'."""
    if mesh is None or getattr(cfg, "act_shard", "none") != "seq":
        return h
    from jax.sharding import PartitionSpec as P

    dax = ("pod", "data") if "pod" in mesh.axis_names else "data"
    if h.ndim == 3 and h.shape[1] % mesh.shape["model"] == 0:
        return jax.lax.with_sharding_constraint(h, P(dax, "model", None))
    return h


def seq_gather(h: jnp.ndarray, cfg, mesh) -> jnp.ndarray:
    """Megatron-SP companion: explicit sequence all-gather at block entry so
    the block's matmuls see clean (batch-sharded, seq-replicated) layouts —
    without this, the partitioner may instead gather FULL weight matrices
    out of the layer scan (observed: 3.25 GiB f32 whole-matrix gathers)."""
    if mesh is None or getattr(cfg, "act_shard", "none") != "seq":
        return h
    from jax.sharding import PartitionSpec as P

    dax = ("pod", "data") if "pod" in mesh.axis_names else "data"
    if h.ndim == 3:
        return jax.lax.with_sharding_constraint(h, P(dax, None, None))
    return h


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) rotary over last dim; positions: (..., S)."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask(q_pos, k_pos, causal: bool, window: int):
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    ok = kp <= qp if causal else jnp.full((q_pos.shape[0], k_pos.shape[0]), True)
    if window > 0:
        ok = ok & (kp > qp - window)
    return ok


def attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Two-level online-softmax attention.

    q: (B,Sq,H,D); k,v: (B,Skv,KH,D); GQA via H % KH == 0.
    q_offset: global position of q[0] (for decode/prefix chunking).
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    cq = min(q_chunk, Sq)
    ck = min(k_chunk, Skv)
    # pad to multiples
    pq = (-Sq) % cq
    pk = (-Skv) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // cq, k.shape[1] // ck
    # KV blocks stream in their storage dtype; dots accumulate in f32 via
    # preferred_element_type — pre-casting bf16 K/V to f32 would double the
    # streamed bytes (EXPERIMENTS.md §Perf/decode applies here too)
    qc = (q.astype(jnp.float32) * scale).astype(k.dtype).reshape(
        B, nq, cq, KH, G, D
    )
    kc = k.reshape(B, nk, ck, KH, D)
    vc = v.reshape(B, nk, ck, KH, D)

    def q_step(_, qi):
        qb, iq = qi  # qb: (B,cq,KH,G,D)
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        @jax.checkpoint  # recompute p/alpha in backward: O(carry) residency
        def kv_step(carry, kvj):
            m, l, acc = carry
            kb, vb, jk = kvj
            k_pos = jk * ck + jnp.arange(ck)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb,
                preferred_element_type=jnp.float32,
            )
            ok = _mask(q_pos, k_pos, causal, window)
            # mask padded kv as well
            ok = ok & (k_pos < Skv)[None, :]
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KH,G,cq,D)
        return None, out

    qs = qc.transpose(1, 0, 2, 3, 4, 5)  # (nq,B,cq,KH,G,D)
    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs: (nq,B,KH,G,cq,D) -> (B, nq*cq, KH*G, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * cq, H, D)
    return out[:, :Sq].astype(q.dtype)


def attention_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One-token attention over a cache. q:(B,H,D); caches:(B,S,KH,D).

    Caches are consumed in their storage dtype with f32 accumulation
    (``preferred_element_type``) — pre-casting bf16 caches to f32 would
    materialize a full f32 copy of every layer's cache per step, doubling
    decode's HBM traffic (measured: EXPERIMENTS.md §Perf/decode)."""
    B, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype).reshape(B, KH, G, D)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    pos = jnp.arange(S)[None, :]
    ok = pos < lengths[:, None]
    if window > 0:
        ok = ok & (pos > lengths[:, None] - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + attention)
# ---------------------------------------------------------------------------


def attn_proj_qkv(p: dict, x: jnp.ndarray, cfg) -> tuple:
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq_col"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk_col"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv_col"])
    if cfg.qkv_bias:
        q = q + p["bq_col"]
        k = k + p["bk_col"]
        v = v + p["bv_col"]
    B, S = x.shape[0], x.shape[1]
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KH, hd),
        v.reshape(B, S, KH, hd),
    )


def expand_heads_for_tp(q, k, v, cfg):
    """Repeat-KV (GQA -> MHA view) + zero-pad heads to cfg.tp_pad_heads so
    the attention score tensor's head dim divides the `model` axis.

    Exact math: MHA head h uses repeated kv[h] == original kv[h // G], the
    same q->kv assignment GQA computes; zero-padded q heads produce outputs
    that the caller slices away before the output projection. The xG kv
    expansion is itself TP-sharded, strictly cheaper than the replicated
    attention these head counts otherwise force (EXPERIMENTS.md §Perf)."""
    Hp = getattr(cfg, "tp_pad_heads", 0)
    H, KH = q.shape[2], k.shape[2]
    if not Hp or Hp < H:
        return q, k, v, H
    if KH < H:
        G = H // KH
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    pad = Hp - H
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    return q, k, v, H


def attn_block(
    p: dict, x: jnp.ndarray, cfg, *, positions, causal=True, window=0,
    kv_override=None,
) -> jnp.ndarray:
    """Full-sequence attention block (train/prefill).

    kv_override: (k, v) for cross-attention (already projected)."""
    B, S, Dm = x.shape
    q, k, v = attn_proj_qkv(p, x, cfg)
    if kv_override is not None:
        k, v = kv_override
        q = rope(q, positions, cfg.rope_theta) if cfg.rope_theta > 0 else q
    elif cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q, k, v, H = expand_heads_for_tp(q, k, v, cfg)
    out = attention_chunked(q, k, v, causal=causal, window=window)
    out = out[:, :, :H].reshape(B, S, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo_row"])


def mlp_block(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.mlp_act == "silu_gated":
        g = jnp.einsum("bsd,df->bsf", x, p["wg_col"])
        u = jnp.einsum("bsd,df->bsf", x, p["wu_col"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:  # gelu
        h = jnp.einsum("bsd,df->bsf", x, p["wu_col"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["wd_row"])


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------


def embed_tokens(embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(embed, tokens, axis=0)


def lm_logits(x: jnp.ndarray, out_embed: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,D); out_embed: (D,V) column-parallel."""
    return jnp.einsum("bsd,dv->bsv", x, out_embed)


def xent_loss_chunked(
    x: jnp.ndarray, out_embed: jnp.ndarray, labels: jnp.ndarray,
    chunk: int = 512, vocab_size: Optional[int] = None,
) -> jnp.ndarray:
    """Sequence-chunked softmax cross-entropy: bounds the live logits tensor
    to (B, chunk, V) instead of (B, S, V). ``vocab_size`` masks padded vocab
    columns (embeddings are padded to mesh-divisible widths)."""
    B, S, D = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = x.shape[1] // chunk
    xc = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute per-chunk logits in backward (never stored)
    def step(carry, xl):
        tot, cnt = carry
        xb, lb = xl
        logits = jnp.einsum("bsd,dv->bsv", xb, out_embed).astype(jnp.float32)
        if vocab_size is not None and vocab_size < out_embed.shape[1]:
            pad_mask = jnp.arange(out_embed.shape[1]) >= vocab_size
            logits = jnp.where(pad_mask, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        tot = tot + ((lse - gold) * valid).sum()
        cnt = cnt + valid.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0)
