"""SSM blocks: Mamba2 (chunked SSD) and xLSTM (mLSTM matrix memory, sLSTM).

The chunked SSD kernel is shared: within a chunk of length Q the recurrence
is materialized as a (Q,Q) decay-masked attention-like contraction (the
Mamba2 "quadratic mode"), across chunks a lax.scan carries the (H,N,P) state
— O(S·Q) work and O(B·H·Q²) live memory instead of O(S²).

mLSTM is the same machinery with B←k, C←q, per-head exponential input gate as
dt and forget gate as the decay; sLSTM is a true sequential scan (scalar
memory mixing — noted in DESIGN.md as inherently recurrent).

Decode steps are single-token recurrent updates against carried (state, conv
buffer) — O(1) in sequence length, which is what makes long_500k decode
tractable for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Generic chunked SSD:  h_t = a_t · h_{t-1} + dt_t · (b_t ⊗ x_t),
#                       y_t = c_t · h_t
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jnp.ndarray,      # (B,S,H,P)
    a_log: jnp.ndarray,  # (B,S,H)  log decay per step (<= 0)
    b: jnp.ndarray,      # (B,S,N)
    c: jnp.ndarray,      # (B,S,N)
    dt: jnp.ndarray,     # (B,S,H)  input scale
    chunk: int = 128,
    h0: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    B, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nb = x.shape[1] // Q

    xc = x.reshape(B, nb, Q, H, P).transpose(1, 0, 2, 3, 4)
    ac = a_log.reshape(B, nb, Q, H).transpose(1, 0, 2, 3).astype(jnp.float32)
    bc = b.reshape(B, nb, Q, N).transpose(1, 0, 2, 3)
    cc = c.reshape(B, nb, Q, N).transpose(1, 0, 2, 3)
    dc = dt.reshape(B, nb, Q, H).transpose(1, 0, 2, 3)

    mask = jnp.tril(jnp.ones((Q, Q), bool))

    @jax.checkpoint  # decay matrices recomputed in backward, never stored
    def step(h, inputs):  # h: (B,H,N,P) f32
        xb, ab, bb, cb, db = inputs
        L = jnp.cumsum(ab, axis=1)  # (B,Q,H)
        # intra-chunk: W[t,i,h] = exp(L_t - L_i) · (c_t·b_i), i<=t
        cbm = jnp.einsum("bqn,bin->bqi", cb.astype(jnp.float32), bb.astype(jnp.float32))
        decay = jnp.exp(
            jnp.clip(L[:, :, None, :] - L[:, None, :, :], -60.0, 0.0)
        )  # (B,Q,Q,H)
        W = cbm[..., None] * decay * mask[None, :, :, None]
        xt = xb.astype(jnp.float32) * db[..., None]  # (B,Q,H,P)
        y_intra = jnp.einsum("bqih,bihp->bqhp", W, xt)
        # inter-chunk: y += c_t · h · exp(L_t)
        y_inter = jnp.einsum(
            "bqn,bhnp,bqh->bqhp", cc_f(cb), h, jnp.exp(jnp.clip(L, -60.0, 0.0))
        )
        # state update: h' = h·exp(L_last) + Σ_i b_i ⊗ x̃_i · exp(L_last - L_i)
        last = L[:, -1:, :]  # (B,1,H)
        w_state = jnp.exp(jnp.clip(last - L, -60.0, 0.0))  # (B,Q,H)
        h_new = h * jnp.exp(jnp.clip(last[:, 0][:, :, None, None], -60.0, 0.0)) + jnp.einsum(
            "bin,bih,bihp->bhnp", cc_f(bb), w_state, xt
        )
        return h_new, (y_intra + y_inter)

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, (xc, ac, bc, cc, dc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nb * Q, H, P)[:, :S]
    return y.astype(x.dtype), hT


def cc_f(t):
    return t.astype(jnp.float32)


def ssd_decode_step(
    h: jnp.ndarray,      # (B,H,N,P) f32
    x: jnp.ndarray,      # (B,H,P)
    a_log: jnp.ndarray,  # (B,H)
    b: jnp.ndarray,      # (B,N)
    c: jnp.ndarray,      # (B,N)
    dt: jnp.ndarray,     # (B,H)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    a = jnp.exp(jnp.clip(a_log.astype(jnp.float32), -60.0, 0.0))
    xt = x.astype(jnp.float32) * dt[..., None]
    h_new = h * a[..., None, None] + jnp.einsum("bn,bhp->bhnp", cc_f(b), xt)
    y = jnp.einsum("bn,bhnp->bhp", cc_f(c), h_new)
    return h_new, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 layer
# ---------------------------------------------------------------------------


def mamba2_proj(p: dict, x: jnp.ndarray, cfg):
    """Input projections (separate matrices so TP shard boundaries align
    with the semantic segments z/x/B/C/dt)."""
    z = jnp.einsum("...d,de->...e", x, p["wz_col"])
    xs = jnp.einsum("...d,de->...e", x, p["wx_col"])
    bmat = jnp.einsum("...d,dn->...n", x, p["wb"])
    cmat = jnp.einsum("...d,dn->...n", x, p["wc"])
    dt = jnp.einsum("...d,dh->...h", x, p["wdt"])
    return z, xs, bmat, cmat, dt


def _causal_conv(xs: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. xs: (B,S,Ck); w: (K,Ck)."""
    K = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xs.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out


def mamba2_layer(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    B, S, D = x.shape
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = din // H
    z, xs, bmat, cmat, dt = mamba2_proj(p, x, cfg)
    act = lambda t: jax.nn.silu(t.astype(jnp.float32)).astype(x.dtype)
    xs = act(_causal_conv(xs, p["conv_x"]))
    bmat = act(_causal_conv(bmat, p["conv_b"]))
    cmat = act(_causal_conv(cmat, p["conv_c"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_log = -jnp.exp(p["a_log"]) * dt  # (B,S,H)
    xh = xs.reshape(B, S, H, P)
    y, _ = ssd_chunked(xh, a_log, bmat, cmat, dt, chunk=cfg.ssm_chunk)
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, din) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["wout_row"])


def mamba2_decode(p: dict, x: jnp.ndarray, state, cfg):
    """x: (B,D) one token; state: (h (B,H,N,P) f32, conv_buf (B,K-1,Ck))."""
    B, D = x.shape
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = din // H
    h, conv_buf = state  # conv_buf: (B, K-1, din + 2N)
    z, xs, bmat, cmat, dt = mamba2_proj(p, x, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)  # (B, din+2N)
    window = jnp.concatenate([conv_buf, conv_in[:, None, :]], axis=1)
    wfull = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, wfull).astype(jnp.float32)
    ).astype(x.dtype)
    xs, bmat, cmat = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a_log = -jnp.exp(p["a_log"]) * dt
    h_new, y = ssd_decode_step(h, xs.reshape(B, H, P), a_log, bmat, cmat, dt)
    y = y + xs.reshape(B, H, P) * p["d_skip"][None, :, None]
    y = y.reshape(B, din) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["wout_row"])
    return out, (h_new, window[:, 1:, :])


def mamba2_param_shapes(cfg) -> dict:
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "wz_col": (cfg.d_model, din),
        "wx_col": (cfg.d_model, din),
        "wb": (cfg.d_model, N),
        "wc": (cfg.d_model, N),
        "wdt": (cfg.d_model, H),
        "conv_x": (cfg.ssm_conv, din),
        "conv_b": (cfg.ssm_conv, N),
        "conv_c": (cfg.ssm_conv, N),
        "dt_bias": (H,),
        "a_log": (H,),
        "d_skip": (H,),
        "wout_row": (din, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory — SSD machinery) and sLSTM (sequential)
# ---------------------------------------------------------------------------


def mlstm_layer(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """mLSTM: h_t = f_t·h + i_t·(k_t ⊗ v_t); y_t = q_t·h_t (per head)."""
    B, S, D = x.shape
    H = cfg.n_heads
    P = D // H
    qkv = jnp.einsum("bsd,de->bse", x, p["wqkv_col"])  # (B,S,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    gates = jnp.einsum("bsd,dg->bsg", x, p["wgate_col"]).astype(jnp.float32)
    i_g, f_g = jnp.split(gates, 2, axis=-1)  # (B,S,H) each
    f_log = -jax.nn.softplus(-f_g)  # log sigmoid ≤ 0
    i_s = jnp.exp(jnp.clip(i_g, -30.0, 8.0))
    vh = v.reshape(B, S, H, P)
    # b ← k heads averaged into shared N=P state basis (per-head handled by
    # folding head into batch for exactness)
    kh = k.reshape(B, S, H, P)
    qh = q.reshape(B, S, H, P)
    # fold heads into batch so each head gets its own (N=P) basis
    xf = vh.transpose(0, 2, 1, 3).reshape(B * H, S, 1, P)
    af = f_log.transpose(0, 2, 1).reshape(B * H, S, 1)
    bf = kh.transpose(0, 2, 1, 3).reshape(B * H, S, P) / (P ** 0.5)
    cf = qh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    df = i_s.transpose(0, 2, 1).reshape(B * H, S, 1)
    y, _ = ssd_chunked(xf, af, bf, cf, df, chunk=cfg.ssm_chunk)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    # normalizer: n_t = f·n + i·k ; denom = |q·n| (running, same machinery
    # with x ≡ 1)
    ones = jnp.ones_like(xf[..., :1])
    nrm, _ = ssd_chunked(ones, af, bf, cf, df, chunk=cfg.ssm_chunk)
    nrm = nrm.reshape(B, H, S, 1).transpose(0, 2, 1, 3)
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    y = y.reshape(B, S, D) * jax.nn.silu(
        jnp.einsum("bsd,de->bse", x, p["wz_col"]).astype(jnp.float32)
    ).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["wo_row"])


def mlstm_decode(p: dict, x: jnp.ndarray, state, cfg):
    B, D = x.shape
    H = cfg.n_heads
    P = D // H
    h, n = state  # h: (B*H,1,P,P) f32, n: (B*H,1,P,1)? store jointly
    qkv = jnp.einsum("bd,de->be", x, p["wqkv_col"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    gates = jnp.einsum("bd,dg->bg", x, p["wgate_col"]).astype(jnp.float32)
    i_g, f_g = jnp.split(gates, 2, axis=-1)
    f_log = -jax.nn.softplus(-f_g)
    i_s = jnp.exp(jnp.clip(i_g, -30.0, 8.0))
    vh = v.reshape(B * H, 1, P)
    kh = k.reshape(B * H, P) / (P ** 0.5)
    qh = q.reshape(B * H, P)
    af = f_log.reshape(B * H, 1)
    df = i_s.reshape(B * H, 1)
    h_new, y = ssd_decode_step(h, vh, af, kh, qh, df)
    ones = jnp.ones_like(vh[..., :1])
    n_new, nrm = ssd_decode_step(n, ones, af, kh, qh, df)
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    y = y.reshape(B, D) * jax.nn.silu(
        jnp.einsum("bd,de->be", x, p["wz_col"]).astype(jnp.float32)
    ).astype(x.dtype)
    return jnp.einsum("be,ed->bd", y, p["wo_row"]), (h_new, n_new)


def mlstm_param_shapes(cfg) -> dict:
    D = cfg.d_model
    return {
        "wqkv_col": (D, 3 * D),
        "wgate_col": (D, 2 * cfg.n_heads),
        "wz_col": (D, D),
        "wo_row": (D, D),
    }


def slstm_layer(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """sLSTM: scalar-memory LSTM with exponential gating and per-head
    recurrent mixing. Sequential lax.scan over time (inherently recurrent)."""
    B, S, D = x.shape
    H = cfg.n_heads
    P = D // H
    zifo = jnp.einsum("bsd,de->bse", x, p["wzifo_col"])  # (B,S,4D)

    @jax.checkpoint
    def step(carry, zt):  # zt: (B,4D)
        c, n, m, y_prev = carry
        # per-head recurrence: head h's output feeds head h's gate slices
        rec = jnp.einsum("bhp,hpq->bhq", y_prev, p["r_dp"])  # (B,H,4P)
        rec = rec.reshape(B, H, 4, P).transpose(0, 2, 1, 3).reshape(B, 4 * D)
        z, i_g, f_g, o = jnp.split(
            (zt + rec).astype(jnp.float32), 4, axis=-1
        )
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        log_f = -jax.nn.softplus(-f_g)
        m_new = jnp.maximum(log_f + m, i_g)
        i_s = jnp.exp(jnp.clip(i_g - m_new, -30.0, 0.0))
        f_s = jnp.exp(jnp.clip(log_f + m - m_new, -30.0, 0.0))
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        y = (o * c_new / jnp.maximum(n_new, 1.0)).astype(x.dtype)
        return (c_new, n_new, m_new, y.reshape(B, H, P)), y

    c0 = jnp.zeros((B, D), jnp.float32)
    m0 = jnp.full((B, D), -30.0, jnp.float32)
    y0 = jnp.zeros((B, H, P), x.dtype)
    (_, _, _, _), ys = jax.lax.scan(
        step, (c0, c0, m0, y0), zifo.transpose(1, 0, 2)
    )
    y = ys.transpose(1, 0, 2)  # (B,S,D)
    return jnp.einsum("bse,ed->bsd", y, p["wo_row"])


def slstm_decode(p: dict, x: jnp.ndarray, state, cfg):
    B, D = x.shape
    H = cfg.n_heads
    P = D // H
    c, n, m, y_prev = state
    zt = jnp.einsum("bd,de->be", x, p["wzifo_col"])
    rec = jnp.einsum("bhp,hpq->bhq", y_prev, p["r_dp"])
    rec = rec.reshape(B, H, 4, P).transpose(0, 2, 1, 3).reshape(B, 4 * D)
    z, i_g, f_g, o = jnp.split((zt + rec).astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = -jax.nn.softplus(-f_g)
    m_new = jnp.maximum(log_f + m, i_g)
    i_s = jnp.exp(jnp.clip(i_g - m_new, -30.0, 0.0))
    f_s = jnp.exp(jnp.clip(log_f + m - m_new, -30.0, 0.0))
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    y = (o * c_new / jnp.maximum(n_new, 1.0)).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["wo_row"])
    return out, (c_new, n_new, m_new, y.reshape(B, H, P))


def slstm_param_shapes(cfg) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    P = D // H
    return {
        "wzifo_col": (D, 4 * D),
        "r_dp": (H, P, 4 * P),
        "wo_row": (D, D),
    }
