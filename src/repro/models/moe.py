"""Mixture-of-experts layer: GShard-style capacity dispatch, block-chunked.

Experts are sharded over the ``model`` axis (EP); tokens arrive sharded over
``data``. The dispatch einsum reshards token-major → expert-major, which the
SPMD partitioner lowers to the expected all-to-all over ``model``. Dispatch
tensors are O(tb · E · C) so tokens are processed in blocks of ``tb`` under
lax.scan, keeping the dispatch one-hot bounded (~tens of MB) at 500k-token
scales instead of O(T · E · C) (~tens of GB).

Variants (per config):
  * shared experts (qwen2-moe): always-on experts added to routed output;
  * dense residual (arctic): a dense FFN runs in parallel with the MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _capacity(tb: int, k: int, E: int, cf: float) -> int:
    c = int(np.ceil(tb * k / E * cf))
    return max(4, ((c + 3) // 4) * 4)


def moe_ffn(p: dict, x: jnp.ndarray, cfg, token_block: int = 4096) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D). p holds router + expert weights.

    Token blocks slice the SEQUENCE dim only — every block keeps the full
    batch dim, so blocks stay sharded over `data` and the partitioner splits
    each block's routing/dispatch/FFN across chips. (Blocking the flattened
    (B·S) stream instead makes each block a single batch-row slice, which is
    resident on ONE chip — the compiled program then replicates every block's
    compute on all chips: a measured 16x executed-flop/byte inflation at
    mesh data=16; see EXPERIMENTS.md §Perf/moe iteration 2.)
    """
    B, S, D = x.shape
    x0 = x  # unpadded view for the shared/residual branches below
    E_real, K = cfg.moe_experts, cfg.moe_top_k
    E = p["w1_exp"].shape[0]  # possibly padded (moe_pad_experts)
    sb = max(1, min(token_block // B, S))  # seq positions per block
    pad = (-S) % sb
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nb = Sp // sb
    tb = B * sb  # tokens per block (global)
    # (B, Sp, D) -> (nb, B*sb, D), seq-major blocks with batch dim intact
    xt = x.reshape(B, nb, sb, D).transpose(1, 0, 2, 3).reshape(nb, tb, D)
    C = _capacity(tb, K, E_real, cfg.moe_capacity_factor)

    w1, w2, w3 = p["w1_exp"], p["w2_exp"], p["w3_exp"]  # (E,D,F),(E,F,D),(E,D,F)
    wr = p["router_col"]  # (D, E)

    def _route(xb):
        """Router + per-(token,k) capacity position. Shared by both
        dispatch variants."""
        logits = jnp.einsum("td,de->te", xb, wr).astype(jnp.float32)
        if E > E_real:  # padded experts can never win the top-k
            logits = jnp.where(jnp.arange(E) >= E_real, -1e30, logits)
        gates = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(gates, K)  # (tb,K)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        # position of each (token, k) within its expert's capacity buffer
        onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)  # (tb,K,E)
        flat = onehot.reshape(tb * K, E)
        pos_in_e = jnp.cumsum(flat, axis=0) - flat  # (tb*K, E)
        pos = (pos_in_e * flat).sum(-1).reshape(tb, K)  # (tb,K)
        keep = pos < C
        return topv, topi, pos, keep

    def _experts(xe):
        """(E,C,D) -> (E,C,D) expert FFNs."""
        g = jnp.einsum("ecd,edf->ecf", xe, w1)
        u = jnp.einsum("ecd,edf->ecf", xe, w3)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
        return jnp.einsum("ecf,efd->ecd", h, w2)

    @jax.checkpoint  # dispatch one-hots recomputed in backward
    def block_einsum(carry, xb):  # xb: (tb, D) — GShard one-hot dispatch
        topv, topi, pos, keep = _route(xb)
        disp = (
            jax.nn.one_hot(topi, E, dtype=xb.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=xb.dtype)[:, :, None, :]
        )[..., :C]  # (tb,K,E,C)
        disp_t = disp.sum(1)  # (tb,E,C)
        xe = jnp.einsum("tec,td->ecd", disp_t, xb)  # (E,C,D)
        ye = _experts(xe)
        comb = (disp * topv.astype(xb.dtype)[..., None, None]).sum(1)  # (tb,E,C)
        yb = jnp.einsum("tec,ecd->td", comb, ye)
        return carry, yb

    @jax.checkpoint
    def block_scatter(carry, xb):  # sort-free scatter/gather dispatch
        # The one-hot dispatch/combine einsums above cost O(tb·E·C·D) MXU
        # flops and materialize a (tb,K,E,C) tensor — as expensive as the
        # expert FFNs themselves (measured: EXPERIMENTS.md §Perf/moe).
        # Every kept (token, k) owns a unique slot = expert·C + pos, so
        # dispatch is a scatter and combine a gather — O(tb·K·D) bytes,
        # zero matmul flops.
        topv, topi, pos, keep = _route(xb)
        slot = jnp.where(keep, topi * C + pos, E * C)  # (tb,K); E*C = trash
        tok = jnp.broadcast_to(jnp.arange(tb)[:, None], (tb, K))
        buf = jnp.zeros((E * C + 1, D), xb.dtype)
        buf = buf.at[slot.reshape(-1)].set(
            xb[tok.reshape(-1)], mode="drop", unique_indices=False
        )
        ye = _experts(buf[: E * C].reshape(E, C, D))
        ye_flat = jnp.concatenate(
            [ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)]
        )
        gathered = ye_flat[slot]  # (tb,K,D)
        w = jnp.where(keep, topv, 0.0).astype(xb.dtype)
        yb = (gathered * w[..., None]).sum(1)
        return carry, yb

    block = (
        block_scatter
        if getattr(cfg, "moe_dispatch", "einsum") == "scatter"
        else block_einsum
    )
    _, ys = jax.lax.scan(block, None, xt)
    # (nb, B*sb, D) -> (B, Sp, D) -> strip seq padding
    y = (
        ys.reshape(nb, B, sb, D)
        .transpose(1, 0, 2, 3)
        .reshape(B, Sp, D)[:, :S]
    )

    if cfg.moe_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x0, p["ws1_col"])
        u = jnp.einsum("bsd,df->bsf", x0, p["ws3_col"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x0.dtype) * u
        y = y + jnp.einsum("bsf,fd->bsd", h, p["ws2_row"])
    if cfg.moe_dense_residual:
        g = jnp.einsum("bsd,df->bsf", x0, p["wr1_col"])
        u = jnp.einsum("bsd,df->bsf", x0, p["wr3_col"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x0.dtype) * u
        y = y + jnp.einsum("bsf,fd->bsd", h, p["wr2_row"])
    return y


def moe_param_shapes(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    # expert dim padded at the PARAMETER level so shardings_for assigns
    # P(model, ...) to *_exp leaves (EP engages); router masks the padding
    E = max(cfg.moe_experts, getattr(cfg, "moe_pad_experts", 0) or 0)
    shapes = {
        "router_col": (D, E),
        "w1_exp": (E, D, F),
        "w2_exp": (E, F, D),
        "w3_exp": (E, D, F),
    }
    if cfg.moe_shared_experts:
        Fs = cfg.moe_shared_d_ff
        shapes.update(
            {"ws1_col": (D, Fs), "ws2_row": (Fs, D), "ws3_col": (D, Fs)}
        )
    if cfg.moe_dense_residual:
        shapes.update(
            {"wr1_col": (D, F), "wr2_row": (F, D), "wr3_col": (D, F)}
        )
    return shapes
