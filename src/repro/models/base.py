"""Architecture configs + sharding rules for the LM model zoo.

Parameters are stored as nested dicts with *stacked* per-layer leaves
(leading L dimension) so layer stacks run under ``jax.lax.scan`` — bounding
both compile time (one traced body for 126-layer llama3-405b) and, with
remat, live activation memory.

Sharding follows DESIGN.md §5: TP over ``model`` (column-parallel QKV/up,
row-parallel O/down, vocab-sharded embeddings), ZeRO-3/FSDP over ``data``
(and ``pod`` when multi-pod), sequence-parallel activations for long-context
shapes, experts over ``model`` (EP).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}
Shapes = SHAPES


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_act: str = "silu_gated"  # or "gelu"
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_shared_d_ff: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel
    moe_capacity_factor: float = 1.25
    # "einsum": GShard one-hot dispatch (SPMD-friendly baseline)
    # "scatter": sort-free scatter/gather dispatch — no O(T·E·C) one-hots,
    #            no dispatch matmul flops (see EXPERIMENTS.md §Perf/moe)
    moe_dispatch: str = "einsum"
    # pad the expert dim so it divides the `model` axis and EP sharding
    # engages (e.g. qwen2-moe 60 -> 64); padded experts are router-masked
    moe_pad_experts: int = 0
    # repeat-KV + zero-pad attention heads to this count inside train/prefill
    # attention so the score tensor's head dim divides the `model` axis
    # (llava 56H kv8 -> 64 MHA-view heads). Exact-math: repeat preserves the
    # GQA q->kv mapping; padded q heads are sliced off before the output
    # projection. Decode is untouched (memory-bound, caches keep KH heads).
    tp_pad_heads: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    slstm_every: int = 0  # xlstm: every k-th layer is sLSTM
    attn_every: int = 0  # zamba2: shared attn block after every k SSM layers
    sliding_window: int = 0  # cap attention window (hybrid long-context)
    # --- enc-dec / frontends ---
    encoder_layers: int = 0
    frontend: str = "none"  # "audio" | "vision" (STUB: embeddings provided)
    frontend_tokens: int = 0  # patches/frames prepended to the sequence
    # --- numerics / memory / runtime ---
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    optimizer: str = "adamw"  # "adamw" | "adafactor"
    optimizer_dtype: str = "float32"  # bf16 moments for the giants
    accum_steps: int = 1  # gradient accumulation (microbatching) for train
    act_shard: str = "none"  # "seq": Megatron-SP residual-stream sharding
    # long-context handling: "full" attention or "skip" (arch can't do 500k)
    long_context: str = "skip"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def supports_shape(self, shape: str) -> tuple[bool, str]:
        if shape == "long_500k" and self.long_context == "skip":
            return False, (
                "pure full-attention arch: 500k dense decode is architecturally "
                "meaningless (see DESIGN.md shape skips)"
            )
        return True, ""


def param_count(cfg: ArchConfig) -> int:
    """Approximate parameter count (embeddings + stacks), for roofline."""
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    total = V * D  # embed
    if not cfg.tie_embeddings:
        total += V * D
    attn = D * H * hd + 2 * D * KH * hd + H * hd * D
    if cfg.mlp_act == "silu_gated":
        mlp = 3 * D * F
    else:
        mlp = 2 * D * F
    if cfg.family == "moe":
        moe = cfg.moe_experts * 3 * D * cfg.d_ff + D * cfg.moe_experts
        if cfg.moe_shared_experts:
            moe += 3 * D * cfg.moe_shared_d_ff
        if cfg.moe_dense_residual:
            moe += 3 * D * cfg.d_ff
        total += L * (attn + moe + 2 * D)
    elif cfg.family in ("ssm",):
        din, N = cfg.d_inner, cfg.ssm_state
        ssm = D * (2 * din + 2 * N + cfg.ssm_heads) + din * D + 2 * D
        total += L * ssm
    elif cfg.family == "hybrid":
        din, N = cfg.d_inner, cfg.ssm_state
        ssm = D * (2 * din + 2 * N + cfg.ssm_heads) + din * D + 2 * D
        total += L * ssm + (attn + 3 * D * F + 2 * D)  # one shared block
    else:
        total += L * (attn + mlp + 2 * D)
        if cfg.encoder_layers:
            total += cfg.encoder_layers * (attn + mlp + 2 * D)
            total += cfg.n_layers * (attn + 2 * D)  # cross-attention
    return int(total)


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top-k experts only) — for MODEL_FLOPS."""
    if cfg.family != "moe":
        return param_count(cfg)
    D, L = cfg.d_model, cfg.n_layers
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = D * H * hd + 2 * D * KH * hd + H * hd * D
    moe_active = cfg.moe_top_k * 3 * D * cfg.d_ff + D * cfg.moe_experts
    if cfg.moe_shared_experts:
        moe_active += 3 * D * cfg.moe_shared_d_ff
    if cfg.moe_dense_residual:
        moe_active += 3 * D * cfg.d_ff
    total = 2 * cfg.vocab_size * D + L * (attn + moe_active + 2 * D)
    return int(total)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshAxes:
    data: Any = "data"  # str or tuple (("pod","data") when multi-pod)
    model: str = "model"


def fsdp_axes(mesh: jax.sharding.Mesh) -> MeshAxes:
    if "pod" in mesh.axis_names:
        return MeshAxes(data=("pod", "data"), model="model")
    return MeshAxes(data="data", model="model")


# Param-leaf sharding is keyed on the leaf's path suffix. Conventions:
#   *_col : (in, out) column-parallel  -> P(data, model)
#   *_row : (in, out) row-parallel     -> P(model, data)
#   embed : (vocab, d)                 -> P(model, data)
#   *_exp : (E, in, out) expert        -> P(model, data, None)
#   bias_col : (out,) column bias      -> P(model)
#   norm / scalars                     -> replicated
def leaf_spec(path: str, ndim: int, ax: MeshAxes, stacked: bool) -> P:
    pre = (None,) if stacked else ()
    if path.endswith("out_embed"):  # (D, V): vocab over model, D replicated
        return P(None, ax.model)
    if path.endswith("embed"):  # (V, D): vocab over model (shard_map lookup
        return P(ax.model, None)  # needs D replicated)
    if path.endswith("_col"):
        if ndim - len(pre) == 1:  # column bias
            return P(*pre, ax.model)
        return P(*pre, ax.data, ax.model)
    if path.endswith("_row"):
        return P(*pre, ax.model, ax.data)
    if path.endswith("_exp"):  # (E, in, out)
        return P(*pre, ax.model, ax.data, None)
    if path.endswith("_dp"):  # shard first non-stack dim over data only
        return P(*pre, ax.data)
    return P(*pre) if pre else P()


def tree_paths(tree: dict, prefix: str = "") -> dict[str, Any]:
    out = {}
    for k, v in tree.items():
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(tree_paths(v, p))
        else:
            out[p] = v
    return out


def _axis_size(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return int(mesh.shape[ax])


def shardings_for(
    params: dict, mesh: jax.sharding.Mesh, stacked_prefixes: tuple[str, ...] = ("layers", "encoder_layers")
):
    """Mirror the param tree with NamedShardings per the leaf rules.

    Dims that don't divide their assigned mesh axis fall back to replicated
    (jit in_shardings require exact divisibility)."""
    ax = fsdp_axes(mesh)

    def rec(tree, path):
        if isinstance(tree, dict):
            return {k: rec(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        stacked = any(path.startswith(p) or f"/{p}/" in f"/{path}/" for p in stacked_prefixes)
        ndim = len(tree.shape)
        spec = leaf_spec(path.split("/")[-1], ndim, ax, stacked)
        if len(spec) > ndim:
            spec = P(*list(spec)[:ndim])
        fixed = [
            a if a is not None and tree.shape[i] % _axis_size(mesh, a) == 0
            else None
            for i, a in enumerate(spec)
        ]
        return jax.sharding.NamedSharding(mesh, P(*fixed))

    return rec(params, "")


def struct(shape, dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)
