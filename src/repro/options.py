"""Typed option bundles for the session front door and the serving layer.

``connect(...)`` and ``PreparedQuery.serve(...)`` grew one keyword at a time
(``cache_dir``, ``cache_max_bytes``, ``verify``, ``max_latency_ms``,
``max_pending``, ``max_coalesce``, donation knobs) until every call site
carried a different subset of an undocumented sprawl. These dataclasses are
the consolidated, typed surface:

  * :class:`ConnectOptions` — everything a session is opened with beyond the
    tables and statistics themselves;
  * :class:`ServeOptions` — everything a served query's scheduler queue and
    execution path can be tuned with.

Both carry a canonical content fingerprint (:meth:`fingerprint`) so explain
output, logs, and cache keys can name a configuration stably, and both
``describe()`` themselves compactly (only non-default fields) for
``explain()``. The old keyword arguments keep working through shims that
emit :class:`DeprecationWarning` — see ``repro.session.connect`` and
``PreparedQuery.serve``.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Optional, Union


def _deprecated_kwargs(context: str, replacement: str, kwargs: dict) -> None:
    """Warn once per call site about legacy keyword usage."""
    used = sorted(k for k, v in kwargs.items() if v is not None)
    if used:
        warnings.warn(
            f"{context}({', '.join(f'{k}=...' for k in used)}) is deprecated"
            f" — pass {replacement}({', '.join(used)}=...) instead",
            DeprecationWarning,
            stacklevel=3,
        )


@dataclass(frozen=True)
class ConnectOptions:
    """Session-wide configuration for :func:`repro.session.connect`.

    ``optimizer`` sets the session-default
    :class:`~repro.core.optimizer.OptimizerOptions`; ``strategy`` a
    statistics-driven runtime chooser. ``cache_dir``/``cache_max_bytes``
    root and bound the cross-process artifact store, ``verify`` the
    session-wide plan-verification mode, ``partition_cols`` the per-table
    partition columns for the data-induced statistics rule.
    """

    optimizer: Optional[Any] = None          # OptimizerOptions
    strategy: Any = None
    partition_cols: Optional[dict[str, str]] = None
    cache_dir: Optional[str] = None
    cache_max_bytes: Optional[int] = None
    verify: Union[str, bool, None] = None
    # fault-tolerance knobs: a seeded FaultPlan installed process-wide for
    # the session's lifetime (deterministic chaos drills; RAVEN_FAULTS is
    # the env equivalent), and the RollbackPolicy the model registry's
    # rollback guard enforces on live versions after a cutover
    faults: Optional[Any] = None             # repro.exec.faults.FaultPlan
    rollback: Optional[Any] = None           # repro.exec.faults.RollbackPolicy

    @classmethod
    def resolve(
        cls,
        options: Any = None,
        *,
        partition_cols: Optional[dict[str, str]] = None,
        strategy: Any = None,
        cache_dir: Optional[str] = None,
        cache_max_bytes: Optional[int] = None,
        verify: Union[str, bool, None] = None,
        _context: str = "connect",
    ) -> "ConnectOptions":
        """Merge the typed bundle with legacy keywords (shim path).

        ``options`` may be a :class:`ConnectOptions`, a bare
        :class:`~repro.core.optimizer.OptimizerOptions` (accepted directly —
        optimizer configuration is orthogonal, not deprecated), or None.
        Legacy ``cache_dir``/``cache_max_bytes``/``verify`` keywords emit a
        :class:`DeprecationWarning` and are merged in; an explicit keyword
        never silently overrides a conflicting field already set on the
        bundle — that raises, because two different answers for the same
        knob is a caller bug, not a preference.
        """
        from repro.core.optimizer import OptimizerOptions

        if isinstance(options, ConnectOptions):
            base = options
        elif isinstance(options, OptimizerOptions):
            base = cls(optimizer=options)
        elif options is None:
            base = cls()
        else:
            raise TypeError(
                f"options must be ConnectOptions or OptimizerOptions, "
                f"got {type(options).__name__}"
            )
        _deprecated_kwargs(
            _context, "ConnectOptions",
            {"cache_dir": cache_dir, "cache_max_bytes": cache_max_bytes,
             "verify": verify},
        )
        merged = {}
        for name, value in (
            ("partition_cols", partition_cols), ("strategy", strategy),
            ("cache_dir", cache_dir), ("cache_max_bytes", cache_max_bytes),
            ("verify", verify),
        ):
            if value is None:
                continue
            current = getattr(base, name)
            if current is not None and current != value:
                raise ValueError(
                    f"{_context}: {name} given both as a keyword ({value!r}) "
                    f"and on ConnectOptions ({current!r})"
                )
            merged[name] = value
        return dataclasses.replace(base, **merged) if merged else base

    def fingerprint(self) -> str:
        """Canonical content hash of this configuration.

        Content-stable whenever every field is (dataclasses, scalars,
        dicts); a strategy object without canonical content hashes by
        identity, which :meth:`content_stable` reports."""
        from repro.core.fingerprint import fingerprint

        return fingerprint("connect-options", *self._tokens())

    @property
    def content_stable(self) -> bool:
        """True when the fingerprint is valid across processes (no field
        hashed by object identity)."""
        from repro.core.fingerprint import fingerprint

        pins: list = []
        fingerprint("connect-options", *self._tokens(), pins=pins)
        return not pins

    def _tokens(self) -> tuple:
        return (
            self.optimizer, self.strategy, self.partition_cols,
            self.cache_dir, self.cache_max_bytes, self.verify,
            self.faults, self.rollback,
        )

    def describe(self) -> str:
        """Compact non-default-fields rendering for ``explain()``."""
        return _describe(self, "ConnectOptions")


@dataclass(frozen=True)
class ServeOptions:
    """Per-served-query configuration for :meth:`PreparedQuery.serve`.

    ``max_latency_ms`` is the queue's flush deadline (EDF across queries,
    and serving starts the background pump), ``max_pending`` its
    backpressure bound, ``max_coalesce`` the widest row group one dispatch
    may coalesce. ``donate=False`` keeps this query's padded entry buffers
    un-donated even on backends that support aliasing (useful when the
    caller retains references into the submitted arrays).
    """

    max_latency_ms: Optional[float] = None
    max_pending: Optional[int] = None
    max_coalesce: Optional[int] = None
    donate: bool = True
    # fault tolerance: the queue's transient-failure RetryPolicy (None uses
    # the scheduler default) and the consecutive-failure count that trips
    # this query's circuit breaker onto the kernel-free fallback plan
    retry: Optional[Any] = None              # repro.exec.faults.RetryPolicy
    breaker_threshold: Optional[int] = None

    @classmethod
    def resolve(
        cls,
        options: Optional["ServeOptions"] = None,
        *,
        max_latency_ms: Optional[float] = None,
        max_pending: Optional[int] = None,
        max_coalesce: Optional[int] = None,
        _context: str = "serve",
    ) -> "ServeOptions":
        """Merge a typed bundle with legacy keywords (shim path); legacy
        keywords warn, and a keyword conflicting with the bundle raises."""
        if options is not None and not isinstance(options, ServeOptions):
            raise TypeError(
                f"options must be ServeOptions, got {type(options).__name__}"
            )
        base = options or cls()
        _deprecated_kwargs(
            _context, "ServeOptions",
            {"max_latency_ms": max_latency_ms, "max_pending": max_pending,
             "max_coalesce": max_coalesce},
        )
        merged = {}
        for name, value in (
            ("max_latency_ms", max_latency_ms), ("max_pending", max_pending),
            ("max_coalesce", max_coalesce),
        ):
            if value is None:
                continue
            current = getattr(base, name)
            if current is not None and current != value:
                raise ValueError(
                    f"{_context}: {name} given both as a keyword ({value!r}) "
                    f"and on ServeOptions ({current!r})"
                )
            merged[name] = value
        return dataclasses.replace(base, **merged) if merged else base

    def fingerprint(self) -> str:
        """Canonical content hash (all fields are scalars: always stable)."""
        from repro.core.fingerprint import fingerprint

        return fingerprint(
            "serve-options", self.max_latency_ms, self.max_pending,
            self.max_coalesce, self.donate, self.retry,
            self.breaker_threshold,
        )

    def describe(self) -> str:
        """Compact non-default-fields rendering for ``explain()``."""
        return _describe(self, "ServeOptions")


def _describe(opts: Any, label: str) -> str:
    shown = []
    for f in dataclasses.fields(opts):
        v = getattr(opts, f.name)
        if v != f.default:
            shown.append(f"{f.name}={v!r}")
    body = ", ".join(shown) if shown else "defaults"
    return f"{label}({body})  fingerprint={opts.fingerprint()[:16]}…"
