"""Strategy-training corpus: pipelines shaped like the OpenML CC-18 study.

The paper trains its runtime-selection strategies on 138 OpenML pipelines,
measuring each under every transformation and labeling with the fastest
(§5.2). CC-18 is unavailable offline, so we *generate* a corpus matching the
paper's Fig. 1 distributions — inputs (median ≈ 21, heavy tail), categorical
fraction with OHE cardinalities, model mix (≈88% tree-based / 12% linear),
tree counts and depths spanning stumps to deep forests — then measure
best-runtime labels on THIS hardware and OUR backends, which is exactly the
paper's prescription ("users re-train the strategy on their workload and
hardware").
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.stats import pipeline_stats
from repro.core.strategies import TRANSFORMS
from repro.ml import (
    DecisionTreeClassifier,
    GradientBoostingClassifier,
    LogisticRegression,
    RandomForestClassifier,
    fit_pipeline,
)
from repro.ml.pipeline import TrainedPipeline, run_pipeline


@dataclass
class Corpus:
    pipelines: list[TrainedPipeline]
    stats: np.ndarray  # (n, 22)
    runtimes: np.ndarray  # (n, 3) seconds per transform, measured
    labels: np.ndarray  # (n,) argmin over transforms


def _sample_pipeline_spec(rng: np.random.Generator) -> dict:
    """One pipeline spec following Fig. 1's marginals."""
    n_inputs = int(np.clip(rng.lognormal(np.log(21), 0.8), 3, 120))
    frac_cat = rng.uniform(0.0, 0.7)
    n_cat = int(round(n_inputs * frac_cat))
    n_num = max(1, n_inputs - n_cat)
    cards = rng.choice([2, 3, 4, 6, 8, 12, 24, 48], size=n_cat).astype(int)
    model = rng.choice(
        ["dt", "rf", "gb", "lr"], p=[0.3, 0.29, 0.29, 0.12]
    )
    depth = int(np.clip(rng.lognormal(np.log(6), 0.7), 2, 16))
    n_trees = (
        1 if model == "dt"
        else int(np.clip(rng.lognormal(np.log(12), 0.9), 2, 120))
    )
    return {
        "n_num": n_num, "n_cat": n_cat, "cards": cards, "model": model,
        "depth": depth, "n_trees": n_trees,
    }


def _make_estimator(spec: dict, rng):
    m = spec["model"]
    if m == "dt":
        return DecisionTreeClassifier(max_depth=spec["depth"])
    if m == "rf":
        return RandomForestClassifier(
            n_estimators=spec["n_trees"], max_depth=spec["depth"],
            seed=int(rng.integers(1 << 30)),
        )
    if m == "gb":
        return GradientBoostingClassifier(
            n_estimators=spec["n_trees"], max_depth=min(spec["depth"], 8),
            seed=int(rng.integers(1 << 30)),
        )
    return LogisticRegression(alpha=float(rng.choice([0.0, 0.001, 0.01])), n_iter=60)


def _train_one(spec: dict, rng, n_rows: int = 1024) -> TrainedPipeline:
    cols = {f"n{i}": rng.normal(size=n_rows) for i in range(spec["n_num"])}
    cats = {
        f"c{i}": rng.integers(0, c, n_rows)
        for i, c in enumerate(spec["cards"])
    }
    z = sum(
        rng.normal() * v for v in list(cols.values())[:: max(1, spec["n_num"] // 4)]
    )
    y = (z + rng.normal(size=n_rows) > 0).astype(np.int64)
    return fit_pipeline(
        {**cols, **cats}, y, list(cols), list(cats),
        _make_estimator(spec, rng),
        categories={k: np.arange(c) for k, c in
                    zip(cats, spec["cards"])},
    )


def _measure(pipe: TrainedPipeline, n_rows: int, rng, repeats: int = 2) -> np.ndarray:
    """Wall-time per transform on a measurement batch (median of repeats).

    The sql/dnn variants run through the engine's fingerprint-keyed
    compiled-plan cache (the same path serving uses), so re-measuring a
    pipeline reuses the compiled stages — zero re-traces on repeat.
    """
    import jax

    from repro.core.rules.ml_to_sql import MLtoSQLUnsupported, compile_pipeline_to_sql
    from repro.relational.engine import Project, Scan, TensorOp, compile_plan
    from repro.tensor.compile import compile_pipeline_tensor

    batch = {}
    for s in pipe.inputs:
        if s.kind == "numeric":
            batch[s.name] = rng.normal(size=n_rows)
        else:
            batch[s.name] = rng.integers(0, 4, n_rows)

    times = np.full(len(TRANSFORMS), np.inf)

    # none: interpreted runtime
    ts = []
    for _ in range(repeats + 1):
        t0 = time.perf_counter()
        run_pipeline(pipe, batch)
        ts.append(time.perf_counter() - t0)
    times[0] = float(np.median(ts[1:]))

    scan = Scan("batch", list(pipe.input_names()))
    db = {
        "batch": {
            k: jax.numpy.asarray(np.asarray(v, np.float32))
            for k, v in batch.items()
        }
    }

    def timed(plan) -> float:
        compiled = compile_plan(plan)  # cache hit on re-measure: no re-trace
        ts = []
        for _ in range(repeats + 1):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(db).columns)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts[1:]))

    # sql: compiled expressions fused into the engine (one XLA program)
    try:
        comp = compile_pipeline_to_sql(pipe)
        times[1] = timed(Project(scan, [], dict(comp.exprs)))
    except MLtoSQLUnsupported:
        pass

    # dnn: tensor program fused into the engine
    comp = compile_pipeline_tensor(pipe)
    times[2] = timed(
        Project(TensorOp(scan, comp.fn, list(pipe.outputs)), list(pipe.outputs))
    )
    return times


def build_corpus(
    n_pipelines: int = 138, n_rows: int = 20_000, seed: int = 0,
    progress=None,
) -> Corpus:
    rng = np.random.default_rng(seed)
    pipelines, stats, runtimes = [], [], []
    for i in range(n_pipelines):
        spec = _sample_pipeline_spec(rng)
        pipe = _train_one(spec, rng)
        pipelines.append(pipe)
        stats.append(pipeline_stats(pipe))
        runtimes.append(_measure(pipe, n_rows, rng))
        if progress:
            progress(i, n_pipelines, spec)
    stats = np.asarray(stats)
    runtimes = np.asarray(runtimes)
    labels = np.argmin(runtimes, axis=1)
    return Corpus(
        pipelines=pipelines, stats=stats, runtimes=runtimes, labels=labels
    )
