"""Raven's unified IR.

One DAG captures *both* the relational spine of a prediction query (scans,
joins, filters, projections, aggregates) and the ML part — each ``LPredict``
node holds a full :class:`~repro.ml.pipeline.TrainedPipeline` whose internal
featurizer/model nodes are first-class IR citizens the rules rewrite
(the paper bases its IR on ONNX extended with relational operators; we do the
same — ``TrainedPipeline`` is our ONNX analog, and the relational nodes below
extend it).

Statistics (`TableStats`) ride along for the data-induced optimizations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Union

import numpy as np

from repro.core.fingerprint import fingerprint
from repro.ml.pipeline import TrainedPipeline
from repro.relational.expr import Expr


# ---------------------------------------------------------------------------
# Data statistics (paper §4.2)
# ---------------------------------------------------------------------------


@dataclass
class ColumnStats:
    min: float
    max: float
    distinct: Optional[np.ndarray] = None  # small-cardinality domains only

    @staticmethod
    def of(col: np.ndarray, max_distinct: int = 64) -> "ColumnStats":
        u = np.unique(col)
        return ColumnStats(
            min=float(u[0]),
            max=float(u[-1]),
            distinct=u if len(u) <= max_distinct else None,
        )


@dataclass
class PartitionStats:
    """One data partition (paper: user-specified or group-by induced)."""

    key: Any  # partition identity (e.g. partition-column value)
    n_rows: int
    columns: dict[str, ColumnStats]


@dataclass
class TableStats:
    n_rows: int
    columns: dict[str, ColumnStats]
    partition_col: Optional[str] = None
    partitions: list[PartitionStats] = field(default_factory=list)

    @staticmethod
    def of(
        table: dict[str, np.ndarray], partition_col: Optional[str] = None
    ) -> "TableStats":
        cols = {c: ColumnStats.of(v) for c, v in table.items()}
        n = len(next(iter(table.values())))
        parts: list[PartitionStats] = []
        if partition_col is not None:
            for key in np.unique(table[partition_col]):
                mask = table[partition_col] == key
                parts.append(
                    PartitionStats(
                        key=key,
                        n_rows=int(mask.sum()),
                        columns={
                            c: ColumnStats.of(v[mask]) for c, v in table.items()
                        },
                    )
                )
        return TableStats(
            n_rows=n, columns=cols, partition_col=partition_col, partitions=parts
        )


# ---------------------------------------------------------------------------
# Logical operators
# ---------------------------------------------------------------------------


@dataclass
class LScan:
    table: str
    columns: list[str]


@dataclass
class LJoin:
    child: "LogicalPlan"
    dim_table: str
    fact_key: str
    dim_key: str
    dim_columns: list[str]
    fk_integrity: bool = True  # FK joins are non-filtering -> eliminable


@dataclass
class LFilter:
    child: "LogicalPlan"
    expr: Expr


@dataclass
class LProject:
    child: "LogicalPlan"
    keep: list[str]
    exprs: dict[str, Expr] = field(default_factory=dict)


@dataclass
class LPredict:
    """Trained-pipeline invocation. ``output_names`` aliases the pipeline's
    graph outputs as columns (e.g. score -> 'score', label -> 'pred').

    ``transform`` records the physical decision (§5): None until the
    optimizer's strategy sets it to one of {'none','sql','dnn'}.
    ``partitioned`` carries per-partition specialized pipelines from the
    data-induced rule.
    """

    child: "LogicalPlan"
    pipeline: TrainedPipeline
    output_names: list[str]
    transform: Optional[str] = None
    partitioned: Optional[list[tuple[Any, TrainedPipeline]]] = None
    partition_col: Optional[str] = None
    # MLtoSQL only: emit the score in probability space (sigmoid applied)
    # because the score column is visible in the query result; otherwise the
    # faster logit-space emission + filter rewrite is used.
    emit_prob: bool = False


@dataclass
class LAggregate:
    child: "LogicalPlan"
    aggs: list[tuple[str, str, str]]


LogicalPlan = Union[LScan, LJoin, LFilter, LProject, LPredict, LAggregate]


def children(p: LogicalPlan) -> list[LogicalPlan]:
    return [] if isinstance(p, LScan) else [p.child]


def walk(p: LogicalPlan):
    yield p
    for c in children(p):
        yield from walk(c)


def plan_fingerprint(p: LogicalPlan, pins: Optional[list] = None) -> str:
    """Canonical content hash of a logical plan (operators, expressions,
    pipeline weights). Structurally identical plans hash equal."""
    return fingerprint(p, pins=pins)


def plan_params(p: LogicalPlan) -> set[str]:
    """Names of every ``:param`` placeholder the logical plan references."""
    from repro.relational.expr import params_of

    names: set[str] = set()
    for node in walk(p):
        if isinstance(node, LFilter):
            names |= params_of(node.expr)
        elif isinstance(node, LProject):
            for e in node.exprs.values():
                names |= params_of(e)
    return names


def format_logical_plan(p: LogicalPlan, indent: int = 0) -> str:
    """Indented one-node-per-line rendering of a logical plan (EXPLAIN)."""
    from repro.relational.expr import format_expr

    pad = "  " * indent
    if isinstance(p, LScan):
        cols = ", ".join(p.columns)
        line = f"{pad}Scan[{p.table}] cols=({cols})"
        return line
    if isinstance(p, LJoin):
        line = (
            f"{pad}Join[{p.dim_table}] on {p.fact_key}={p.dim_key} "
            f"bring=({', '.join(p.dim_columns)})"
        )
        return line + "\n" + format_logical_plan(p.child, indent + 1)
    if isinstance(p, LFilter):
        line = f"{pad}Filter[{format_expr(p.expr)}]"
        return line + "\n" + format_logical_plan(p.child, indent + 1)
    if isinstance(p, LProject):
        exprs = ", ".join(f"{k}={format_expr(e)}" for k, e in p.exprs.items())
        line = f"{pad}Project[keep=({', '.join(p.keep or [])}) {exprs}]"
        return line + "\n" + format_logical_plan(p.child, indent + 1)
    if isinstance(p, LPredict):
        part = (
            f", partitioned over {p.partition_col} "
            f"({len(p.partitioned)} models)"
            if p.partitioned
            else ""
        )
        line = (
            f"{pad}Predict[{p.pipeline.n_ops()} ops, "
            f"{len(p.pipeline.inputs)} inputs -> "
            f"({', '.join(p.output_names)}); "
            f"runtime={p.transform or 'unassigned'}{part}]"
        )
        return line + "\n" + format_logical_plan(p.child, indent + 1)
    if isinstance(p, LAggregate):
        aggs = ", ".join(f"{n}={op}({c})" for n, op, c in p.aggs)
        line = f"{pad}Aggregate[{aggs}]"
        return line + "\n" + format_logical_plan(p.child, indent + 1)
    raise TypeError(type(p))


@dataclass
class PredictionQuery:
    """The unified IR instance for one prediction query."""

    plan: LogicalPlan
    stats: dict[str, TableStats] = field(default_factory=dict)

    def predict_nodes(self) -> list[LPredict]:
        return [n for n in walk(self.plan) if isinstance(n, LPredict)]

    def params(self) -> set[str]:
        """Names of the query's ``:param`` placeholders."""
        return plan_params(self.plan)

    def fingerprint(self) -> str:
        """Hash of (plan, stats): the optimizer's output is a pure function
        of both, so this keys the serving layer's optimized-plan cache."""
        return fingerprint(self.plan, self.stats)

    def copy(self) -> "PredictionQuery":
        import copy as _copy

        return PredictionQuery(plan=_deep_copy_plan(self.plan), stats=self.stats)


def _deep_copy_plan(p: LogicalPlan) -> LogicalPlan:
    if isinstance(p, LScan):
        return LScan(p.table, list(p.columns))
    if isinstance(p, LJoin):
        return LJoin(
            _deep_copy_plan(p.child), p.dim_table, p.fact_key, p.dim_key,
            list(p.dim_columns), p.fk_integrity,
        )
    if isinstance(p, LFilter):
        return LFilter(_deep_copy_plan(p.child), p.expr)
    if isinstance(p, LProject):
        return LProject(_deep_copy_plan(p.child), list(p.keep), dict(p.exprs))
    if isinstance(p, LPredict):
        return LPredict(
            _deep_copy_plan(p.child),
            p.pipeline.copy(),
            list(p.output_names),
            p.transform,
            [(k, pl.copy()) for k, pl in p.partitioned] if p.partitioned else None,
            p.partition_col,
            p.emit_prob,
        )
    if isinstance(p, LAggregate):
        return LAggregate(_deep_copy_plan(p.child), list(p.aggs))
    raise TypeError(type(p))
