"""Canonical content hashing for plans, pipelines, and statistics.

The serving layer caches optimized plans and compiled stage executables, so it
needs a *stable* identity for a plan: two structurally identical plans must
hash equal, and any change to an operator, expression, model weight, or
statistic must change the hash. This module feeds a canonical byte stream into
sha256:

  * scalars/strings/bytes — tagged by type, so ``1`` ≠ ``1.0`` ≠ ``"1"``;
  * numpy arrays — dtype + shape + raw bytes;
  * dataclasses (plan nodes, ``TableStats``, ``TreeEnsemble``, …) — class
    name + fields in declaration order;
  * ``Expr`` trees — hashed iteratively with per-node digest memoization
    (MLtoSQL emits tens of thousands of nodes; recursion would overflow, and
    shared sub-DAGs would blow up exponentially without the memo);
  * callables and other opaque objects — hashed by ``id()`` and recorded in
    ``pins``. Identity-hashed fingerprints are only valid while the object is
    alive, so any cache keyed on them must keep a strong reference to every
    pinned object (id reuse after GC would otherwise alias two different
    closures to one fingerprint).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import numpy as np


def fingerprint(*objs: Any, pins: list | None = None) -> str:
    """Canonical sha256 hex digest of ``objs``.

    ``pins`` (if given) collects every object that was hashed by identity;
    the caller must keep those alive for as long as the fingerprint is used
    as a cache key.
    """
    h = hashlib.sha256()
    sink = pins if pins is not None else []
    for o in objs:
        _feed(h, o, sink)
    return h.hexdigest()


def node_fingerprint(
    node: Any, *, pins: list | None = None, exclude: tuple[str, ...] = ("child",)
) -> str:
    """Shallow canonical hash of one plan node (child subtrees excluded).

    The StageGraph hashes each stage as a *chain* — ``fp[i] = H(fp[i-1],
    ops[i])`` — so the per-node hash must cover the node's own content
    (expressions, pipeline weights, output names) without re-walking the
    subtree below it; upstream structure is already encoded by the chain.
    This is the prerequisite for per-stage artifact caching: a stage's
    fingerprint identifies "this operator slice of this plan" stably across
    plan objects and processes.
    """
    h = hashlib.sha256()
    sink = pins if pins is not None else []
    if dataclasses.is_dataclass(node) and not isinstance(node, type):
        h.update(b"C" + type(node).__name__.encode() + b"\x00")
        for f in dataclasses.fields(node):
            if f.name in exclude:
                continue
            h.update(b"f" + f.name.encode() + b"\x00")
            _feed(h, getattr(node, f.name), sink)
    else:
        _feed(h, node, sink)
    return h.hexdigest()


def _feed(h, obj: Any, pins: list) -> None:
    # Expr first: it is a dataclass, but deep chains need the iterative path
    from repro.relational.expr import Expr

    if isinstance(obj, Expr):
        h.update(b"E")
        h.update(bytes.fromhex(_expr_digest(obj, pins)))
        return
    if obj is None:
        h.update(b"N")
    elif isinstance(obj, bool):
        h.update(b"B1" if obj else b"B0")
    elif isinstance(obj, (int, np.integer)):
        h.update(b"I" + str(int(obj)).encode())
    elif isinstance(obj, (float, np.floating)):
        h.update(b"F" + np.float64(obj).tobytes())
    elif isinstance(obj, str):
        h.update(b"S" + obj.encode() + b"\x00")
    elif isinstance(obj, bytes):
        h.update(b"Y" + obj + b"\x00")
    elif isinstance(obj, np.ndarray):
        h.update(b"A" + str(obj.dtype).encode() + str(obj.shape).encode())
        h.update(np.ascontiguousarray(obj).tobytes())
    elif isinstance(obj, (list, tuple)):
        h.update(b"L" if isinstance(obj, list) else b"T")
        h.update(str(len(obj)).encode())
        for v in obj:
            _feed(h, v, pins)
    elif isinstance(obj, dict):
        h.update(b"D" + str(len(obj)).encode())
        # primitive keys sort by repr (stable, and preserves the historical
        # byte stream for every existing cache entry); rich keys sort by
        # their own canonical fingerprint — a repr can embed memory
        # addresses (`<Foo object at 0x...>`), which would silently make the
        # key *order* process-dependent even though each key hashes stably
        for k in sorted(obj, key=_dict_key):
            _feed(h, k, pins)
            _feed(h, obj[k], pins)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(b"C" + type(obj).__name__.encode() + b"\x00")
        for f in dataclasses.fields(obj):
            _feed(h, getattr(obj, f.name), pins)
    elif hasattr(obj, "__array__"):  # jax arrays and friends
        _feed(h, np.asarray(obj), pins)
    else:
        # opaque objects carrying a canonical content token (e.g. MLtoDNN
        # TensorOp closures stamped by the tensor compiler) hash by that
        # token: content-stable across processes, nothing to pin
        token = getattr(obj, "__fingerprint_token__", None)
        if isinstance(token, str):
            h.update(b"K" + token.encode() + b"\x00")
            return
        # opaque (callables, foreign objects): identity hash — see module doc
        h.update(b"O" + str(id(obj)).encode())
        pins.append(obj)


_PRIMITIVE_KEYS = (type(None), bool, int, float, str, bytes)


def _dict_key(k: Any):
    if isinstance(k, _PRIMITIVE_KEYS):
        return (0, repr(k))
    return (1, fingerprint(k))


def _expr_digest(expr, pins: list) -> str:
    """Bottom-up digest of an Expr DAG (explicit stack, memoized by id)."""
    from repro.relational.expr import Bin, Case, Col, Const, Param, Un

    memo: dict[int, str] = {}
    stack: list[tuple[Any, bool]] = [(expr, False)]
    while stack:
        node, visited = stack.pop()
        nid = id(node)
        if nid in memo:
            continue
        if isinstance(node, Col):
            memo[nid] = hashlib.sha256(b"Col" + node.name.encode()).hexdigest()
        elif isinstance(node, Param):
            # by *name* only: binding a different value must not change the
            # plan fingerprint (prepared queries re-bind without re-compiling)
            memo[nid] = hashlib.sha256(b"Param" + node.name.encode()).hexdigest()
        elif isinstance(node, Const):
            hh = hashlib.sha256(b"Const")
            _feed(hh, node.value, pins)
            memo[nid] = hh.hexdigest()
        elif visited:
            hh = hashlib.sha256()
            if isinstance(node, Bin):
                hh.update(b"Bin" + node.op.encode())
                hh.update(bytes.fromhex(memo[id(node.a)]))
                hh.update(bytes.fromhex(memo[id(node.b)]))
            elif isinstance(node, Un):
                hh.update(b"Un" + node.op.encode())
                hh.update(bytes.fromhex(memo[id(node.a)]))
            elif isinstance(node, Case):
                hh.update(b"Case")
                hh.update(bytes.fromhex(memo[id(node.cond)]))
                hh.update(bytes.fromhex(memo[id(node.then)]))
                hh.update(bytes.fromhex(memo[id(node.orelse)]))
            else:
                raise TypeError(type(node))
            memo[nid] = hh.hexdigest()
        else:
            stack.append((node, True))
            if isinstance(node, Bin):
                stack.extend([(node.a, False), (node.b, False)])
            elif isinstance(node, Un):
                stack.append((node.a, False))
            elif isinstance(node, Case):
                stack.extend(
                    [(node.cond, False), (node.then, False), (node.orelse, False)]
                )
            else:
                raise TypeError(type(node))
    return memo[id(expr)]
