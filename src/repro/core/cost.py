"""Per-op cost model driving pipeline cut selection.

``split_pipeline`` (ml/pipeline.py) is the candidate *generator*: it computes
the structural prefix/residual/suffix cut — maximal tensor coverage with the
minimal host residual. This module is the *judge*: given that structural cut,
it prices the two plan shapes the verifier's ``residual-minimal`` rule
admits —

  * **split** — ``TensorOp(prefix) → MLUdf(residual) → TensorOp(suffix)``:
    supported ops run at tensor rates, but every value crossing a cut
    becomes a ``__pv_*`` block column materialized across the host boundary,
    and each tensor segment adds dispatch overhead;
  * **monolithic** — one host MLUdf over the whole pipeline: every op at
    host rates, but nothing extra crosses the boundary.

(Any *other* cut — demoting supported ops into the residual — is rejected by
``residual-minimal``, so {structural split, monolithic} is the complete
rule-compatible candidate set; both shapes carry exactly one host boundary,
so cost-based selection can never add one.)

Rates start from hand-seeded defaults and are *calibrated* from the per-stage
dispatch timings the serving layer already collects and ``explain()``
renders (``Stage.calls`` / ``Stage.total_s``): observing a served StageGraph
rescales the per-op ns/row rates so predicted stage time matches measured
stage time. A calibrated model is passed through
``OptimizerOptions.cost_model`` — it is a plain dataclass of floats, so plan
cache keys fold its rates in content-stably.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


# hand-seeded ns/row rates per pipeline-op kind (CPU-interpreter host path
# vs fused XLA tensor path); unknown kinds fall back to the defaults below
_HOST_NS = {
    "scaler": 220.0,
    "one_hot": 420.0,
    "concat": 260.0,
    "linear": 320.0,
    "tree_ensemble": 2400.0,
    "python_udf": 3200.0,
}
_TENSOR_NS = {
    "scaler": 8.0,
    "one_hot": 30.0,
    "concat": 12.0,
    "linear": 35.0,
    "tree_ensemble": 260.0,
}


@dataclass
class CutDecision:
    """Outcome of pricing one pipeline's candidate cuts."""

    choice: str  # "split" | "monolithic"
    split_s: float
    monolithic_s: float
    rows: int

    def note(self) -> str:
        pick = (
            "kept the structural split"
            if self.choice == "split"
            else "collapsed the split to one monolithic host UDF"
        )
        return (
            f"cost-based cut: {pick} "
            f"(est split {1e3 * self.split_s:.2f}ms vs monolithic "
            f"{1e3 * self.monolithic_s:.2f}ms @ {self.rows} rows)"
        )


@dataclass
class CostModel:
    """Per-op-kind per-row rates plus boundary-crossing costs.

    All fields are plain floats/dicts so the model fingerprints content-
    stably into plan-cache keys. ``rows_hint`` is the batch size decisions
    are priced at (per-row rates make the *relative* ranking insensitive to
    it; it matters only against the fixed per-dispatch overheads).
    """

    host_ns: dict[str, float] = field(default_factory=lambda: dict(_HOST_NS))
    tensor_ns: dict[str, float] = field(
        default_factory=lambda: dict(_TENSOR_NS)
    )
    default_host_ns: float = 800.0
    default_tensor_ns: float = 60.0
    # block-column materialization across the host boundary (per crossing
    # column per row: device→host sync + numpy round-trip)
    crossing_ns_per_row: float = 45.0
    # fixed dispatch overhead per extra tensor segment the split introduces
    segment_fixed_us: float = 250.0
    rows_hint: int = 4096
    # EWMA blend for calibration updates
    alpha: float = 0.5

    @classmethod
    def default(cls) -> "CostModel":
        return cls()

    # -- pricing -------------------------------------------------------------

    def op_s(self, kind: str, runtime: str, rows: int) -> float:
        if runtime == "host":
            ns = self.host_ns.get(kind, self.default_host_ns)
        else:
            ns = self.tensor_ns.get(kind, self.default_tensor_ns)
        return ns * rows * 1e-9

    def pipeline_s(self, nodes, runtime: str, rows: int) -> float:
        return sum(self.op_s(n.op, runtime, rows) for n in nodes)

    def choose_cut(self, split, nodes, rows: Optional[int] = None) -> CutDecision:
        """Price the structural ``split`` (a PipelineSplit) of ``nodes``
        against the monolithic host lowering and pick the cheaper."""
        rows = int(rows or self.rows_hint)
        mono = self.pipeline_s(nodes, "host", rows)
        split_s = 0.0
        for n, (_, seg) in zip(nodes, split.placement):
            runtime = "host" if seg == "residual" else "tensor"
            split_s += self.op_s(n.op, runtime, rows)
        n_cross = 0
        n_segments = 0
        for part in (split.prefix, split.suffix):
            if part is not None:
                n_segments += 1
                n_cross += sum(
                    1 for c in part.out_cols if c.startswith("__pv_")
                )
        split_s += n_cross * self.crossing_ns_per_row * rows * 1e-9
        split_s += n_segments * self.segment_fixed_us * 1e-6
        choice = "split" if split_s <= mono else "monolithic"
        return CutDecision(
            choice=choice, split_s=split_s, monolithic_s=mono, rows=rows
        )

    # -- calibration ---------------------------------------------------------

    def observe(self, kinds, runtime: str, rows: int, seconds: float) -> None:
        """Blend measured wall time for one executed op slice into the
        per-kind rates: every involved kind is rescaled toward making the
        predicted slice time match the measurement."""
        if rows <= 0 or seconds <= 0 or not kinds:
            return
        rates = self.host_ns if runtime == "host" else self.tensor_ns
        default = (
            self.default_host_ns if runtime == "host" else self.default_tensor_ns
        )
        predicted = sum(rates.get(k, default) for k in kinds) * rows * 1e-9
        if predicted <= 0:
            return
        factor = seconds / predicted
        for k in set(kinds):
            cur = rates.get(k, default)
            rates[k] = (1.0 - self.alpha) * cur + self.alpha * cur * factor

    def calibrate_from_graph(self, graph, rows: int) -> int:
        """Calibrate from a served StageGraph's dispatch timings — the same
        ``calls``/``total_s`` accounting ``explain()`` renders per stage.
        Host (MLUdf) stages attribute their measured per-call time to their
        pipeline ops at host rates; pure stages containing a TensorOp
        attribute theirs at tensor rates. Returns the number of stages
        observed."""
        n = 0
        for stage in graph.stages:
            if not stage.calls or stage.total_s <= 0:
                continue
            per_call = stage.total_s / stage.calls
            if stage.kind == "host" and stage.udf is not None:
                kinds = [nd.op for nd in stage.udf.pipeline.nodes]
                self.observe(kinds, "host", rows, per_call)
                n += 1
            elif stage.kind == "pure":
                kinds = []
                for op in stage.ops:
                    pipe = getattr(op, "pipeline", None)
                    if pipe is not None:
                        kinds += [nd.op for nd in pipe.nodes]
                if kinds:
                    self.observe(kinds, "tensor", rows, per_call)
                    n += 1
        return n
