"""Pipeline statistics for the data-driven strategies (paper §5.2).

The paper gathers 22 statistics per trained pipeline; we compute the same
families: input/feature counts, featurizer-op counts and OHE output sizes,
tree counts/depths, plus structural sizes that directly predict each
transformation's cost (SQL expression size, GEMM padded dims).
"""
from __future__ import annotations

import numpy as np

from repro.ml.pipeline import TrainedPipeline
from repro.ml.trees import LEAF

STAT_NAMES = [
    "n_inputs",            # 1  inputs to the pipeline
    "n_features",          # 2  inputs to the model (after featurization)
    "n_ops",               # 3  operators in the pipeline
    "n_featurizers",       # 4
    "n_one_hot",           # 5
    "mean_ohe_outputs",    # 6
    "max_ohe_outputs",     # 7
    "n_scalers",           # 8
    "n_models",            # 9
    "is_tree_model",       # 10
    "is_linear_model",     # 11
    "n_trees",             # 12
    "mean_tree_depth",     # 13
    "max_tree_depth",      # 14
    "std_tree_depth",      # 15
    "n_tree_nodes",        # 16
    "n_leaves",            # 17
    "max_internal_per_tree",  # 18
    "n_nonzero_weights",   # 19
    "used_feature_frac",   # 20
    "sql_expr_size_est",   # 21
    "gemm_padded_cost",    # 22
]


def pipeline_stats(pipe: TrainedPipeline) -> np.ndarray:
    n_inputs = len(pipe.inputs)
    ohe_sizes = []
    n_scalers = 0
    n_featurizers = 0
    for n in pipe.nodes:
        if n.op in ("scaler", "normalizer", "label_encode", "one_hot", "concat",
                    "feature_extractor"):
            n_featurizers += 1
        if n.op == "one_hot":
            ohe_sizes.append(len(n.attrs["categories"]))
        if n.op == "scaler":
            n_scalers += 1

    models = pipe.model_nodes()
    is_tree = any(m.op == "tree_ensemble" for m in models)
    is_linear = any(m.op == "linear" for m in models)
    n_features = 0
    n_trees = depths_mean = depths_max = depths_std = 0.0
    n_nodes = n_leaves = max_internal = 0
    nnz = 0
    used_frac = 1.0
    sql_size = 0.0
    gemm_cost = 0.0
    for m in models:
        if m.op == "tree_ensemble":
            ens = m.attrs["ensemble"]
            n_features = max(n_features, ens.n_features)
            n_trees += ens.n_trees
            d = ens.depths().astype(np.float64)
            depths_mean = float(d.mean())
            depths_max = float(d.max())
            depths_std = float(d.std())
            n_nodes += ens.n_nodes
            n_leaves += int((ens.feature == LEAF).sum())
            per_tree = [sl.stop - sl.start for sl in ens.tree_slices()]
            max_internal = max(max_internal, max((n + 1) // 2 for n in per_tree))
            used_frac = len(ens.used_features()) / max(ens.n_features, 1)
            sql_size += 4.0 * ens.n_nodes
            I = L = max(max_internal, 1)
            gemm_cost += ens.n_trees * (ens.n_features * I + I * L)
        else:
            w = np.asarray(m.attrs["weights"])
            n_features = max(n_features, len(w))
            nnz += int(np.sum(w != 0.0))
            used_frac = nnz / max(len(w), 1)
            sql_size += 3.0 * nnz
            # mean tree depth for linear models is 0 (paper footnote 6)

    return np.asarray(
        [
            n_inputs,
            n_features,
            pipe.n_ops(),
            n_featurizers,
            len(ohe_sizes),
            float(np.mean(ohe_sizes)) if ohe_sizes else 0.0,
            float(np.max(ohe_sizes)) if ohe_sizes else 0.0,
            n_scalers,
            len(models),
            float(is_tree),
            float(is_linear),
            n_trees,
            depths_mean,
            depths_max,
            depths_std,
            n_nodes,
            n_leaves,
            max_internal,
            nnz,
            used_frac,
            sql_size,
            gemm_cost,
        ],
        dtype=np.float64,
    )
