"""MLtoDNN (paper §5.1): pipeline → fused tensor program for the DNN runtime.

Thin rule wrapper over :mod:`repro.tensor.compile` (the Hummingbird analog);
coverage is everything the tensor compiler supports — featurizers, linear
models, tree ensembles (GEMM or gather strategy). The LPredict node's
physical lowering becomes a TensorOp whose function is jitted and fused
with the surrounding relational program.

Partial lowering: when a pipeline contains unsupported nodes, the rule no
longer abandons the whole pipeline. :func:`compile_pipeline_to_dnn_partial`
runs the coverage/frontier split (:func:`repro.ml.pipeline.split_pipeline`),
compiles the supported prefix/suffix slices to tensor programs, and leaves
only the minimal residual for the host runtime — the optimizer emits
``TensorOp(prefix) → MLUdf(residual) → TensorOp(suffix)``.
:exc:`MLtoDNNUnsupported` is raised only when nothing at all can be lowered.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.cost import CostModel, CutDecision
from repro.ml.pipeline import PipelineSplit, SplitSegment, TrainedPipeline, select_cut
from repro.tensor.compile import (
    TensorCompilation,
    compile_pipeline_tensor,
    tensor_supported,
)


class MLtoDNNUnsupported(Exception):
    pass


def compile_pipeline_to_dnn(
    pipe: TrainedPipeline, strategy: str = "auto", use_pallas: bool | None = None
) -> TensorCompilation:
    """Whole-pipeline compilation (raises on any unsupported node)."""
    try:
        return compile_pipeline_tensor(pipe, strategy=strategy, use_pallas=use_pallas)
    except (ValueError, KeyError) as e:  # unsupported op kinds
        raise MLtoDNNUnsupported(str(e)) from e


@dataclass
class PartialDNNLowering:
    """Outcome of the pipeline-splitting MLtoDNN lowering.

    One of three shapes: ``full`` set (pipeline fully supported — the
    classic single-TensorOp lowering); a split with a host ``residual``
    and compiled ``prefix``/``suffix`` tensor slices (either may be None
    when its slice is empty); or — when the cost model prices the split's
    boundary crossings above the tensor speedup — neither, with
    ``decision.choice == "monolithic"`` telling the optimizer to emit one
    host MLUdf over the whole pipeline. ``split`` carries the per-node
    placement for the optimizer's report; ``decision`` (None for fully
    supported pipelines) carries the cost comparison.
    """

    split: PipelineSplit
    full: Optional[TensorCompilation] = None
    prefix: Optional[tuple[TensorCompilation, SplitSegment]] = None
    residual: Optional[SplitSegment] = None
    suffix: Optional[tuple[TensorCompilation, SplitSegment]] = None
    decision: Optional[CutDecision] = None


def compile_pipeline_to_dnn_partial(
    pipe: TrainedPipeline,
    strategy: str = "auto",
    use_pallas: bool | None = None,
    rename: Optional[dict[str, str]] = None,
    cost_model: Optional[CostModel] = None,
    rows_hint: Optional[int] = None,
) -> PartialDNNLowering:
    """Split-aware MLtoDNN: lower the maximal supported prefix and suffix,
    keep the minimal residual on host — unless the cost model says the
    split's boundary crossings outweigh the tensor speedup, in which case
    the decision says "monolithic" and nothing is compiled.

    ``rename`` maps pipeline graph outputs to plan column names so segment
    ``out_cols`` land directly in the engine's namespace. ``cost_model``
    defaults to a fresh :meth:`CostModel.default` (deterministic, so plan
    cache keys stay stable); ``rows_hint`` overrides the batch size the
    decision is priced at. Raises :exc:`MLtoDNNUnsupported` when neither a
    prefix nor a suffix can be lowered (the plan falls back to one
    monolithic MLUdf with no decision to make).
    """
    split, decision = select_cut(
        pipe, tensor_supported, rename=rename,
        cost_model=cost_model, rows=rows_hint,
    )
    if split.fully_supported:
        return PartialDNNLowering(
            split=split,
            full=compile_pipeline_to_dnn(
                pipe, strategy=strategy, use_pallas=use_pallas
            ),
        )
    if split.prefix is None and split.suffix is None:
        raise MLtoDNNUnsupported(
            "no supported prefix or suffix to split out: "
            + ", ".join(label for label, _ in split.placement)
        )
    if decision is not None and decision.choice == "monolithic":
        return PartialDNNLowering(split=split, decision=decision)

    def _compile(seg: Optional[SplitSegment]):
        if seg is None:
            return None
        return (
            compile_pipeline_tensor(
                seg.pipeline, strategy=strategy, use_pallas=use_pallas
            ),
            seg,
        )

    return PartialDNNLowering(
        split=split,
        prefix=_compile(split.prefix),
        residual=split.residual,
        suffix=_compile(split.suffix),
        decision=decision,
    )
