"""MLtoDNN (paper §5.1): pipeline → fused tensor program for the DNN runtime.

Thin rule wrapper over :mod:`repro.tensor.compile` (the Hummingbird analog);
coverage is everything the tensor compiler supports — featurizers, linear
models, tree ensembles (GEMM or gather strategy). The LPredict node's
physical lowering becomes a TensorOp whose function is jitted and fused
with the surrounding relational program.
"""
from __future__ import annotations

from repro.ml.pipeline import TrainedPipeline
from repro.tensor.compile import TensorCompilation, compile_pipeline_tensor


class MLtoDNNUnsupported(Exception):
    pass


def compile_pipeline_to_dnn(
    pipe: TrainedPipeline, strategy: str = "auto", use_pallas: bool | None = None
) -> TensorCompilation:
    try:
        return compile_pipeline_tensor(pipe, strategy=strategy, use_pallas=use_pallas)
    except (ValueError, KeyError) as e:  # unsupported op kinds
        raise MLtoDNNUnsupported(str(e)) from e
