"""Interval/constant propagation through trained pipelines + model pruning.

This is the machinery behind BOTH paper §4.1 (predicate-based model pruning —
constraints come from WHERE clauses) and §4.2 (data-induced — constraints come
from min/max column statistics, globally or per partition). A constraint set
maps raw input columns to closed intervals ``[lo, hi]`` (equality = point
interval); propagation pushes them through featurizers to per-feature
intervals at each model node, which then prune trees / fold linear terms.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.ml.pipeline import TrainedPipeline
from repro.ml.trees import LEAF, TreeEnsemble
from repro.relational.expr import Bin, Col, Const, Expr

INF = math.inf


@dataclass(frozen=True)
class Interval:
    lo: float = -INF
    hi: float = INF

    @property
    def is_const(self) -> bool:
        return self.lo == self.hi and math.isfinite(self.lo)

    def intersect(self, o: "Interval") -> "Interval":
        return Interval(max(self.lo, o.lo), min(self.hi, o.hi))


TOP = Interval()


# ---------------------------------------------------------------------------
# Predicate extraction (WHERE conjunctions -> per-column intervals)
# ---------------------------------------------------------------------------

_FLIP = {"le": "ge", "lt": "gt", "ge": "le", "gt": "lt", "eq": "eq"}


def extract_constraints(expr: Expr) -> Optional[dict[str, Interval]]:
    """Extract per-column intervals from a conjunctive predicate.

    Returns None if the expression is not a conjunction of simple
    column-vs-literal comparisons (in which case no pruning is attempted —
    the optimization is conservative, as in the paper).
    """
    out: dict[str, Interval] = {}

    def visit(e: Expr) -> bool:
        if isinstance(e, Bin) and e.op == "and":
            return visit(e.a) and visit(e.b)
        if isinstance(e, Bin) and e.op == "ne":
            # an inequation carries no interval information, but it must not
            # reject the whole conjunction (sound: pruning with a superset
            # of the satisfying rows)
            return True
        if isinstance(e, Bin) and e.op in ("le", "lt", "ge", "gt", "eq"):
            from repro.relational.expr import Param

            a, b, op = e.a, e.b, e.op
            if isinstance(a, Const) and isinstance(b, Col):
                a, b, op = b, a, _FLIP[op]
            if isinstance(a, Col) and isinstance(b, Param):
                return True  # value unknown until bind time: no interval info
            if not (isinstance(a, Col) and isinstance(b, Const)):
                return False
            v = float(b.value)
            iv = {
                "eq": Interval(v, v),
                "le": Interval(-INF, v),
                "lt": Interval(-INF, v),  # closed approx: sound for pruning
                "ge": Interval(v, INF),
                "gt": Interval(v, INF),
            }[op]
            out[a.name] = out.get(a.name, TOP).intersect(iv)
            return True
        return False

    return out if visit(expr) else None


def predicate_columns(expr: Expr) -> set[str]:
    from repro.relational.expr import columns_of

    return columns_of(expr)


# ---------------------------------------------------------------------------
# Interval propagation through the pipeline graph
# ---------------------------------------------------------------------------


def propagate_intervals(
    pipeline: TrainedPipeline, constraints: dict[str, Interval]
) -> dict[str, list[Interval]]:
    """Per-value per-column intervals at every pipeline value."""
    vals: dict[str, list[Interval]] = {}
    for spec in pipeline.inputs:
        vals[spec.name] = [constraints.get(spec.name, TOP)]
    for node in pipeline.nodes:
        a = node.attrs
        if node.op == "concat":
            vals[node.outputs[0]] = [
                iv for i in node.inputs for iv in vals[i]
            ]
        elif node.op == "scaler":
            ivs = vals[node.inputs[0]]
            out = []
            for k, iv in enumerate(ivs):
                off, sc = float(a["offset"][k]), float(a["scale"][k])
                lo, hi = (iv.lo - off) * sc, (iv.hi - off) * sc
                if sc < 0:
                    lo, hi = hi, lo
                out.append(Interval(lo, hi))
            vals[node.outputs[0]] = out
        elif node.op == "one_hot":
            iv = vals[node.inputs[0]][0]
            cats = a["categories"]
            out = []
            for c in cats:
                c = float(c)
                if iv.is_const:
                    out.append(Interval(1.0, 1.0) if c == iv.lo else Interval(0.0, 0.0))
                elif c < iv.lo or c > iv.hi:
                    out.append(Interval(0.0, 0.0))
                else:
                    out.append(Interval(0.0, 1.0))
            vals[node.outputs[0]] = out
        elif node.op == "label_encode":
            iv = vals[node.inputs[0]][0]
            classes = a["classes"]
            if iv.is_const:
                code = float(np.searchsorted(classes, iv.lo))
                vals[node.outputs[0]] = [Interval(code, code)]
            else:
                vals[node.outputs[0]] = [Interval(0.0, float(len(classes) - 1))]
        elif node.op == "feature_extractor":
            ivs = vals[node.inputs[0]]
            vals[node.outputs[0]] = [ivs[int(i)] for i in a["indices"]]
        elif node.op == "constant":
            v = np.atleast_1d(np.asarray(a["value"], dtype=np.float64))
            vals[node.outputs[0]] = [Interval(float(x), float(x)) for x in v]
        elif node.op == "normalizer":
            ivs = vals[node.inputs[0]]
            # row-norm mixes columns; only fully-constant rows stay constant
            if all(iv.is_const for iv in ivs):
                from repro.ml.featurizers import Normalizer

                row = np.asarray([iv.lo for iv in ivs])[None, :]
                out_row = Normalizer(a["norm"]).transform(row)[0]
                vals[node.outputs[0]] = [Interval(float(x), float(x)) for x in out_row]
            else:
                vals[node.outputs[0]] = [TOP] * len(ivs)
        elif node.op in ("tree_ensemble", "linear"):
            for o in node.outputs:
                vals[o] = [TOP]
        elif node.op == "python_udf":
            # opaque host callable: same column count as its input, but
            # nothing can be said about the values — every column goes TOP
            vals[node.outputs[0]] = [TOP] * len(vals[node.inputs[0]])
        else:
            raise ValueError(node.op)
    return vals


# ---------------------------------------------------------------------------
# Model pruning given per-feature intervals
# ---------------------------------------------------------------------------


def prune_tree_ensemble(
    ens: TreeEnsemble, feature_intervals: list[Interval]
) -> TreeEnsemble:
    """Rebuild the ensemble resolving statically-decidable splits.

    Split on feature f with threshold t: interval [lo,hi] ⇒
      hi <= t → always-left, lo > t → always-right.
    """
    feature, threshold, left, right, leaf_value = [], [], [], [], []

    def emit() -> int:
        feature.append(LEAF)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        leaf_value.append(0.0)
        return len(feature) - 1

    def rebuild(old: int) -> int:
        # iterative rebuild to dodge recursion limits on deep trees
        # returns new node id for old subtree root
        stack = [("visit", old, None, None)]
        result: dict[int, int] = {}
        while stack:
            action, node, parent_new, side = stack.pop()
            if action == "visit":
                f = int(ens.feature[node])
                if f == LEAF:
                    nid = emit()
                    leaf_value[nid] = float(ens.leaf_value[node])
                    result[node] = nid
                    _link(parent_new, side, nid)
                    continue
                iv = feature_intervals[f] if f < len(feature_intervals) else TOP
                t = float(ens.threshold[node])
                if iv.hi <= t:  # always left
                    stack.append(("visit", int(ens.left[node]), parent_new, side))
                elif iv.lo > t:  # always right
                    stack.append(("visit", int(ens.right[node]), parent_new, side))
                else:
                    nid = emit()
                    feature[nid] = f
                    threshold[nid] = t
                    result[node] = nid
                    _link(parent_new, side, nid)
                    stack.append(("visit", int(ens.right[node]), nid, "r"))
                    stack.append(("visit", int(ens.left[node]), nid, "l"))
        return result.get(old, len(feature) - 1)

    def _link(parent_new, side, nid):
        if parent_new is None:
            return
        if side == "l":
            left[parent_new] = nid
        else:
            right[parent_new] = nid

    offsets = [0]
    for sl in ens.tree_slices():
        rebuild(sl.start)
        offsets.append(len(feature))

    feat = np.asarray(feature, dtype=np.int64)
    idx = np.arange(len(feat))
    is_leaf = feat == LEAF
    return TreeEnsemble(
        feature=feat,
        threshold=np.asarray(threshold, dtype=np.float64),
        left=np.where(is_leaf, idx, np.asarray(left, dtype=np.int64)),
        right=np.where(is_leaf, idx, np.asarray(right, dtype=np.int64)),
        leaf_value=np.asarray(leaf_value, dtype=np.float64),
        tree_offsets=np.asarray(offsets, dtype=np.int64),
        tree_weight=ens.tree_weight.copy(),
        base_score=ens.base_score,
        post_transform=ens.post_transform,
        n_features=ens.n_features,
    )


def fold_linear(
    weights: np.ndarray, bias: float, feature_intervals: list[Interval]
) -> tuple[np.ndarray, float]:
    """Fold constant features into the bias (weights become exact zeros)."""
    w = weights.copy()
    b = float(bias)
    for k, iv in enumerate(feature_intervals[: len(w)]):
        if iv.is_const and w[k] != 0.0:
            b += w[k] * iv.lo
            w[k] = 0.0
    return w, b


def prune_leaves_by_output_predicate(
    ens: TreeEnsemble, satisfies
) -> TreeEnsemble:
    """Paper §4.1 output-predicate pruning (single-tree models).

    Subtrees in which NO leaf satisfies the output predicate collapse to one
    canonical failing leaf — rows landing there are filtered out anyway, so
    query results are preserved exactly while the tree shrinks.
    """
    assert ens.n_trees == 1, "output-predicate pruning targets single trees"
    sat = np.zeros(ens.n_nodes, dtype=bool)
    # leaves first, then propagate up (nodes are parent-before-child, so
    # reverse order visits children before parents)
    for i in range(ens.n_nodes - 1, -1, -1):
        if ens.feature[i] == LEAF:
            sat[i] = bool(satisfies(float(ens.leaf_value[i])))
        else:
            sat[i] = sat[ens.left[i]] or sat[ens.right[i]]

    feature, threshold, left, right, leaf_value = [], [], [], [], []

    def emit_leaf(v):
        feature.append(LEAF)
        threshold.append(0.0)
        left.append(len(feature) - 1)
        right.append(len(feature) - 1)
        leaf_value.append(v)
        return len(feature) - 1

    # find a canonical failing leaf value
    fail_vals = [
        float(ens.leaf_value[i])
        for i in range(ens.n_nodes)
        if ens.feature[i] == LEAF and not sat[i]
    ]
    fail_v = fail_vals[0] if fail_vals else float(ens.leaf_value[0])

    def build(old: int) -> int:
        if not sat[old]:
            return emit_leaf(fail_v)
        if ens.feature[old] == LEAF:
            return emit_leaf(float(ens.leaf_value[old]))
        nid = len(feature)
        feature.append(int(ens.feature[old]))
        threshold.append(float(ens.threshold[old]))
        left.append(0)
        right.append(0)
        leaf_value.append(0.0)
        l = build(int(ens.left[old]))
        r = build(int(ens.right[old]))
        left[nid] = l
        right[nid] = r
        return nid

    import sys

    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, ens.n_nodes * 4 + 1000))
    try:
        build(0)
    finally:
        sys.setrecursionlimit(old_limit)
    feat = np.asarray(feature, dtype=np.int64)
    idx = np.arange(len(feat))
    is_leaf = feat == LEAF
    return TreeEnsemble(
        feature=feat,
        threshold=np.asarray(threshold, dtype=np.float64),
        left=np.where(is_leaf, idx, np.asarray(left, dtype=np.int64)),
        right=np.where(is_leaf, idx, np.asarray(right, dtype=np.int64)),
        leaf_value=np.asarray(leaf_value, dtype=np.float64),
        tree_offsets=np.asarray([0, len(feat)], dtype=np.int64),
        tree_weight=ens.tree_weight.copy(),
        base_score=ens.base_score,
        post_transform=ens.post_transform,
        n_features=ens.n_features,
    )
