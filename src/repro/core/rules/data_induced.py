"""Data-induced optimizations (paper §4.2).

Column min/max statistics induce range predicates that feed the same
interval-propagation machinery as §4.1 — if the data contains no instance
with ``age <= 60``, the corresponding subtree is dead and is pruned at
compile time.

With partitioned data, a *specialized model per partition* is compiled using
that partition's statistics; the LPredict node carries the per-partition
pipelines and execution dispatches on the partition column (MLtoSQL composes:
per-partition expressions are guarded by a CASE on the partition column).
"""
from __future__ import annotations

from repro.core.ir import (
    LScan,
    PredictionQuery,
    TableStats,
    walk,
)
from repro.core.rules.propagation import (
    Interval,
    fold_linear,
    propagate_intervals,
    prune_tree_ensemble,
)


def _constraints_from_stats(
    stats: TableStats, input_names: set[str], columns: dict | None = None
) -> dict[str, Interval]:
    src = columns if columns is not None else stats.columns
    return {
        c: Interval(cs.min, cs.max) for c, cs in src.items() if c in input_names
    }


def _specialize(pipeline, constraints: dict[str, Interval]):
    """Prune a pipeline copy under the given interval constraints."""
    pipe = pipeline.copy()
    if not constraints:
        return pipe
    ivs = propagate_intervals(pipe, constraints)
    for node in pipe.model_nodes():
        feat_ivs = ivs[node.inputs[0]]
        if node.op == "tree_ensemble":
            node.attrs["ensemble"] = prune_tree_ensemble(
                node.attrs["ensemble"], feat_ivs
            )
        else:
            w, b = fold_linear(node.attrs["weights"], node.attrs["bias"], feat_ivs)
            node.attrs["weights"] = w
            node.attrs["bias"] = b
    return pipe


def apply_data_induced(query: PredictionQuery) -> PredictionQuery:
    if not query.stats:
        return query
    scans = [n for n in walk(query.plan) if isinstance(n, LScan)]
    for pred in query.predict_nodes():
        input_names = set(pred.pipeline.input_names())
        # global min/max-induced predicates (from every scanned table)
        constraints: dict[str, Interval] = {}
        for scan in scans:
            st = query.stats.get(scan.table)
            if st is None:
                continue
            for c, iv in _constraints_from_stats(st, input_names).items():
                constraints[c] = constraints.get(c, Interval()).intersect(iv)
        pred.pipeline = _specialize(pred.pipeline, constraints)

        # per-partition specialized models (fact-table partitioning)
        for scan in scans:
            st = query.stats.get(scan.table)
            if st is None or not st.partitions:
                continue
            parts = []
            for p in st.partitions:
                pc = dict(constraints)
                for c, iv in _constraints_from_stats(
                    st, input_names, p.columns
                ).items():
                    pc[c] = pc.get(c, Interval()).intersect(iv)
                parts.append((p.key, _specialize(pred.pipeline, pc)))
            pred.partitioned = parts
            pred.partition_col = st.partition_col
            break
    return query
