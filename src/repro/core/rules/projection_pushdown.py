"""Model-projection pushdown (paper §4.1, model-to-data).

Pass 1 — for every model node, detect unused features (trees: features used by
no internal node; linear: zero weights — L1 training and predicate-folding
both produce exact zeros), replace the model with a densified version, and
insert a FeatureExtractor selecting only the used features.

Pass 2 — push each FeatureExtractor towards the pipeline inputs until
fixpoint: through Concat (splitting per input segment; empty segments drop the
whole producer chain), through Scaler (slicing offset/scale), through
OneHotEncoder (slicing categories), composing with FeatureExtractors; stopping
at Normalizers (row-norms mix columns).

Finally the relational side is pruned: scans read only surviving columns,
joins carry only surviving dim columns, and FK joins whose dim columns are all
projected out are *eliminated* (the paper's largest wins on Expedia/Flights).
"""
from __future__ import annotations

import numpy as np

from repro.core.ir import (
    LAggregate,
    LFilter,
    LJoin,
    LPredict,
    LProject,
    LScan,
    LogicalPlan,
    PredictionQuery,
)
from repro.ml.pipeline import PipelineNode, TrainedPipeline
from repro.relational.expr import columns_of


# ---------------------------------------------------------------------------
# Pass 1: densification
# ---------------------------------------------------------------------------


def _densify_models(pipe: TrainedPipeline) -> bool:
    changed = False
    for node in pipe.model_nodes():
        if node.op == "tree_ensemble":
            ens = node.attrs["ensemble"]
            used = ens.used_features()
            if len(used) >= ens.n_features:
                continue
            dense = ens.copy()
            remap = np.searchsorted(used, np.maximum(dense.feature, 0))
            dense.feature = np.where(dense.feature == -1, -1, remap)
            dense.n_features = len(used)
            node.attrs["ensemble"] = dense
            indices = used
        else:  # linear
            w = node.attrs["weights"]
            used = np.flatnonzero(w != 0.0)
            if len(used) >= len(w):
                continue
            node.attrs["weights"] = w[used]
            indices = used
        fe_out = f"{node.outputs[0]}__dense_in"
        pipe.nodes.insert(
            pipe.nodes.index(node),
            PipelineNode(
                "feature_extractor",
                [node.inputs[0]],
                [fe_out],
                {"indices": np.asarray(indices, dtype=np.int64)},
            ),
        )
        node.inputs = [fe_out]
        changed = True
    return changed


# ---------------------------------------------------------------------------
# Pass 2: pushdown to fixpoint
# ---------------------------------------------------------------------------


def _value_width(pipe: TrainedPipeline, producer: PipelineNode) -> list[int]:
    """Widths of a concat node's inputs (needed to split FE indices)."""
    widths = []
    for i in producer.inputs:
        p = pipe.producer_of(i)
        if p is None:  # graph input: single column
            widths.append(1)
        elif p.op == "one_hot":
            widths.append(len(p.attrs["categories"]))
        elif p.op == "scaler":
            widths.append(len(p.attrs["offset"]))
        elif p.op == "constant":
            widths.append(np.atleast_1d(np.asarray(p.attrs["value"])).shape[-1])
        elif p.op == "feature_extractor":
            widths.append(len(p.attrs["indices"]))
        elif p.op == "concat":
            widths.append(sum(_value_width(pipe, p)))
        elif p.op in ("normalizer", "label_encode"):
            q = pipe.producer_of(p.inputs[0])
            widths.append(
                1 if q is None else _value_width(pipe, q)[0]
                if q.op == "concat" else 1
            )
        else:
            raise ValueError(p.op)
    return widths


def _push_one(pipe: TrainedPipeline, fe: PipelineNode) -> bool:
    """Try to push one FeatureExtractor below its producer. True if changed."""
    src = fe.inputs[0]
    producer = pipe.producer_of(src)
    if producer is None:
        # graph input (single column)
        if len(fe.attrs["indices"]) == 0:
            return False  # handled by dead-input pruning
        if len(fe.attrs["indices"]) == 1 and int(fe.attrs["indices"][0]) == 0:
            _replace_value(pipe, fe.outputs[0], src)
            pipe.nodes.remove(fe)
            return True
        return False
    if len(pipe.consumers_of(src)) > 1:
        return False  # conservative: only sole-consumer pushes

    idx = np.asarray(fe.attrs["indices"], dtype=np.int64)

    if producer.op == "feature_extractor":
        producer.attrs = dict(producer.attrs)
        producer.attrs["indices"] = np.asarray(producer.attrs["indices"])[idx]
        _replace_value(pipe, fe.outputs[0], producer.outputs[0])
        pipe.nodes.remove(fe)
        return True

    if producer.op == "scaler":
        new_in = f"{producer.outputs[0]}__fe"
        pipe.nodes.insert(
            pipe.nodes.index(producer),
            PipelineNode(
                "feature_extractor", [producer.inputs[0]], [new_in],
                {"indices": idx},
            ),
        )
        producer.inputs = [new_in]
        producer.attrs = {
            "offset": np.asarray(producer.attrs["offset"])[idx],
            "scale": np.asarray(producer.attrs["scale"])[idx],
        }
        _replace_value(pipe, fe.outputs[0], producer.outputs[0])
        pipe.nodes.remove(fe)
        return True

    if producer.op == "one_hot":
        producer.attrs = {
            "categories": np.asarray(producer.attrs["categories"])[idx]
        }
        _replace_value(pipe, fe.outputs[0], producer.outputs[0])
        pipe.nodes.remove(fe)
        return True

    if producer.op == "constant":
        v = np.atleast_1d(np.asarray(producer.attrs["value"]))
        producer.attrs = {"value": v[idx]}
        _replace_value(pipe, fe.outputs[0], producer.outputs[0])
        pipe.nodes.remove(fe)
        return True

    if producer.op == "concat":
        widths = _value_width(pipe, producer)
        bounds = np.cumsum([0] + widths)
        new_inputs = []
        pos = pipe.nodes.index(producer)
        for k, inp in enumerate(producer.inputs):
            lo, hi = bounds[k], bounds[k + 1]
            sub = idx[(idx >= lo) & (idx < hi)] - lo
            if len(sub) == 0:
                continue  # segment entirely unused -> input dropped
            if len(sub) == widths[k] and np.array_equal(sub, np.arange(widths[k])):
                new_inputs.append(inp)  # full passthrough
            else:
                sub_name = f"{inp}__fe{k}"
                pipe.nodes.insert(
                    pos,
                    PipelineNode(
                        "feature_extractor", [inp], [sub_name],
                        {"indices": sub},
                    ),
                )
                pos += 1
                new_inputs.append(sub_name)
        producer.inputs = new_inputs
        _replace_value(pipe, fe.outputs[0], producer.outputs[0])
        pipe.nodes.remove(fe)
        return True

    return False  # normalizer / label_encode / models: not pushable


def _replace_value(pipe: TrainedPipeline, old: str, new: str) -> None:
    for n in pipe.nodes:
        n.inputs = [new if i == old else i for i in n.inputs]
    pipe.outputs = [new if o == old else o for o in pipe.outputs]


def apply_projection_pushdown(query: PredictionQuery) -> PredictionQuery:
    for pred in query.predict_nodes():
        pipe = pred.pipeline
        _densify_models(pipe)
        changed = True
        while changed:
            changed = False
            for node in list(pipe.nodes):
                if node.op == "feature_extractor" and node in pipe.nodes:
                    if _push_one(pipe, node):
                        changed = True
        pipe.prune_dead()
        pipe.toposort()
    prune_relational_columns(query)
    return query


# ---------------------------------------------------------------------------
# Relational-side pruning + join elimination
# ---------------------------------------------------------------------------


def prune_relational_columns(
    query: PredictionQuery, eliminate_joins: bool = True
) -> None:
    """Column pruning to the scans. ``eliminate_joins=False`` gives the
    vanilla-engine behaviour (Spark prunes columns but keeps FK joins — join
    elimination needs Raven's FK-integrity knowledge), used for the no-opt
    baseline."""
    query.plan = _prune(query.plan, set(), eliminate_joins)


def _prune(
    plan: LogicalPlan, required: set[str], eliminate_joins: bool = True
) -> LogicalPlan:
    if isinstance(plan, LAggregate):
        need = set(required) | {c for _, _, c in plan.aggs}
        plan.child = _prune(plan.child, need, eliminate_joins)
        return plan
    if isinstance(plan, LProject):
        plan.keep = [c for c in plan.keep if not required or c in required]
        need = set(plan.keep)
        for e in plan.exprs.values():
            need |= columns_of(e)
        plan.child = _prune(plan.child, need, eliminate_joins)
        return plan
    if isinstance(plan, LFilter):
        plan.child = _prune(plan.child, set(required) | columns_of(plan.expr), eliminate_joins)
        return plan
    if isinstance(plan, LPredict):
        need = (set(required) - set(plan.output_names)) | set(
            plan.pipeline.input_names()
        )
        if plan.partition_col:
            need.add(plan.partition_col)
        plan.child = _prune(plan.child, need, eliminate_joins)
        return plan
    if isinstance(plan, LJoin):
        dim_needed = [c for c in plan.dim_columns if c in required]
        if not dim_needed and plan.fk_integrity and eliminate_joins:
            return _prune(plan.child, set(required), eliminate_joins)  # join eliminated
        plan.dim_columns = dim_needed
        fact_need = (set(required) - set(dim_needed)) | {plan.fact_key}
        plan.child = _prune(plan.child, fact_need, eliminate_joins)
        return plan
    if isinstance(plan, LScan):
        cols = [c for c in plan.columns if c in required]
        if not cols:  # keep one column so row count survives
            cols = plan.columns[:1]
        plan.columns = cols
        return plan
    raise TypeError(type(plan))
