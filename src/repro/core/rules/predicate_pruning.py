"""Predicate-based model pruning (paper §4.1, data-to-model).

Step 1 — collect the model's inputs that participate in WHERE predicates
*below* the predict node; equality-constrained inputs are replaced by constant
nodes inside the pipeline (the column then no longer needs to reach the model
— projection pushdown will later remove it from scans/joins entirely).

Step 2 — push the equality/range information through featurizers via interval
propagation and prune each tree-based model / fold each linear model.

Also handles predicates on pipeline *outputs* (filters above the predict
node): for single-tree models, subtrees with no satisfying leaf collapse.
"""
from __future__ import annotations


import numpy as np

from repro.core.ir import (
    LFilter,
    LPredict,
    LogicalPlan,
    PredictionQuery,
    children,
    walk,
)
from repro.core.rules.propagation import (
    Interval,
    extract_constraints,
    fold_linear,
    propagate_intervals,
    prune_leaves_by_output_predicate,
    prune_tree_ensemble,
)
from repro.ml.pipeline import PipelineNode
from repro.relational.expr import Bin, Col, Const, Expr


def _filters_below(plan: LogicalPlan, target: LPredict) -> list[Expr]:
    """Filter expressions on the path below ``target``."""
    out = []
    for node in walk(target.child):
        if isinstance(node, LFilter):
            out.append(node.expr)
    return out


def _filters_above(plan: LogicalPlan, target: LPredict) -> list[LFilter]:
    """Filter nodes between the root and ``target`` (exclusive)."""
    out = []

    def descend(p: LogicalPlan) -> bool:
        if p is target:
            return True
        found = any(descend(c) for c in children(p))
        if found and isinstance(p, LFilter):
            out.append(p)
        return found

    descend(plan)
    return out


def apply_predicate_pruning(query: PredictionQuery) -> PredictionQuery:
    for pred in query.predict_nodes():
        pipe = pred.pipeline
        constraints: dict[str, Interval] = {}
        for expr in _filters_below(query.plan, pred):
            c = extract_constraints(expr)
            if c:
                for col, iv in c.items():
                    constraints[col] = constraints.get(col, Interval()).intersect(iv)
        input_names = set(pipe.input_names())
        relevant = {k: v for k, v in constraints.items() if k in input_names}

        # --- step 1: equality predicates -> constant nodes -----------------
        for col, iv in relevant.items():
            if iv.is_const:
                pipe.inputs = [s for s in pipe.inputs if s.name != col]
                pipe.nodes.insert(
                    0,
                    PipelineNode(
                        "constant", [], [col], {"value": np.asarray([iv.lo])}
                    ),
                )

        # --- step 2: interval propagation + model pruning ------------------
        if relevant:
            ivs = propagate_intervals(pipe, relevant)
            for node in pipe.model_nodes():
                feat_ivs = ivs[node.inputs[0]]
                if node.op == "tree_ensemble":
                    node.attrs["ensemble"] = prune_tree_ensemble(
                        node.attrs["ensemble"], feat_ivs
                    )
                elif node.op == "linear":
                    w, b = fold_linear(
                        node.attrs["weights"], node.attrs["bias"], feat_ivs
                    )
                    node.attrs["weights"] = w
                    node.attrs["bias"] = b

        # --- output predicates (paper: leaf-level pruning) ------------------
        for f in _filters_above(query.plan, pred):
            sat = _satisfier(f.expr, pred)
            if sat is None:
                continue
            for node in pipe.model_nodes():
                if node.op == "tree_ensemble" and node.attrs["ensemble"].n_trees == 1:
                    node.attrs["ensemble"] = prune_leaves_by_output_predicate(
                        node.attrs["ensemble"], sat
                    )
        pipe.toposort()
    return query


def _satisfier(expr: Expr, pred: LPredict):
    """Build leaf-value -> bool for simple output predicates.

    Supports ``<label_col> = k`` and ``<score_col> {>=,>,<=,<} c`` on a
    tree model whose score is the leaf value (post_transform handled).
    """
    if not (isinstance(expr, Bin) and isinstance(expr.a, Col) and isinstance(expr.b, Const)):
        return None
    col, op, v = expr.a.name, expr.op, float(expr.b.value)
    outs = pred.output_names
    model = pred.pipeline.model_nodes()
    if not model:
        return None
    node = model[0]
    post = (
        node.attrs["ensemble"].post_transform
        if node.op == "tree_ensemble"
        else node.attrs.get("post", "none")
    )
    thr = node.attrs.get("decision_threshold", 0.5)

    def transform(leaf):
        return 1.0 / (1.0 + np.exp(-leaf)) if post == "logistic" else leaf

    if len(outs) > 1 and col == outs[1] and op == "eq":  # label predicate
        want = int(v)
        return lambda leaf: int(transform(leaf) >= thr) == want
    if col == outs[0]:  # score predicate
        return {
            "ge": lambda leaf: transform(leaf) >= v,
            "gt": lambda leaf: transform(leaf) > v,
            "le": lambda leaf: transform(leaf) <= v,
            "lt": lambda leaf: transform(leaf) < v,
        }.get(op)
    return None
