"""MLtoSQL (paper §5.1): compile a trained pipeline to relational expressions.

Linear models and scalers become mul/add/sub chains; trees and encoders
become (nested) CASE expressions — exactly the paper's construction. The
resulting expressions replace the LPredict node with a Project, so the whole
query fuses into a single XLA program in the data engine (no ML-runtime
invocation, no data conversion — the two costs the optimization removes).

Whole-pipeline-or-fail semantics, as in the paper: raises
:class:`MLtoSQLUnsupported` if any op lacks a SQL translation (e.g. l2
normalizer — needs sqrt), and the optimizer falls back to the ML runtime.

Classification scores: a logistic post-transform is monotone, so the label
compare moves to logit space (``z >= 0`` ⟺ ``sigmoid(z) >= 0.5``) and the
emitted score column is in *logit* space (``score_space`` records this).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.ml.pipeline import TrainedPipeline
from repro.ml.trees import LEAF, TreeEnsemble
from repro.relational.expr import Bin, Case, Col, Const, Expr


class MLtoSQLUnsupported(Exception):
    pass


@dataclass
class SQLCompilation:
    exprs: dict[str, Expr]  # graph output name -> expression
    score_space: str  # "prob" | "logit"
    size: int  # total expression node count


def _tree_to_expr(ens: TreeEnsemble, tree: int, feats: list[Expr]) -> Expr:
    """Nested-CASE for one tree, built leaves-up (no recursion)."""
    sl = ens.tree_slices()[tree]
    w = float(ens.tree_weight[tree])
    exprs: dict[int, Expr] = {}
    for i in range(sl.stop - 1, sl.start - 1, -1):
        if ens.feature[i] == LEAF:
            exprs[i] = Const(w * float(ens.leaf_value[i]))
        else:
            f = int(ens.feature[i])
            exprs[i] = Case(
                Bin("le", feats[f], Const(float(ens.threshold[i]))),
                exprs[int(ens.left[i])],
                exprs[int(ens.right[i])],
            )
    return exprs[sl.start]


def _sum(parts: list[Expr]) -> Expr:
    if not parts:
        return Const(0.0)
    e = parts[0]
    for p in parts[1:]:
        e = Bin("add", e, p)
    return e


def compile_pipeline_to_sql(pipe: TrainedPipeline) -> SQLCompilation:
    from repro.relational.expr import expr_size

    vals: dict[str, list[Expr]] = {}
    for spec in pipe.inputs:
        vals[spec.name] = [Col(spec.name)]

    score_space = "prob"
    out_exprs: dict[str, Expr] = {}

    for node in pipe.nodes:
        a = node.attrs
        if node.op == "concat":
            vals[node.outputs[0]] = [e for i in node.inputs for e in vals[i]]
        elif node.op == "scaler":
            src = vals[node.inputs[0]]
            vals[node.outputs[0]] = [
                Bin(
                    "mul",
                    Bin("sub", e, Const(float(a["offset"][k]))),
                    Const(float(a["scale"][k])),
                )
                for k, e in enumerate(src)
            ]
        elif node.op == "one_hot":
            e = vals[node.inputs[0]][0]
            vals[node.outputs[0]] = [
                Case(Bin("eq", e, Const(c)), Const(1.0), Const(0.0))
                for c in np.asarray(a["categories"]).tolist()
            ]
        elif node.op == "label_encode":
            e = vals[node.inputs[0]][0]
            expr: Expr = Const(float(len(a["classes"]) - 1))
            for code, cls in reversed(list(enumerate(np.asarray(a["classes"]).tolist()))):
                expr = Case(Bin("eq", e, Const(cls)), Const(float(code)), expr)
            vals[node.outputs[0]] = [expr]
        elif node.op == "feature_extractor":
            src = vals[node.inputs[0]]
            vals[node.outputs[0]] = [src[int(i)] for i in a["indices"]]
        elif node.op == "constant":
            v = np.atleast_1d(np.asarray(a["value"], dtype=np.float64))
            vals[node.outputs[0]] = [Const(float(x)) for x in v]
        elif node.op == "normalizer":
            if a["norm"] == "l2":
                raise MLtoSQLUnsupported("l2 normalizer needs sqrt")
            src = vals[node.inputs[0]]
            absd = [Bin("max", e, Bin("sub", Const(0.0), e)) for e in src]
            denom = _sum(absd) if a["norm"] == "l1" else _max_chain(absd)
            vals[node.outputs[0]] = [Bin("div", e, denom) for e in src]
        elif node.op == "tree_ensemble":
            ens: TreeEnsemble = a["ensemble"]
            feats = vals[node.inputs[0]]
            score = _sum(
                [Const(ens.base_score)]
                + [_tree_to_expr(ens, t, feats) for t in range(ens.n_trees)]
            )
            thr = float(a.get("decision_threshold", 0.5))
            if ens.post_transform == "logistic":
                score_space = "logit"
                cut = 0.0 if thr == 0.5 else float(np.log(thr / (1 - thr)))
            else:
                cut = thr
            out_exprs[node.outputs[0]] = score
            if len(node.outputs) > 1:
                out_exprs[node.outputs[1]] = Case(
                    Bin("ge", score, Const(cut)), Const(1), Const(0)
                )
        elif node.op == "linear":
            feats = vals[node.inputs[0]]
            w = np.asarray(a["weights"], dtype=np.float64)
            terms = [
                Bin("mul", feats[k], Const(float(w[k])))
                for k in range(len(w))
                if w[k] != 0.0  # zero weights never touch the data
            ]
            score = _sum(terms + [Const(float(a["bias"]))])
            thr = float(a.get("decision_threshold", 0.5))
            if a.get("post", "none") == "logistic":
                score_space = "logit"
                cut = 0.0 if thr == 0.5 else float(np.log(thr / (1 - thr)))
            else:
                cut = thr
            out_exprs[node.outputs[0]] = score
            if len(node.outputs) > 1:
                out_exprs[node.outputs[1]] = Case(
                    Bin("ge", score, Const(cut)), Const(1), Const(0)
                )
        else:
            raise MLtoSQLUnsupported(node.op)

    missing = [o for o in pipe.outputs if o not in out_exprs]
    if missing:
        raise MLtoSQLUnsupported(f"outputs {missing} not produced by a model op")
    exprs = {o: out_exprs[o] for o in pipe.outputs}
    size = sum(expr_size(e) for e in exprs.values())
    return SQLCompilation(exprs=exprs, score_space=score_space, size=size)


def _max_chain(parts: list[Expr]) -> Expr:
    e = parts[0]
    for p in parts[1:]:
        e = Bin("max", e, p)
    return e
