"""The Raven optimizer: logical rules in strict order, then data-driven
logical-to-physical runtime selection, then lowering to the physical plan.

Order (paper §5.2 closing summary):
  1. predicate-based model pruning   (enables more projection pushdown)
  2. data-induced optimizations      (same machinery, stats-sourced)
  3. model-projection pushdown       (consumes sparsity created by 1 & 2)
  4. runtime selection per predict node via a strategy (or forced option)
  5. lowering: LPredict → Project(exprs) | TensorOp | MLUdf

MLtoSQL / MLtoDNN failures fall back to the ML runtime ('none'), matching
the paper's whole-pipeline-or-fail semantics.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional


from repro.core.ir import (
    LAggregate,
    LFilter,
    LJoin,
    LPredict,
    LProject,
    LScan,
    LogicalPlan,
    PredictionQuery,
)
from repro.core.cost import CostModel
from repro.core.rules.data_induced import apply_data_induced
from repro.core.rules.ml_to_dnn import (
    MLtoDNNUnsupported,
    compile_pipeline_to_dnn,  # noqa: F401  (public rule API)
    compile_pipeline_to_dnn_partial,
)
from repro.ml.pipeline import _node_label as _pipeline_node_label
from repro.core.rules.ml_to_sql import (
    MLtoSQLUnsupported,
    compile_pipeline_to_sql,
)
from repro.core.rules.predicate_pruning import apply_predicate_pruning
from repro.core.rules.projection_pushdown import apply_projection_pushdown
from repro.core.stats import pipeline_stats
from repro.relational.engine import (
    Aggregate,
    Filter,
    Join,
    MLUdf,
    PhysicalPlan,
    Project,
    Scan,
    TensorOp,
    walk_plan,
)
from repro.core.fingerprint import fingerprint
from repro.relational.expr import (
    Bin,
    Case,
    Col,
    Const,
    Expr,
    Param,
    Un,
    columns_of,
    format_expr,
)


@dataclass
class OptimizerOptions:
    predicate_pruning: bool = True
    projection_pushdown: bool = True
    data_induced: bool = True
    transform: Optional[str] = None  # force {'none','sql','dnn'}; None -> strategy
    tensor_strategy: str = "auto"  # 'auto' | 'gemm' | 'traversal'
    use_pallas: Optional[bool] = None
    udf_batch_size: int = 10_000
    # cost model judging pipeline cuts (split vs monolithic); None means a
    # fresh deterministic CostModel.default() per lowering, so plan-cache
    # fingerprints stay stable across processes. A calibrated model hashes
    # by its rate content and forks the cache only when rates change.
    cost_model: Optional[CostModel] = None
    # plan verification: None defers to $RAVEN_VERIFY (default 'off');
    # 'warn' reports violations, 'strict' raises PlanVerificationError.
    # Excluded from plan-cache fingerprints (see session._optimize) so the
    # mode never forks compiled artifacts.
    verify: Optional[str] = None


@dataclass
class OptimizationReport:
    transforms: dict[int, str] = field(default_factory=dict)
    notes: list[str] = field(default_factory=list)
    # stage-boundary annotation, filled at lowering time: one line per
    # physical stage ("pure: Scan[t]→Project" / "host: MLUdf"), matching the
    # StageGraph the engine will build from the plan
    stages: list[str] = field(default_factory=list)
    # per-node runtime placement, one list per lowered predict node (in
    # lowering order): (pipeline-node label, runtime), where runtime is
    # "tensor" / "host" / "sql", suffixed with the split segment
    # ("tensor/prefix", "host/residual", "tensor/suffix") when the
    # pipeline-splitting MLtoDNN lowering cut the pipeline
    placement: list[list[tuple[str, str]]] = field(default_factory=list)
    # differential-verification trail (one line per checked rewrite phase),
    # filled when the verify mode is 'warn' or 'strict'; rendered by
    # explain()
    verification: list[str] = field(default_factory=list)
    # relational-op runtime placement (Join / Aggregate), filled after
    # lowering: (op label, runtime description). Reflects the process-wide
    # RAVEN_KERNELS mode captured when the stage graph is built.
    relational: list[tuple[str, str]] = field(default_factory=list)


class RavenOptimizer:
    def __init__(self, strategy=None, options: Optional[OptimizerOptions] = None):
        self.strategy = strategy
        self.options = options or OptimizerOptions()

    # -- public API ---------------------------------------------------------

    def optimize(self, query: PredictionQuery) -> tuple[PhysicalPlan, OptimizationReport]:
        opt = self.options
        q = query.copy()
        report = OptimizationReport()

        # differential verification: re-check the plan after every rewrite
        # rule, so a violation names the rule that introduced it
        from repro.analysis.verifier import (
            check_logical,
            enforce,
            resolve_verify_mode,
        )

        verify_mode = resolve_verify_mode(opt.verify)

        def checkpoint(phase: str) -> None:
            if verify_mode == "off":
                return
            report.verification += enforce(
                check_logical(q, where=phase), verify_mode, phase
            )

        checkpoint("input")
        if opt.predicate_pruning:
            apply_predicate_pruning(q)
            checkpoint("after predicate_pruning")
        if opt.data_induced:
            apply_data_induced(q)
            checkpoint("after data_induced")
        if opt.projection_pushdown:
            apply_projection_pushdown(q)
            checkpoint("after projection_pushdown")
        else:
            from repro.core.rules.projection_pushdown import (
                prune_relational_columns,
            )

            # vanilla-engine behaviour: scans don't read columns no operator
            # references, but FK joins survive (join elimination is Raven's)
            prune_relational_columns(q, eliminate_joins=False)
            checkpoint("after column_pruning")

        for i, pred in enumerate(q.predict_nodes()):
            if opt.transform is not None:
                t = opt.transform
            elif self.strategy is not None:
                t = self.strategy.choose(pipeline_stats(pred.pipeline))
            else:
                t = "none"
            pred.transform = t
            report.transforms[i] = t
            if t == "sql" and self._sql_score_space(pred) == "logit":
                score = pred.output_names[0]
                if _score_visible(q.plan, score):
                    # score reaches the query result (or a non-threshold
                    # expression): emit in probability space — exact
                    # semantics, one sigmoid at the top of the expression.
                    pred.emit_prob = True
                else:
                    # score only feeds threshold filters: keep the faster
                    # logit-space emission and move the thresholds instead.
                    rewrite_score_filters(q.plan, score, "logit")
        checkpoint("after transform_selection")

        plan = self._lower(q.plan, report)
        from repro.exec.stages import describe_segments

        if verify_mode != "off":
            from repro.analysis.verifier import check_graph
            from repro.exec.stages import build_stage_graph

            report.verification += enforce(
                check_graph(build_stage_graph(plan)), verify_mode,
                "after lowering",
            )
        report.stages = describe_segments(plan)
        from repro.kernels.ops import kernels_enabled

        kern = kernels_enabled()
        for node in walk_plan(plan):
            if isinstance(node, Join):
                report.relational.append((
                    f"Join[{node.dim_table}] on "
                    f"{node.fact_key}={node.dim_key}",
                    "tensor/kernel: gather_join, upstream filter mask fused"
                    " (jnp fallback when shapes don't qualify)"
                    if kern else
                    "tensor/jnp: argsort+searchsorted gather",
                ))
            elif isinstance(node, Aggregate):
                aggs = ", ".join(f"{n}={op}({c})" for n, op, c in node.aggs)
                report.relational.append((
                    f"Aggregate[{aggs}]",
                    "tensor/kernel: segment_agg, filter folded in as mask"
                    if kern else
                    "tensor/jnp: masked segment_sum/min/max",
                ))
        n_host = sum(1 for s in report.stages if s.startswith("host"))
        if n_host:
            report.notes.append(
                f"lowered to {len(report.stages)} stages "
                f"({n_host} host boundary(ies) — bucketed per stage when served)"
            )
        return plan, report

    @staticmethod
    def _sql_score_space(pred: LPredict) -> str:
        for m in pred.pipeline.model_nodes():
            post = (
                m.attrs["ensemble"].post_transform
                if m.op == "tree_ensemble"
                else m.attrs.get("post", "none")
            )
            if post == "logistic":
                return "logit"
        return "prob"

    # -- lowering -----------------------------------------------------------

    def _lower(self, p: LogicalPlan, report: OptimizationReport) -> PhysicalPlan:
        opt = self.options
        if isinstance(p, LScan):
            return Scan(p.table, list(p.columns))
        if isinstance(p, LJoin):
            return Join(
                self._lower(p.child, report), p.dim_table, p.fact_key,
                p.dim_key, list(p.dim_columns),
            )
        if isinstance(p, LFilter):
            return Filter(self._lower(p.child, report), p.expr)
        if isinstance(p, LProject):
            return Project(self._lower(p.child, report), list(p.keep), dict(p.exprs))
        if isinstance(p, LAggregate):
            return Aggregate(self._lower(p.child, report), list(p.aggs))
        if isinstance(p, LPredict):
            child = self._lower(p.child, report)
            t = p.transform or "none"
            if t == "sql":
                try:
                    return self._lower_sql(p, child, report)
                except MLtoSQLUnsupported as e:
                    report.notes.append(f"MLtoSQL fallback: {e}")
                    t = "none"
            if t == "dnn":
                try:
                    part = compile_pipeline_to_dnn_partial(
                        p.pipeline, strategy=opt.tensor_strategy,
                        use_pallas=opt.use_pallas,
                        rename=dict(zip(p.pipeline.outputs, p.output_names)),
                        cost_model=opt.cost_model,
                    )
                    return self._emit_dnn(p, child, part, report)
                except MLtoDNNUnsupported as e:
                    report.notes.append(f"MLtoDNN fallback: {e}")
                    t = "none"
            report.placement.append(
                [(_pipeline_node_label(n), "host") for n in p.pipeline.nodes]
            )
            return MLUdf(
                child, p.pipeline, list(p.output_names),
                batch_size=opt.udf_batch_size,
            )
        raise TypeError(type(p))

    def _emit_dnn(self, p: LPredict, child, part, report) -> PhysicalPlan:
        """Emit the physical plan for an MLtoDNN lowering — a single fused
        TensorOp when the whole pipeline is supported, else the split
        ``TensorOp(prefix) → MLUdf(residual) → TensorOp(suffix)`` chain with
        cut values threaded as reserved block columns."""
        opt = self.options
        if part.full is not None:
            comp = part.full
            outs = list(p.pipeline.outputs)
            names = list(p.output_names)

            def fn(cols, _c=comp, _o=outs, _n=names):
                res = _c.fn(cols)
                return {
                    n: (res[o].reshape(-1) if res[o].ndim > 1 else res[o])
                    for o, n in zip(_o, _n)
                }

            # canonical content token: the closure's behaviour is a pure
            # function of (pipeline, outputs, strategy) — the compiler's own
            # token folds in its emission version (e.g. featurize fusion) —
            # so two MLtoDNN lowerings of the same pipeline, even in
            # different processes, fingerprint identically
            fn.__fingerprint_token__ = fingerprint(
                "mltodnn", p.pipeline, outs, names,
                opt.tensor_strategy, opt.use_pallas,
                comp.fn.__fingerprint_token__,
            )
            # consumed-column schema for the StageGraph (the closure is
            # otherwise opaque to schema inference)
            fn.__input_names__ = tuple(comp.input_names)
            if comp.fused:
                report.notes.append(
                    "MLtoDNN fused featurize kernel: "
                    + ", ".join(comp.fused)
                )
            report.placement.append(
                [(label, "tensor") for label, _ in part.split.placement]
            )
            return TensorOp(child, fn, names)

        if part.decision is not None and part.decision.choice == "monolithic":
            # the cost model priced the split's boundary crossings above the
            # tensor speedup: emit one host MLUdf over the whole pipeline
            # (the same shape as the no-split fallback, so every verifier
            # rule that holds there holds here)
            report.placement.append(
                [(label, "host") for label, _ in part.split.placement]
            )
            report.notes.append(part.decision.note())
            return MLUdf(
                child, p.pipeline, list(p.output_names),
                batch_size=opt.udf_batch_size,
            )

        runtime = {
            "prefix": "tensor/prefix",
            "residual": "host/residual",
            "suffix": "tensor/suffix",
        }
        report.placement.append(
            [(label, runtime[seg]) for label, seg in part.split.placement]
        )
        final = set(p.output_names)

        def tensor_wrap(comp, seg, tag):
            def fn(cols, _c=comp, _seg=seg):
                res = _c.fn(cols)
                out = {}
                for o, name in zip(_seg.pipeline.outputs, _seg.out_cols):
                    v = res[o]
                    out[name] = (
                        v.reshape(-1) if name in final and v.ndim > 1 else v
                    )
                return out

            fn.__fingerprint_token__ = fingerprint(
                "mltodnn_split", tag, seg.pipeline, seg.out_cols,
                seg.consumes, opt.tensor_strategy, opt.use_pallas,
                comp.fn.__fingerprint_token__,
            )
            fn.__input_names__ = tuple(comp.input_names)
            return fn

        plan: PhysicalPlan = child
        fused: list[str] = []
        if part.prefix is not None:
            comp, seg = part.prefix
            fused += list(comp.fused)
            plan = TensorOp(
                plan, tensor_wrap(comp, seg, "prefix"),
                list(seg.out_cols), consumes=tuple(seg.consumes),
            )
        seg = part.residual
        plan = MLUdf(
            plan, seg.pipeline, list(seg.out_cols),
            batch_size=opt.udf_batch_size, consumes=tuple(seg.consumes),
        )
        if part.suffix is not None:
            comp, seg = part.suffix
            fused += list(comp.fused)
            plan = TensorOp(
                plan, tensor_wrap(comp, seg, "suffix"),
                list(seg.out_cols), consumes=tuple(seg.consumes),
            )
        n_res = sum(1 for _, s in part.split.placement if s == "residual")
        n_all = len(part.split.placement)
        report.notes.append(
            f"MLtoDNN split: {n_all - n_res}/{n_all} pipeline ops lowered to "
            f"the tensor runtime; {n_res}-op residual stays on host"
        )
        if part.decision is not None:
            report.notes.append(part.decision.note())
        if fused:
            report.notes.append(
                "MLtoDNN fused featurize kernel: " + ", ".join(fused)
            )
        return plan

    def _lower_sql(self, p: LPredict, child: PhysicalPlan, report) -> PhysicalPlan:
        """MLtoSQL lowering, incl. per-partition specialized expressions."""
        if p.partitioned and p.partition_col:
            comps = [
                (key, compile_pipeline_to_sql(pl)) for key, pl in p.partitioned
            ]
            space = comps[0][1].score_space
            exprs: dict[str, Expr] = {}
            for out, name in zip(p.pipeline.outputs, p.output_names):
                expr: Expr = comps[-1][1].exprs[out]
                for key, comp in comps[:-1]:
                    expr = Case(
                        Bin("eq", Col(p.partition_col), Const(float(key))),
                        comp.exprs[out],
                        expr,
                    )
                exprs[name] = expr
            report.notes.append(
                f"MLtoSQL partitioned over {p.partition_col} "
                f"({len(comps)} specialized models)"
            )
        else:
            comp = compile_pipeline_to_sql(p.pipeline)
            space = comp.score_space
            exprs = {
                name: comp.exprs[out]
                for out, name in zip(p.pipeline.outputs, p.output_names)
            }
        if space == "logit":
            if p.emit_prob:
                score_name = p.output_names[0]
                exprs[score_name] = Un("sigmoid", exprs[score_name])
                report.notes.append(
                    f"score column '{score_name}' emitted in probability "
                    "space (sigmoid applied — score is query-visible)"
                )
            else:
                report.notes.append(
                    f"score column '{p.output_names[0]}' emitted in logit "
                    "space (threshold filters rewritten)"
                )
        report.placement.append(
            [(_pipeline_node_label(n), "sql") for n in p.pipeline.nodes]
        )
        return Project(child, None, exprs)


def _logical_out_cols(p: LogicalPlan) -> list[str]:
    """Output-column inference for logical plans (mirrors engine._out_cols)."""
    if isinstance(p, LScan):
        return list(p.columns)
    if isinstance(p, LJoin):
        return _logical_out_cols(p.child) + list(p.dim_columns)
    if isinstance(p, LFilter):
        return _logical_out_cols(p.child)
    if isinstance(p, LProject):
        base = list(p.keep) if p.keep is not None else _logical_out_cols(p.child)
        return base + list(p.exprs)
    if isinstance(p, LPredict):
        return _logical_out_cols(p.child) + list(p.output_names)
    if isinstance(p, LAggregate):
        return [a[0] for a in p.aggs]
    raise TypeError(type(p))


def _is_threshold_filter(e: Expr, score_col: str) -> bool:
    """True iff every reference to ``score_col`` in ``e`` is a rewritable
    ``score <op> const`` comparison (possibly under and/or)."""
    if isinstance(e, Bin) and e.op in ("and", "or"):
        return _is_threshold_filter(e.a, score_col) and _is_threshold_filter(
            e.b, score_col
        )
    if (
        isinstance(e, Bin)
        and e.op in ("ge", "gt", "le", "lt")
        and isinstance(e.a, Col)
        and e.a.name == score_col
        and isinstance(e.b, (Const, Param))
    ):
        return True
    return score_col not in columns_of(e)


def _score_visible(plan: LogicalPlan, score_col: str) -> bool:
    """Does the score column escape threshold filters — i.e. reach the query
    result, an aggregate, or a projection expression? If so, MLtoSQL must
    emit it in probability space."""
    from repro.core.ir import walk

    if score_col in _logical_out_cols(plan):
        return True
    for node in walk(plan):
        if isinstance(node, LAggregate):
            if any(col == score_col for _, _, col in node.aggs):
                return True
        elif isinstance(node, LProject):
            if any(score_col in columns_of(e) for e in node.exprs.values()):
                return True
        elif isinstance(node, LFilter):
            if not _is_threshold_filter(node.expr, score_col):
                return True
    return False


def format_physical_plan(p: PhysicalPlan, indent: int = 0) -> str:
    """Indented rendering of a lowered physical plan (EXPLAIN output).

    Scans show the columns that survived projection pushdown; Projects show
    compiled model expressions (summarized when large); Filters show rewritten
    thresholds (logit-space constants / ``logit(:param)`` wrappers).
    """
    from repro.relational.engine import plan_children

    pad = "  " * indent
    if isinstance(p, Scan):
        line = f"{pad}Scan[{p.table}] cols=({', '.join(p.columns)})"
    elif isinstance(p, Join):
        line = (
            f"{pad}Join[{p.dim_table}] on {p.fact_key}={p.dim_key} "
            f"bring=({', '.join(p.dim_columns)})"
        )
    elif isinstance(p, Filter):
        line = f"{pad}Filter[{format_expr(p.expr)}]"
    elif isinstance(p, Project):
        exprs = ", ".join(f"{k}={format_expr(e)}" for k, e in p.exprs.items())
        keep = "*" if p.keep is None else f"({', '.join(p.keep)})"
        line = f"{pad}Project[keep={keep}{'; ' + exprs if exprs else ''}]"
    elif isinstance(p, MLUdf):
        line = (
            f"{pad}MLUdf[{p.pipeline.n_ops()}-op pipeline -> "
            f"({', '.join(p.output_names)}); host boundary, "
            f"batch={p.batch_size}]"
        )
    elif isinstance(p, TensorOp):
        line = f"{pad}TensorOp[fused tensor program -> ({', '.join(p.output_names)})]"
    elif isinstance(p, Aggregate):
        aggs = ", ".join(f"{n}={op}({c})" for n, op, c in p.aggs)
        line = f"{pad}Aggregate[{aggs}]"
    else:
        raise TypeError(type(p))
    kids = plan_children(p)
    return "\n".join([line] + [format_physical_plan(c, indent + 1) for c in kids])


def rewrite_score_filters(
    plan: LogicalPlan, score_col: str, to_space: str
) -> None:
    """Rewrite prob-space score predicates to logit space in-place
    (needed when MLtoSQL emits logit-space scores)."""
    from repro.core.ir import walk

    if to_space != "logit":
        return
    for node in walk(plan):
        if isinstance(node, LFilter):
            node.expr = _rewrite_expr(node.expr, score_col)


def _rewrite_expr(e: Expr, score_col: str) -> Expr:
    if (
        isinstance(e, Bin)
        and e.op in ("ge", "gt", "le", "lt")
        and isinstance(e.a, Col)
        and e.a.name == score_col
    ):
        if isinstance(e.b, Const):
            p = min(max(float(e.b.value), 1e-9), 1 - 1e-9)
            return Bin(e.op, e.a, Const(float(math.log(p / (1 - p)))))
        if isinstance(e.b, Param):
            # bound value arrives at run time: defer the prob->logit map
            # into the compiled program (same clipping as the static path)
            return Bin(e.op, e.a, Un("logit", e.b))
    if isinstance(e, Bin) and e.op in ("and", "or"):
        return Bin(e.op, _rewrite_expr(e.a, score_col), _rewrite_expr(e.b, score_col))
    return e
