"""Data-driven optimization strategies for runtime selection (paper §5.2).

Three strategies choose between {none, sql, dnn} per predict node:

  * ML-informed rule-based — train a deep multiclass tree on the corpus, take
    its top-k features, retrain a shallow tree, and *render it as a rule*
    (no model invocation at optimization time; deployable as code).
  * Classification-based — random forest over the 22 pipeline statistics
    predicting the best transformation directly.
  * Regression-based — a regression tree predicts log-runtime with the
    transformation as an input feature (3× the training data); pick argmin.

The corpus is measured on *this* hardware/backends (the paper's own
prescription: users re-train the strategy for their workload and setup).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.stats import STAT_NAMES
from repro.ml.trees import _candidate_thresholds, _concat_trees, _grow_tree

TRANSFORMS = ("none", "sql", "dnn")


# ---------------------------------------------------------------------------
# Multiclass CART (gini) — used by the rule-based & classification strategies
# ---------------------------------------------------------------------------


def _gini(counts: np.ndarray) -> float:
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts / n
    return float(1.0 - (p * p).sum())


@dataclass
class MulticlassTreeClassifier:
    max_depth: int = 6
    min_samples_split: int = 2
    max_bins: int = 16
    max_features: Optional[int] = None
    seed: int = 0
    nodes: list = field(default_factory=list, repr=False)  # (f,t,l,r,counts)
    classes_: Optional[np.ndarray] = None
    importances_: Optional[np.ndarray] = None

    def fit(self, X, y, sample_idx=None):
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y)
        self.classes_, yi = np.unique(y, return_counts=False), None
        yi = np.searchsorted(self.classes_, y)
        K = len(self.classes_)
        rng = np.random.default_rng(self.seed)
        self.nodes = []
        self.importances_ = np.zeros(X.shape[1])
        idx = np.arange(X.shape[0]) if sample_idx is None else sample_idx

        def counts_of(ii):
            return np.bincount(yi[ii], minlength=K).astype(np.float64)

        def build(ii, depth):
            node_id = len(self.nodes)
            c = counts_of(ii)
            self.nodes.append([-1, 0.0, 0, 0, c])
            if (
                depth >= self.max_depth
                or len(ii) < self.min_samples_split
                or (c > 0).sum() <= 1
            ):
                return node_id
            gp = _gini(c)
            feats = (
                rng.choice(X.shape[1], self.max_features, replace=False)
                if self.max_features and self.max_features < X.shape[1]
                else np.arange(X.shape[1])
            )
            best = (None, None, 1e-12)
            for f in feats:
                col = X[ii, f]
                for t in _candidate_thresholds(col, self.max_bins):
                    m = col <= t
                    cl, cr = counts_of(ii[m]), counts_of(ii[~m])
                    nl, nr = cl.sum(), cr.sum()
                    if nl == 0 or nr == 0:
                        continue
                    gain = gp - (nl * _gini(cl) + nr * _gini(cr)) / len(ii)
                    if gain > best[2]:
                        best = (int(f), float(t), float(gain))
            f, t, gain = best
            if f is None:
                return node_id
            self.importances_[f] += gain * len(ii)
            m = X[ii, f] <= t
            self.nodes[node_id][0] = f
            self.nodes[node_id][1] = t
            self.nodes[node_id][2] = build(ii[m], depth + 1)
            self.nodes[node_id][3] = build(ii[~m], depth + 1)
            return node_id

        build(idx, 0)
        s = self.importances_.sum()
        if s > 0:
            self.importances_ /= s
        return self

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.empty(len(X), dtype=self.classes_.dtype)
        for i, row in enumerate(X):
            n = 0
            while self.nodes[n][0] != -1:
                f, t, l, r, _ = self.nodes[n]
                n = l if row[f] <= t else r
            out[i] = self.classes_[int(np.argmax(self.nodes[n][4]))]
        return out

    def predict_proba(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.zeros((len(X), len(self.classes_)))
        for i, row in enumerate(X):
            n = 0
            while self.nodes[n][0] != -1:
                f, t, l, r, _ = self.nodes[n]
                n = l if row[f] <= t else r
            c = self.nodes[n][4]
            out[i] = c / max(c.sum(), 1.0)
        return out


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


@dataclass
class RuleBasedStrategy:
    """Deep tree → top-k features → shallow tree → human-readable rule."""

    k: int = 3
    shallow_depth: int = 2
    tree: Optional[MulticlassTreeClassifier] = field(default=None, repr=False)
    top_features: Optional[np.ndarray] = None

    def fit(self, X, y):
        deep = MulticlassTreeClassifier(max_depth=8).fit(X, y)
        self.top_features = np.argsort(deep.importances_)[::-1][: self.k]
        self.tree = MulticlassTreeClassifier(max_depth=self.shallow_depth).fit(
            X[:, self.top_features], y
        )
        return self

    def choose(self, stats: np.ndarray) -> str:
        lab = self.tree.predict(stats[None, self.top_features])[0]
        return TRANSFORMS[int(lab)]

    def describe(self) -> str:
        """Render the learned rule as nested if/else over stat names."""
        lines: list[str] = []

        def render(n, indent):
            f, t, l, r, c = self.tree.nodes[n]
            pad = "  " * indent
            if f == -1:
                lines.append(
                    f"{pad}apply {TRANSFORMS[int(np.argmax(c))].upper()}"
                )
                return
            name = STAT_NAMES[int(self.top_features[f])]
            lines.append(f"{pad}if {name} <= {t:.3g}:")
            render(l, indent + 1)
            lines.append(f"{pad}else:")
            render(r, indent + 1)

        render(0, 0)
        return "\n".join(lines)


@dataclass
class ClassificationStrategy:
    """Random forest over pipeline statistics (paper's best performer)."""

    n_estimators: int = 25
    max_depth: int = 8
    seed: int = 0
    trees: list = field(default_factory=list, repr=False)

    def fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        n, d = np.asarray(X).shape
        mf = max(1, int(np.sqrt(d)))
        self.trees = []
        for i in range(self.n_estimators):
            boot = rng.integers(0, n, size=n)
            t = MulticlassTreeClassifier(
                max_depth=self.max_depth, max_features=mf, seed=i
            ).fit(np.asarray(X)[boot], np.asarray(y)[boot])
            self.trees.append(t)
        return self

    def choose(self, stats: np.ndarray) -> str:
        votes = np.zeros(len(TRANSFORMS))
        for t in self.trees:
            p = t.predict_proba(stats[None])[0]
            for ci, cls in enumerate(t.classes_):
                votes[int(cls)] += p[ci]
        return TRANSFORMS[int(np.argmax(votes))]


@dataclass
class RegressionStrategy:
    """Regression tree over [stats ⊕ onehot(transform)] → log runtime."""

    max_depth: int = 8
    ensemble: object = field(default=None, repr=False)

    @staticmethod
    def _augment(X: np.ndarray, transform_ids: np.ndarray) -> np.ndarray:
        oh = np.eye(len(TRANSFORMS))[transform_ids]
        return np.concatenate([X, oh], axis=1)

    def fit(self, X, y_runtimes):
        """X: (n, 22); y_runtimes: (n, 3) measured runtime per transform."""
        X = np.asarray(X, dtype=np.float64)
        rows, targets = [], []
        for i in range(len(X)):
            for tid in range(len(TRANSFORMS)):
                rows.append(self._augment(X[i : i + 1], np.asarray([tid]))[0])
                targets.append(np.log(max(y_runtimes[i, tid], 1e-9)))
        Xa = np.asarray(rows)
        ya = np.asarray(targets)
        # Grow the tree on mean-centered targets: the grad-mode split gain
        # G²/(H+λ) is regularized, so a large common offset (log-runtimes sit
        # far from 0) makes every split cost ~μ² and the tree degenerates to
        # a single leaf. The mean becomes the ensemble's base_score.
        base = float(ya.mean())
        tree = _grow_tree(
            Xa,
            (ya - base, np.ones_like(ya)),
            max_depth=self.max_depth,
            min_samples_split=2,
            max_bins=32,
            rng=None,
            max_features=None,
            mode="grad",
        )
        self.ensemble = _concat_trees([tree], np.ones(1), base, "none", Xa.shape[1])
        return self

    def choose(self, stats: np.ndarray) -> str:
        preds = []
        for tid in range(len(TRANSFORMS)):
            row = self._augment(stats[None], np.asarray([tid]))
            preds.append(float(self.ensemble.raw_scores(row)[0]))
        return TRANSFORMS[int(np.argmin(preds))]


# ---------------------------------------------------------------------------
# Evaluation harness (paper Fig. 4)
# ---------------------------------------------------------------------------


def evaluate_strategy(strategy, X_test, y_test, runtimes_test) -> dict:
    """Accuracy + speedup-vs-optimal over a held-out corpus fold."""
    chosen = np.asarray(
        [TRANSFORMS.index(strategy.choose(x)) for x in np.asarray(X_test)]
    )
    acc = float((chosen == np.asarray(y_test)).mean())
    opt_time = runtimes_test[np.arange(len(chosen)), np.asarray(y_test)].sum()
    got_time = runtimes_test[np.arange(len(chosen)), chosen].sum()
    return {"accuracy": acc, "speedup_vs_optimal": float(opt_time / got_time)}
