"""Raven's contribution: the unified IR and the prediction-query optimizer."""
from repro.core.ir import (
    ColumnStats,
    LAggregate,
    LFilter,
    LJoin,
    LPredict,
    LProject,
    LScan,
    LogicalPlan,
    PredictionQuery,
    TableStats,
    plan_fingerprint,
    walk,
)
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
