from repro.checkpoint.store import (
    CheckpointManager,
    load_checkpoint,
    restore_onto_mesh,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "load_checkpoint",
    "restore_onto_mesh",
]
