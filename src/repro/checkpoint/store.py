"""Mesh-agnostic sharded checkpointing with async save and elastic restore.

Format: one directory per step containing
  * ``meta.json``   — pytree skeleton, per-leaf global shape/dtype, step,
                      wall-clock, user metadata;
  * ``shard_<host>.npz`` — this host's addressable shard data, keyed by
                      ``<leaf-path>|<flat-index-offsets>`` so any number of
                      hosts/mesh layouts can be reassembled.

Because every leaf records its GLOBAL shape plus per-shard index windows,
restore is *elastic*: a checkpoint written on a 16×16 mesh restores onto
2×16×16 (or a single CPU device) by assembling the global array and
``jax.device_put``-ing it with the target sharding — exactly the recipe in
DESIGN.md §5 (elastic scaling / fault tolerance).

Writes are atomic (tmp dir + rename) so a preemption mid-save never corrupts
the latest-complete pointer. ``CheckpointManager`` adds async (background
thread) saves, retention, and preemption-signal draining.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# pytree <-> flat path helpers
# ---------------------------------------------------------------------------


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out: dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict[str, Any], skeleton: Any, prefix: str = "") -> Any:
    if isinstance(skeleton, dict):
        return {
            k: _unflatten(flat, skeleton[k], f"{prefix}.{k}" if prefix else str(k))
            for k in skeleton
        }
    if isinstance(skeleton, (tuple, list)):
        seq = [
            _unflatten(flat, v, f"{prefix}[{i}]") for i, v in enumerate(skeleton)
        ]
        return tuple(seq) if isinstance(skeleton, tuple) else seq
    return flat[prefix]


def _skeleton(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {k: _skeleton(v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        seq = [_skeleton(v) for v in tree]
        return seq if isinstance(tree, list) else {"__tuple__": seq}
    return None


def _from_skeleton(sk: Any) -> Any:
    if isinstance(sk, dict):
        if "__tuple__" in sk and len(sk) == 1:
            return tuple(_from_skeleton(v) for v in sk["__tuple__"])
        return {k: _from_skeleton(v) for k, v in sk.items()}
    if isinstance(sk, list):
        return [_from_skeleton(v) for v in sk]
    return None


def _index_key(idx: tuple) -> str:
    """Serialize a shard's global index window (tuple of slices)."""
    parts = []
    for s in idx:
        parts.append(f"{0 if s.start is None else s.start}:{'' if s.stop is None else s.stop}")
    return ";".join(parts)


def _parse_index(key: str, shape: tuple[int, ...]) -> tuple:
    out = []
    if not key:
        return tuple(slice(0, d) for d in shape)
    for part, dim in zip(key.split(";"), shape):
        a, b = part.split(":")
        out.append(slice(int(a), int(b) if b else dim))
    return tuple(out)


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    metadata: Optional[dict] = None,
    host_id: int = 0,
) -> str:
    """Write ``tree`` (params/opt-state/anything) as step-<step> atomically."""
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=directory)
    try:
        leaves_meta = {}
        arrays: dict[str, np.ndarray] = {}
        for path, leaf in flat.items():
            if isinstance(leaf, jax.Array):
                leaves_meta[path] = {
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                }
                for sh in leaf.addressable_shards:
                    key = f"{path}|{_index_key(sh.index)}"
                    arrays[key] = np.asarray(sh.data)
            else:
                arr = np.asarray(leaf)
                leaves_meta[path] = {
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
                arrays[f"{path}|"] = arr
        # bf16 has no numpy dtype: view as uint16 with a marker
        packed = {}
        for k, v in arrays.items():
            if v.dtype == jax.numpy.bfloat16:
                packed["BF16::" + k] = v.view(np.uint16)
            else:
                packed[k] = v
        np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **packed)
        meta = {
            "step": step,
            "time": time.time(),
            "skeleton": _skeleton(tree),
            "leaves": leaves_meta,
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _assemble_global(path_meta: dict, pieces: list[tuple[tuple, np.ndarray]]):
    shape = tuple(path_meta["shape"])
    dtype = path_meta["dtype"]
    if dtype == "bfloat16":
        out = np.zeros(shape, np.uint16)
        for idx, arr in pieces:
            out[idx] = arr
        return out  # caller re-views as bf16 at device_put
    out = np.zeros(shape, np.dtype(dtype))
    for idx, arr in pieces:
        out[idx] = arr
    return out


def load_checkpoint(directory: str, step: Optional[int] = None) -> tuple[int, Any, dict]:
    """Load the given (or latest complete) step as numpy global arrays."""
    if step is None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(directory)
            if d.startswith("step_") and os.path.exists(
                os.path.join(directory, d, "meta.json")
            )
        )
        if not steps:
            raise FileNotFoundError(f"no complete checkpoints in {directory}")
        step = steps[-1]
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, "meta.json")) as f:
        meta = json.load(f)
    pieces: dict[str, list[tuple[tuple, np.ndarray]]] = {}
    for fn in os.listdir(ckpt):
        if not fn.startswith("shard_"):
            continue
        with np.load(os.path.join(ckpt, fn)) as z:
            for key in z.files:
                raw = key
                is_bf16 = raw.startswith("BF16::")
                if is_bf16:
                    raw = raw[len("BF16::"):]
                path, _, idx_key = raw.partition("|")
                shape = tuple(meta["leaves"][path]["shape"])
                idx = _parse_index(idx_key, shape)
                pieces.setdefault(path, []).append((idx, z[key]))
    flat = {
        path: _assemble_global(meta["leaves"][path], pieces[path])
        for path in meta["leaves"]
    }
    skeleton = _from_skeleton(meta["skeleton"])
    tree = _unflatten(flat, skeleton)
    return step, tree, meta


def restore_onto_mesh(
    np_tree: Any, shardings: Any, dtypes: Optional[dict[str, str]] = None
) -> Any:
    """Elastic restore: place global numpy arrays with the target shardings
    (which may come from a DIFFERENT mesh shape than the writer's)."""
    flat_t = _flatten(np_tree)
    flat_s = _flatten(shardings)

    def place(path):
        arr = flat_t[path]
        sh = flat_s.get(path)
        want_bf16 = dtypes and dtypes.get(path) == "bfloat16"
        if arr.dtype == np.uint16 and (want_bf16 or dtypes is None):
            arr = arr.view(jax.numpy.bfloat16)
        if sh is None:
            return jax.numpy.asarray(arr)
        return jax.device_put(arr, sh)

    flat_out = {p: place(p) for p in flat_t}
    return _unflatten(flat_out, _skeleton(np_tree))


# ---------------------------------------------------------------------------
# manager: async save, retention, preemption draining
# ---------------------------------------------------------------------------


class CheckpointManager:
    """Background-thread checkpointer with retention + preemption support.

    ``save()`` snapshots device arrays to host (cheap, blocking) then writes
    in a worker thread so the train loop never waits on disk. ``flush()``
    joins outstanding writes (call on preemption signal / shutdown).
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             blocking: bool = False) -> None:
        self.flush()  # one in-flight write at a time
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._retain()
            except BaseException as e:  # surfaced on next flush()
                self._err = e

        if blocking:
            work()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def flush(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def latest_step(self) -> Optional[int]:
        try:
            steps = [
                int(d.split("_")[1])
                for d in os.listdir(self.directory)
                if d.startswith("step_")
                and os.path.exists(os.path.join(self.directory, d, "meta.json"))
            ]
            return max(steps) if steps else None
        except FileNotFoundError:
            return None

    def _retain(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
