"""Pallas TPU kernel: fused featurization (scaler + one-hot + concat).

The paper's §7.4 identifies relational→model data conversion as a main
PREDICT overhead. On TPU we fuse the whole featurization into one VMEM pass:
a row-block of raw numeric columns and categorical codes enters VMEM once and
the full feature block (numerics scaled, categoricals one-hot, concatenated)
leaves — no intermediate HBM materialization per featurizer op.

Categorical segments are static (compile-time python loop), so each one-hot
writes to a statically-sliced column range of the output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    num_ref, cat_ref, off_ref, sc_ref, vals_ref, o_ref, *, segments, n_num
):
    if n_num:
        num = num_ref[...]  # (BN, Kn)
        o_ref[:, :n_num] = (num - off_ref[0][None, :]) * sc_ref[0][None, :]
    if segments:
        cat = cat_ref[...]  # (BN, Kc) int32
        col = n_num
        for j, (start, length) in enumerate(segments):
            vals = vals_ref[0, start : start + length]  # (V_j,) static slice
            oh = (cat[:, j : j + 1] == vals[None, :]).astype(jnp.float32)
            o_ref[:, col : col + length] = oh
            col += length


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def featurize(
    num: jnp.ndarray,
    cat: jnp.ndarray,
    offset: jnp.ndarray,
    scale: jnp.ndarray,
    cat_values: jnp.ndarray,
    cat_segments: tuple[tuple[int, int], ...],
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """num:(N,Kn) f32; cat:(N,Kc) int32; offset/scale:(Kn,);
    cat_values:(Vtot,) concatenated category values (int32);
    cat_segments: ((start,len), ...) per categorical column.
    Returns (N, Kn + Vtot) f32. Rows are padded internally to a multiple of
    ``block_n`` (categorical pad code -1 never matches a category) and
    cropped back, so callers pass natural row counts."""
    N, Kn = num.shape
    Kc = cat.shape[1]
    Vtot = int(cat_values.shape[0])
    Fout = Kn + Vtot
    if Fout == 0:
        return jnp.zeros((N, 0), jnp.float32)
    Np = _round_up(max(N, 1), block_n)
    num = jnp.pad(num.astype(jnp.float32), ((0, Np - N), (0, 0)))
    cat = jnp.pad(cat.astype(jnp.int32), ((0, Np - N), (0, 0)), constant_values=-1)
    offset = offset.astype(jnp.float32)
    scale = scale.astype(jnp.float32)
    cat_values = cat_values.astype(jnp.int32)
    # Zero-width operands break Pallas block indexing; widen them to one
    # inert column. The kernel never reads it: n_num / segments are static
    # and skip the padded operand entirely.
    if Kn == 0:
        num = jnp.zeros((Np, 1), jnp.float32)
        offset = scale = jnp.zeros((1,), jnp.float32)
    if Kc == 0:
        cat = jnp.full((Np, 1), -1, jnp.int32)
    if Vtot == 0:
        cat_values = jnp.zeros((1,), jnp.int32)
    Knp, Kcp, Vp = max(Kn, 1), max(Kc, 1), max(Vtot, 1)
    grid = (Np // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, segments=tuple(cat_segments), n_num=Kn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, Knp), lambda n: (n, 0)),
            pl.BlockSpec((block_n, Kcp), lambda n: (n, 0)),
            pl.BlockSpec((1, Knp), lambda n: (0, 0)),
            pl.BlockSpec((1, Knp), lambda n: (0, 0)),
            pl.BlockSpec((1, Vp), lambda n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Fout), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Fout), jnp.float32),
        interpret=interpret,
    )(
        num,
        cat,
        offset.reshape(1, -1),
        scale.reshape(1, -1),
        cat_values.reshape(1, -1),
    )
    return out[:N]
