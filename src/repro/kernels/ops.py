"""Jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernel runs natively; on CPU the
pure-jnp oracle from :mod:`repro.kernels.ref` runs instead (fused by XLA),
and ``interpret=True`` forces the Pallas kernel body through the interpreter
for correctness tests. All wrappers handle padding so callers pass natural
shapes; padding is constructed to be provably inert (see each pad helper).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.tree_gemm import tree_gemm as _tree_gemm_kernel
from repro.kernels.featurize import featurize as _featurize_kernel
from repro.kernels.relational import (
    gather_join as _gather_join_kernel,
    segment_agg as _segment_agg_kernel,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def kernels_enabled() -> bool:
    """``RAVEN_KERNELS`` knob: ``off``/``0`` routes relational stages through
    the legacy jnp composition (argsort/searchsorted/segment_sum inline in
    the stage fn) instead of the kernel ops. Anything else (the default)
    uses :func:`gather_join_op`/:func:`segment_agg_op`, which dispatch to
    the Pallas kernels on TPU and the jnp oracles on CPU."""
    return os.environ.get("RAVEN_KERNELS", "on").lower() not in ("off", "0")


def kernel_mode_token() -> str:
    """Content token for the relational-kernel codegen mode. Folded into the
    fingerprints of stages (and plans) containing Join/Aggregate ops so the
    two ``RAVEN_KERNELS`` modes never alias each other's compiled artifacts.
    ``rk1`` versions the relational-kernel emission itself."""
    return "rk1-on" if kernels_enabled() else "rk1-off"


# ---------------------------------------------------------------------------
# tree_gemm
# ---------------------------------------------------------------------------


def pad_gemm_program(A, B, C, D, V, align: int = 128):
    """MXU-align F/I/L. Inert padding proof:
      * extra F rows of A are zero → S unchanged (x is zero-padded to match);
      * extra I columns: threshold +inf ⇒ decision 1, but their C rows are
        zero ⇒ P unchanged;
      * extra L columns: Dcount = -1 can never equal a non-negative path
        count ⇒ match 0 ⇒ V never read (and V is 0 there anyway)."""
    T, F, I = A.shape
    L = C.shape[2]
    Fp, Ip, Lp = _round_up(F, align), _round_up(I, align), _round_up(L, align)
    A2 = np.zeros((T, Fp, Ip), np.float32)
    A2[:, :F, :I] = A
    B2 = np.full((T, Ip), np.float32(np.inf))
    B2[:, :I] = B
    C2 = np.zeros((T, Ip, Lp), np.float32)
    C2[:, :I, :L] = C
    D2 = np.full((T, Lp), np.float32(-1.0))
    D2[:, :L] = D
    V2 = np.zeros((T, Lp), np.float32)
    V2[:, :L] = V
    return A2, B2, C2, D2, V2


@functools.partial(jax.jit, static_argnames=("base", "block_n", "use_pallas", "interpret"))
def tree_gemm_op(
    x, A, B, C, D, V, *, base: float, block_n: int = 256,
    use_pallas: bool | None = None, interpret: bool = False,
):
    """(N,F) rows → (N,) raw scores. Pads N to block_n and F to A's F."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    N, F = x.shape
    Fk = A.shape[1]
    if not (use_pallas or interpret):
        xp = jnp.pad(x, ((0, 0), (0, Fk - F))) if Fk > F else x
        return _ref.tree_gemm_ref(xp, A, B, C, D, V, base)
    Np = _round_up(max(N, 1), block_n)
    xp = jnp.pad(x.astype(jnp.float32), ((0, Np - N), (0, Fk - F)))
    out = _tree_gemm_kernel(
        xp, A, B, C, D, V, base, block_n=block_n, interpret=interpret
    )
    return out[:N]


# ---------------------------------------------------------------------------
# featurize
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("cat_segments", "block_n", "use_pallas", "interpret"),
)
def featurize_op(
    num, cat, offset, scale, cat_values, cat_segments,
    *, block_n: int = 256, use_pallas: bool | None = None, interpret: bool = False,
):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return _ref.featurize_ref(num, cat, offset, scale, cat_values, cat_segments)
    # row padding/cropping (and zero-width operand widening) live in the
    # kernel wrapper itself — natural shapes in, natural shapes out
    return _featurize_kernel(
        num, cat, offset, scale, cat_values, cat_segments,
        block_n=block_n, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# relational: gather-join and masked segmented aggregate
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("block_n", "use_pallas", "interpret")
)
def gather_join_op(
    fk, skeys, spay, *, block_n: int = 256,
    use_pallas: bool | None = None, interpret: bool = False,
):
    """Dim-table equi-join gather. fk:(N,) int32; skeys:(M,) sorted *unique*
    int32 dim keys; spay:(M,P) f32 payload aligned to skeys. Returns
    ``(out, hit)``: out:(N,P) f32 (zero on miss), hit:(N,) bool. Miss rows
    zero their payload in every dispatch path, so kernel and oracle agree
    bitwise on all rows."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return _ref.gather_join_ref(fk, skeys, spay)
    return _gather_join_kernel(
        fk, skeys, spay, block_n=block_n, interpret=interpret
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "block_n", "use_pallas", "interpret"),
)
def segment_agg_op(
    vals, w, sid, *, num_segments: int, block_n: int = 256,
    use_pallas: bool | None = None, interpret: bool = False,
):
    """Masked segmented aggregate. vals:(N,C) f32; w:(N,) f32 validity
    weights (the fused filter mask); sid:(N,) int32 in [0, num_segments).
    Returns ``(counts, sums, mins, maxs)`` — counts:(S,), the rest (S,C);
    mins/maxs are +inf/-inf where a segment has no valid rows (callers
    replace empties via ``counts > 0``)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return _ref.segment_agg_ref(vals, w, sid, num_segments=num_segments)
    return _segment_agg_kernel(
        vals, w, sid, num_segments=num_segments,
        block_n=block_n, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# attention (wrappers defined with the kernels in flash_attention.py /
# decode_attention.py; re-exported here for a single import surface)
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention import flash_attention_op  # noqa: E402
from repro.kernels.decode_attention import decode_attention_op  # noqa: E402
