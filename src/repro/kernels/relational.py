"""Pallas TPU kernels for the relational half of the runtime: dim-table
gather-join and masked segmented group-by aggregation.

The paper's thesis is that relational and ML operators share one IR so each
side can run on the best runtime; these kernels are what lets Join and
Filter→Aggregate chains stay *inside* a fused pure stage instead of standing
alone as generic jnp ops around a host boundary.

Join strategy (dim-table equi-join, unique keys): instead of
argsort + searchsorted + gather, each row block builds a one-hot match matrix
against the (VMEM-resident) dim-key vector and gathers the payload with one
MXU matmul — ``out = onehot @ payload``. With unique dim keys each one-hot
row has at most a single 1.0, so the matmul reproduces the gathered payload
value *bitwise* (x * 1.0 accumulated with zeros is exact in f32); miss rows
produce all-zero payload and ``hit=0``, matching :func:`gather_join_ref`.
The upstream filter's validity mask is fused downstream (``valid & hit``) —
the kernel itself never materializes filtered rows.

Aggregate strategy: one grid pass over row blocks accumulating into a
(segments × columns) block that stays resident across grid steps
(``@pl.when(program_id == 0)`` init, then ``+=``). Sums and counts are one
one-hot matmul per block (`onehot.T @ (vals * w)` with the weight column
stacked in), min/max are masked broadcast reductions. The filter mask ``w``
is folded in as the weight — filtered rows contribute exactly zero and are
never materialized.

Both kernels use the same treatment as the PR 6 ``featurize`` kernel: rows
padded to a multiple of ``block_n`` with provably inert values and cropped
back, zero-width operands widened to one inert column, ``interpret=True``
for CPU correctness tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# gather-join
# ---------------------------------------------------------------------------


def _gather_join_body(fk_ref, keys_ref, pay_ref, out_ref, hit_ref, *, m_real):
    fk = fk_ref[...]  # (BN, 1) int32
    keys = keys_ref[...]  # (1, Mp) int32
    onehot = fk == keys  # (BN, Mp)
    # padded key columns must never match, whatever their pad value is
    col = jax.lax.broadcasted_iota(jnp.int32, onehot.shape, 1)
    onehot_f = jnp.where(onehot & (col < m_real), 1.0, 0.0).astype(jnp.float32)
    out_ref[...] = jnp.dot(
        onehot_f, pay_ref[...], preferred_element_type=jnp.float32
    )
    hit_ref[...] = jnp.sum(onehot_f, axis=1, keepdims=True)


def gather_join(
    fk: jnp.ndarray,
    skeys: jnp.ndarray,
    spay: jnp.ndarray,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """fk:(N,) int32 fact keys; skeys:(M,) int32 *unique* dim keys;
    spay:(M,P) f32 payload aligned to ``skeys``. Returns ``(out, hit)``:
    out:(N,P) f32 gathered payload (zero on miss), hit:(N,) bool.

    Inert padding proof: extra rows only extend the grid and are cropped;
    extra key columns are masked by the in-kernel ``col < M`` guard (their
    payload rows are zero anyway); extra payload columns are zero and
    cropped.
    """
    N = fk.shape[0]
    M, P = spay.shape
    Mp = _round_up(max(M, 1), 128)
    Pp = _round_up(max(P, 1), 128)
    Np = _round_up(max(N, 1), block_n)
    fk = jnp.pad(fk.astype(jnp.int32), (0, Np - N))
    keys = jnp.pad(skeys.astype(jnp.int32), (0, Mp - M))
    pay = jnp.pad(spay.astype(jnp.float32), ((0, Mp - M), (0, Pp - P)))
    out, hit = pl.pallas_call(
        functools.partial(_gather_join_body, m_real=M),
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda n: (n, 0)),
            pl.BlockSpec((1, Mp), lambda n: (0, 0)),
            pl.BlockSpec((Mp, Pp), lambda n: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, Pp), lambda n: (n, 0)),
            pl.BlockSpec((block_n, 1), lambda n: (n, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, Pp), jnp.float32),
            jax.ShapeDtypeStruct((Np, 1), jnp.float32),
        ],
        interpret=interpret,
    )(fk.reshape(-1, 1), keys.reshape(1, -1), pay)
    return out[:N, :P], hit[:N, 0] > 0


# ---------------------------------------------------------------------------
# masked segmented aggregate
# ---------------------------------------------------------------------------


def _segment_agg_body(
    vals_ref, w_ref, sid_ref, sum_ref, min_ref, max_ref, *, n_cols
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)

    vals = vals_ref[...]  # (BN, Cp) f32, col 0 is the weight itself
    w = w_ref[...]  # (BN, 1) f32 validity weights
    sid = sid_ref[...]  # (BN, 1) int32
    seg = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], sum_ref.shape[0]), 1)
    onehot = sid == seg  # (BN, Sp)
    onehot_f = jnp.where(onehot, 1.0, 0.0).astype(jnp.float32)
    # sums and counts in one MXU pass: contract the row axis
    sum_ref[...] += jax.lax.dot_general(
        onehot_f, vals * w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    mask = onehot & (w > 0)  # (BN, Sp): row feeds segment AND survived filter
    for j in range(n_cols):
        colv = vals[:, j : j + 1]  # (BN, 1) static slice
        mn = jnp.min(jnp.where(mask, colv, jnp.inf), axis=0)  # (Sp,)
        mx = jnp.max(jnp.where(mask, colv, -jnp.inf), axis=0)
        min_ref[j : j + 1, :] = jnp.minimum(min_ref[j : j + 1, :], mn[None, :])
        max_ref[j : j + 1, :] = jnp.maximum(max_ref[j : j + 1, :], mx[None, :])


def segment_agg(
    vals: jnp.ndarray,
    w: jnp.ndarray,
    sid: jnp.ndarray,
    *,
    num_segments: int,
    block_n: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """vals:(N,C) f32 aggregate source columns; w:(N,) f32 validity weights
    (the fused filter mask); sid:(N,) int32 segment ids in
    ``[0, num_segments)``. Returns ``(counts, sums, mins, maxs)``:
    counts:(S,) weighted row counts; sums:(S,C) masked segment sums;
    mins/maxs:(S,C) masked extrema (+inf/-inf where a segment has no valid
    rows — callers replace empties via ``counts > 0``).

    Inert padding proof: padded rows carry ``w=0, sid=0, vals=0`` — they add
    ``0 * 0`` to segment 0's sums and are excluded from min/max by the
    ``w > 0`` mask; padded segment columns receive no real sid and are
    cropped; padded value columns are cropped.
    """
    N, C = vals.shape
    S = num_segments
    Np = _round_up(max(N, 1), block_n)
    Sp = _round_up(max(S, 1), 128)
    Cp = _round_up(C + 1, 128)  # col 0 = weight (counts ride the same matmul)
    C8 = _round_up(max(C + 1, 1), 8)
    stacked = jnp.concatenate(
        [w.astype(jnp.float32).reshape(-1, 1), vals.astype(jnp.float32)], axis=1
    )
    stacked = jnp.pad(stacked, ((0, Np - N), (0, Cp - (C + 1))))
    wp = jnp.pad(w.astype(jnp.float32), (0, Np - N))
    sidp = jnp.pad(sid.astype(jnp.int32), (0, Np - N))
    sums, mins, maxs = pl.pallas_call(
        functools.partial(_segment_agg_body, n_cols=C + 1),
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, Cp), lambda n: (n, 0)),
            pl.BlockSpec((block_n, 1), lambda n: (n, 0)),
            pl.BlockSpec((block_n, 1), lambda n: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Sp, Cp), lambda n: (0, 0)),
            pl.BlockSpec((C8, Sp), lambda n: (0, 0)),
            pl.BlockSpec((C8, Sp), lambda n: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Sp, Cp), jnp.float32),
            jax.ShapeDtypeStruct((C8, Sp), jnp.float32),
            jax.ShapeDtypeStruct((C8, Sp), jnp.float32),
        ],
        interpret=interpret,
    )(stacked, wp.reshape(-1, 1), sidp.reshape(-1, 1))
    counts = sums[:S, 0]
    return counts, sums[:S, 1 : C + 1], mins[1 : C + 1, :S].T, maxs[1 : C + 1, :S].T
