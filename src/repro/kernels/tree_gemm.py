"""Pallas TPU kernel: GEMM-strategy tree-ensemble inference.

The paper's MLtoDNN hotspot, rethought for the MXU (DESIGN.md §2): each
(batch-block, tree) grid step runs the fused chain

    S = X·A  →  D = (S ≤ B)  →  P = D·C  →  match = (P == Dcount)  →  y += match·V

entirely in VMEM, with the two contractions on the MXU. Trees accumulate into
the output block across the innermost grid dimension (revisited output block;
init at t == 0) — no HBM round-trips between trees.

Tiling: rows are tiled by ``block_n``; F/I/L are MXU-aligned by padding in
``repro.kernels.ops`` (zero feature columns, +inf thresholds, zero path
columns and Dcount = -1 are all provably inert — see ops.pad_gemm_program).
VMEM footprint per step ≈ 4·(block_n·F + F·I + I·L + block_n·(I+L)) bytes;
callers pick block_n so this stays under ~12 MB of the 16 MB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, c_ref, d_ref, v_ref, o_ref, *, base: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, base)

    x = x_ref[...]  # (BN, F)
    a = a_ref[0]  # (F, I)
    s = jnp.dot(x, a, preferred_element_type=jnp.float32)  # MXU
    dec = (s <= b_ref[0][None, :]).astype(jnp.float32)  # (BN, I)
    p = jnp.dot(dec, c_ref[0], preferred_element_type=jnp.float32)  # MXU
    match = (p == d_ref[0][None, :]).astype(jnp.float32)  # (BN, L)
    part = jnp.dot(
        match, v_ref[0][:, None], preferred_element_type=jnp.float32
    )  # (BN, 1)
    o_ref[...] += part


def tree_gemm(
    x: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    V: jnp.ndarray,
    base: float,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """x:(N,F) f32 (N % block_n == 0); A:(T,F,I); B:(T,I); C:(T,I,L);
    D:(T,L); V:(T,L). Returns (N,) raw ensemble scores."""
    N, F = x.shape
    T, _, I = A.shape
    L = C.shape[2]
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n, T)
    out = pl.pallas_call(
        functools.partial(_kernel, base=float(base)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, F), lambda n, t: (n, 0)),
            pl.BlockSpec((1, F, I), lambda n, t: (t, 0, 0)),
            pl.BlockSpec((1, I), lambda n, t: (t, 0)),
            pl.BlockSpec((1, I, L), lambda n, t: (t, 0, 0)),
            pl.BlockSpec((1, L), lambda n, t: (t, 0)),
            pl.BlockSpec((1, L), lambda n, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda n, t: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        A.astype(jnp.float32),
        B.astype(jnp.float32),
        C.astype(jnp.float32),
        D.astype(jnp.float32),
        V.astype(jnp.float32),
    )
    return out[:, 0]
