"""Pallas TPU kernel: tiled online-softmax (flash) attention with GQA.

Grid (B, H, Sq/BQ, Skv/BK), KV innermost; the running max / normalizer / un-
normalized accumulator live in VMEM scratch across KV steps and the output
block is written once on the last KV step. K/V blocks stream HBM→VMEM; the
two contractions (q·kᵀ and p·v) hit the MXU. Causal masking is applied
in-block (upper-triangular blocks still run but contribute nothing; the
XLA-path roofline is unaffected since dry-runs use the jnp reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, bq: int, bk: int, skv: int, sq: int,
):
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :] * scale  # (BQ, D)
    k = k_ref[0, :, 0, :]  # (BK, D)
    v = v_ref[0, :, 0, :]  # (BK, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)

    if causal:
        i = pl.program_id(2)
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (skv - sq)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]  # (BQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)  # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)  # (BQ, 1)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q:(B,Sq,H,D); k,v:(B,Skv,KH,D), H % KH == 0. Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    grid = (B, H, Sq // bq, Skv // bk)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, bq=bq, bk=bk, skv=Skv, sq=Sq
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "use_pallas", "interpret")
)
def flash_attention_op(
    q, k, v, *, causal: bool = True, scale: float | None = None,
    use_pallas: bool | None = None, interpret: bool = False,
):
    from repro.kernels import ref as _ref

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not (use_pallas or interpret):
        return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention(
        q, k, v, causal=causal, scale=scale, interpret=interpret
    )
