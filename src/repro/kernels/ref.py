"""Pure-jnp oracles for every Pallas kernel (CPU-checkable ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_gemm_ref(x, A, B, C, D, V, base: float) -> jnp.ndarray:
    """GEMM-strategy tree inference. x:(N,F); A:(T,F,I); B:(T,I); C:(T,I,L);
    D:(T,L); V:(T,L) -> (N,) raw scores."""
    S = jnp.einsum("nf,tfi->nti", x.astype(jnp.float32), A)
    dec = (S <= B[None]).astype(jnp.float32)
    P = jnp.einsum("nti,til->ntl", dec, C)
    match = (P == D[None]).astype(jnp.float32)
    return jnp.einsum("ntl,tl->n", match, V) + base


def featurize_ref(num, cat, offset, scale, cat_values, cat_segments):
    """Fused scaler + one-hot + concat.

    num:(N,Kn) f32; cat:(N,Kc) int32; offset/scale:(Kn,);
    cat_values:(Vtot,) concatenated category values;
    cat_segments: list of (start, length) per categorical column.
    Output: (N, Kn + Vtot) f32, numerics first.
    """
    parts = [(num.astype(jnp.float32) - offset) * scale]
    for j, (s, l) in enumerate(cat_segments):
        vals = jax.lax.dynamic_slice_in_dim(cat_values, s, l)
        parts.append((cat[:, j : j + 1] == vals[None, :]).astype(jnp.float32))
    return jnp.concatenate(parts, axis=1)


def gather_join_ref(fk, skeys, spay):
    """Dim-table equi-join gather oracle (unique, pre-sorted dim keys).

    fk:(N,) int32 fact keys; skeys:(M,) int32 sorted unique dim keys;
    spay:(M,P) f32 payload aligned to ``skeys``. Returns ``(out, hit)`` —
    out:(N,P) f32 (zero on miss, so the oracle and the one-hot-matmul kernel
    agree bitwise on *every* row, not just hits), hit:(N,) bool.
    """
    pos = jnp.clip(jnp.searchsorted(skeys, fk), 0, skeys.shape[0] - 1)
    hit = skeys[pos] == fk
    out = jnp.where(hit[:, None], spay[pos], jnp.float32(0.0))
    return out, hit


def segment_agg_ref(vals, w, sid, *, num_segments):
    """Masked segmented aggregate oracle.

    vals:(N,C) f32; w:(N,) f32 validity weights (the fused filter mask);
    sid:(N,) int32 segment ids in ``[0, num_segments)``. Returns
    ``(counts, sums, mins, maxs)`` with the same shapes/semantics as the
    Pallas kernel: counts:(S,), sums:(S,C) weighted sums, mins/maxs:(S,C)
    masked extrema (+inf/-inf for segments with no valid rows).
    """
    S = num_segments
    wf = w.astype(jnp.float32)
    vf = vals.astype(jnp.float32)
    if S == 1:
        # global fold: plain reductions, not a scatter of N rows into one
        # slot (XLA lowers segment_* to scatter-adds, which on CPU are far
        # slower than a tree reduce)
        if vf.shape[0] == 0:
            return (
                jnp.zeros((1,), jnp.float32),
                jnp.zeros((1, vf.shape[1]), jnp.float32),
                jnp.full((1, vf.shape[1]), jnp.inf, jnp.float32),
                jnp.full((1, vf.shape[1]), -jnp.inf, jnp.float32),
            )
        valid1 = (wf > 0)[:, None]
        counts = jnp.sum(wf)[None]
        sums = jnp.sum(vf * wf[:, None], axis=0)[None]
        mins = jnp.min(jnp.where(valid1, vf, jnp.inf), axis=0)[None]
        maxs = jnp.max(jnp.where(valid1, vf, -jnp.inf), axis=0)[None]
        return counts, sums, mins, maxs
    counts = jax.ops.segment_sum(wf, sid, num_segments=S)
    sums = jax.ops.segment_sum(vf * wf[:, None], sid, num_segments=S)
    valid = (wf > 0)[:, None]
    mins = jax.ops.segment_min(
        jnp.where(valid, vf, jnp.inf), sid, num_segments=S
    )
    maxs = jax.ops.segment_max(
        jnp.where(valid, vf, -jnp.inf), sid, num_segments=S
    )
    return counts, sums, mins, maxs


def flash_attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """Full-softmax attention oracle. q:(B,Sq,H,D) k,v:(B,Skv,KH,D) with GQA
    (H % KH == 0). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Sq, KH, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Sq)[:, None] + (Skv - Sq) >= jnp.arange(Skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths, scale: float | None = None):
    """Single-token decode attention oracle.

    q:(B,H,D); k_cache,v_cache:(B,S,KH,D); lengths:(B,) valid KV lengths.
    Returns (B,H,D)."""
    B, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(B, KH, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B,S)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
