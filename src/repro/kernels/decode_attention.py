"""Pallas TPU kernel: batched single-token decode attention over a KV cache.

The decode_32k serve_step hotspot: one query token per sequence attends over
a long KV cache. Memory-bound by the cache read, so the kernel streams KV
blocks HBM→VMEM once, carries the online-softmax state in VMEM scratch, and
masks by per-sequence cache length. Grid (B, KH, S/BK): per-(batch, kv-head)
all G grouped query heads are processed together so each KV block is read
exactly once per group — the minimal-traffic schedule for GQA decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, bk: int, g: int,
):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # (G, D) — grouped heads of this kv head
    k = k_ref[0, :, 0, :]  # (BK, D)
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, BK)
    kv_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
    valid = kv_pos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q:(B,H,D); k_cache,v_cache:(B,S,KH,D); lengths:(B,) → (B,H,D)."""
    B, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bk = min(block_k, S)
    assert S % bk == 0
    qg = (q * scale).reshape(B, KH, G, D)
    grid = (B, KH, S // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, g=G),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32).reshape(B, 1), qg, k_cache, v_cache)
    return out.reshape(B, H, D)


@functools.partial(jax.jit, static_argnames=("scale", "use_pallas", "interpret"))
def decode_attention_op(
    q, k_cache, v_cache, lengths, *, scale: float | None = None,
    use_pallas: bool | None = None, interpret: bool = False,
):
    from repro.kernels import ref as _ref

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not (use_pallas or interpret):
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale)
    return decode_attention(q, k_cache, v_cache, lengths, scale=scale, interpret=interpret)
