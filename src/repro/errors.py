"""Typed, message-bearing errors for the prediction-query front door.

The SQL frontend and session API raise these instead of leaking raw
``KeyError``/``IndexError`` from internal dict lookups, so callers can catch
one family (``RavenError``) or a specific failure mode.

``SQLSyntaxError`` also subclasses :class:`SyntaxError` for backward
compatibility with callers that caught the parser's original exception type.
"""
from __future__ import annotations


class RavenError(Exception):
    """Base class for all prediction-query API errors."""


class SQLSyntaxError(RavenError, SyntaxError):
    """Malformed query text (including a malformed PREDICT clause)."""


class UnknownModelError(RavenError):
    """PREDICT references a model name absent from the registry."""


class UnknownModelVersionError(UnknownModelError):
    """A ``name@version`` reference names a version never published.

    Subclasses :class:`UnknownModelError` so callers catching the model
    family see both; the message distinguishes "no such model" from "model
    exists, version doesn't"."""


class RegistryStateError(RavenError):
    """A model-lifecycle operation was attempted from an invalid state.

    Raised by the :class:`~repro.serve.registry.ModelRegistry` when a
    transition violates the ``published → warming → ready → live → retired``
    state machine — e.g. cutting over to a version that is not warm
    (``cutover(require_warm=True)`` with cold buckets outstanding), staging
    a version whose scan columns are incompatible with the live route, or
    retiring the live version."""


class UnknownTableError(RavenError):
    """Query references a table absent from the database."""


class UnknownColumnError(RavenError):
    """Predicate or join key references a column no table provides."""


class UnboundParameterError(RavenError):
    """A ``:param`` placeholder was left unbound at prepare/execute time."""


class UnknownParameterError(RavenError):
    """``bind``/``rebind`` named a parameter the query does not declare."""


class UnknownQueryError(RavenError):
    """``submit``/``rebind`` named a query never registered with the server."""


class ServerOverloadedError(RavenError):
    """A bounded queue (``serve(max_pending=...)``) rejected a submit.

    Raised by ``submit(..., block=False)`` the moment a query's pending
    queue is full, or by a blocking submit whose ``timeout`` expired before
    the scheduler freed space. Backpressure instead of unbounded queueing:
    the caller sheds load (or retries) rather than the server accumulating
    an ever-deeper backlog it can never serve within its latency targets."""


class TransientError(RavenError):
    """A failure that is safe to retry: the request group is still intact
    and a re-dispatch of the same group may succeed (injected fault, dead
    scheduler worker, torn artifact read). The scheduler's
    :class:`~repro.exec.faults.RetryPolicy` only ever retries errors in
    this family — anything else is treated as deterministic and fails the
    group immediately."""


class FaultInjectedError(RavenError):
    """An error raised by the deterministic fault-injection harness
    (:mod:`repro.exec.faults`). ``site`` names the injection point."""

    def __init__(self, site: str, token: str = ""):
        at = f" at {token}" if token else ""
        super().__init__(f"injected fault at site '{site}'{at}")
        self.site = site
        self.token = token


class TransientFaultError(FaultInjectedError, TransientError):
    """An injected fault marked retryable (``FaultSpec(transient=True)``)."""


class RequestTimeoutError(RavenError):
    """``QueryRequest.wait(timeout=...)`` expired before the request
    settled. The request itself is *not* cancelled — it may still complete
    (or fail) later; the caller can wait again."""


class RequestFailedError(RavenError):
    """Terminal serving failure delivered to every waiter in a dispatch
    group: the group's retries are exhausted (or the error was never
    retryable) and the request will not produce a result. ``attempts``
    counts dispatch attempts; the underlying error is ``__cause__``."""

    def __init__(self, message: str, attempts: int = 1):
        super().__init__(message)
        self.attempts = attempts


class RecoveryError(RavenError):
    """``Session.recover()`` could not restore the registry from disk —
    no journal exists under this registry fingerprint, the journal was
    quarantined as corrupt, or it was written by an incompatible store."""


class PlanVerificationError(RavenError):
    """The static plan verifier rejected a plan (``verify='strict'``).

    Carries the typed :class:`~repro.analysis.rules.Violation` list in
    ``violations`` — each names the rule that fired and, for differential
    checks, the optimizer rewrite rule that introduced the breakage."""

    def __init__(self, message: str, violations=None):
        super().__init__(message)
        self.violations = list(violations or [])


class StaleQueryError(RavenError):
    """A served handle no longer matches the registration under its name.

    Raised when ``PreparedQuery.submit`` (or ``QueryServer.submit`` with
    ``expect_token``) targets a name that has since been re-registered —
    with a different physical plan *or* different bound parameter values
    (plan fingerprints are deliberately param-invariant, so the guard keys
    on the registration itself) — serving through the stale handle would
    silently answer with the wrong query."""


def check_params(
    declared, bound, *, require_all: bool = True, context: str = "query"
) -> None:
    """Validate a parameter binding against a query's declared ``:params``.

    ``require_all=True`` (prepare/register) demands every declared parameter
    is bound; ``require_all=False`` (bind/rebind) allows partial re-binds.
    Unknown names are always rejected.
    """
    declared, bound = set(declared), set(bound)
    if require_all:
        missing = declared - bound
        if missing:
            raise UnboundParameterError(
                f"{context} has unbound parameters {sorted(missing)} — "
                f"bind them via params={{...}}"
            )
    unknown = bound - declared
    if unknown:
        raise UnknownParameterError(
            f"{context} declares no parameters {sorted(unknown)}; "
            f"its parameters are {sorted(declared) or '(none)'}"
        )
