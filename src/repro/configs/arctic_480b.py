"""arctic-480b [moe]: 128 experts top-2 + dense residual FFN in parallel.
[hf:Snowflake/snowflake-arctic-base; hf]

Memory fitting: bf16 Adam moments, FSDP + 16-way EP over `model`.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe_experts=128,
    moe_top_k=2,
    moe_dense_residual=True,
    optimizer_dtype="bfloat16",
    rope_theta=1e6,
    accum_steps=8,
    act_shard="seq",
    long_context="skip",
)
