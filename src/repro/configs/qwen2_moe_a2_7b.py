"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared experts
(always-on, fused as one 4x-wide shared FFN). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    moe_experts=60,
    moe_top_k=4,
    moe_shared_experts=4,
    moe_shared_d_ff=5632,
    # beyond-paper perf: pad expert dim to 64 so EP shards over model=16
    # (60 % 16 != 0 left experts replicated — EXPERIMENTS.md §Perf/moe it.3)
    moe_pad_experts=64,
    rope_theta=1e6,
    accum_steps=2,
    long_context="skip",
)
