"""llama3-405b [dense]: GQA kv=8, 128k vocab. [arXiv:2407.21783; unverified]

Memory fitting (DESIGN.md §4): bf16 Adam moments, FSDP over data axis.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=5e5,
    optimizer_dtype="bfloat16",
    accum_steps=16,
    # act_shard="seq" measured 10x WORSE collectives at this scale: the SP
    # resharding constraints make the partitioner all-gather full un-TP'd
    # f32 weights in the backward dots (EXPERIMENTS.md §Perf/llama it.1).
    act_shard="none",
    long_context="skip",
)
