"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (7:1 ratio -> every 8th layer is
sLSTM). d_ff=0: xLSTM blocks have no separate MLP. [arXiv:2405.04517;
unverified] Runs long_500k (recurrent state decode)."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    ssm_chunk=128,
    rope_theta=0.0,
    long_context="run",
)
