"""Config registry: the 10 assigned architectures + reduced smoke variants.

``get_config(name)`` returns the full published config; ``reduced_config``
returns a same-family miniature (few layers, narrow width, tiny vocab, few
experts) for CPU smoke tests — full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.base import ArchConfig

ARCHS = [
    "whisper-small",
    "qwen2-0.5b",
    "granite-3-8b",
    "llama3-405b",
    "minitron-4b",
    "llava-next-34b",
    "xlstm-350m",
    "arctic-480b",
    "qwen2-moe-a2.7b",
    "zamba2-7b",
]

_MODULES = {
    "whisper-small": "whisper_small",
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-3-8b": "granite_3_8b",
    "llama3-405b": "llama3_405b",
    "minitron-4b": "minitron_4b",
    "llava-next-34b": "llava_next_34b",
    "xlstm-350m": "xlstm_350m",
    "arctic-480b": "arctic_480b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "zamba2-7b": "zamba2_7b",
}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced_config(name: str, dtype: str = "float32") -> ArchConfig:
    """Miniature same-family config for CPU smoke tests."""
    cfg = get_config(name)
    common = {
        "d_model": 64,
        "n_heads": 4,
        "n_kv_heads": 2 if cfg.n_kv_heads < cfg.n_heads else 4,
        "vocab_size": 128,
        "dtype": dtype,
        "remat": False,
    }
    if cfg.family == "encdec":
        return dataclasses.replace(
            cfg, n_layers=2, encoder_layers=2, d_ff=128,
            frontend_tokens=32, **common
        )
    if cfg.family == "moe":
        return dataclasses.replace(
            cfg,
            n_layers=2,
            d_ff=32,
            moe_experts=8,
            moe_top_k=2,
            moe_shared_d_ff=64 if cfg.moe_shared_experts else 0,
            **common,
        )
    if cfg.family == "ssm":
        return dataclasses.replace(
            cfg, n_layers=4, d_ff=0, slstm_every=2, ssm_chunk=32, **common
        )
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg,
            n_layers=5,
            d_ff=128,
            attn_every=2,
            ssm_state=16,
            ssm_heads=8,   # d_inner 128 / head dim 16
            ssm_chunk=32,
            sliding_window=64,
            **common,
        )
    # dense / vlm
    extra = {"frontend_tokens": 16} if cfg.frontend == "vision" else {}
    return dataclasses.replace(cfg, n_layers=2, d_ff=128, **extra, **common)
