"""zamba2-7b [hybrid]: Mamba2 stack + ONE shared attention/MLP block applied
after every 6 SSM layers (weight sharing), sliding-window KV (the SSM carries
long-range state). ssm_state=64. [arXiv:2411.15242; unverified]
Runs long_500k (O(1)-in-seq decode via recurrent state + windowed KV)."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=112,   # d_inner 7168 / head dim 64
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,
    sliding_window=4096,
    rope_theta=1e4,
    accum_steps=4,
    long_context="run",
)
