"""whisper-small [audio]: enc-dec, conv frontend STUB (precomputed frame
embeddings). [arXiv:2212.04356; unverified]

Deviations: encoder positions sinusoidal (as whisper), decoder uses RoPE
instead of learned positions so 32k decode shapes are well-defined
(whisper's learned table stops at 448) — noted in DESIGN.md.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    qkv_bias=True,
    mlp_act="gelu",
    frontend="audio",
    frontend_tokens=1500,
    rope_theta=1e4,
    long_context="skip",
)
