"""llava-next-34b [vlm]: anyres tiling backbone; vision frontend STUB
(precomputed patch embeddings + learned projector).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    frontend_tokens=576,
    rope_theta=1e6,
    accum_steps=8,
    act_shard="seq",
    long_context="skip",
)
