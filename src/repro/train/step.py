"""Jitted step builders: train_step / prefill_step / serve_step.

These are what the dry-run lowers and what the real launchers execute.
Gradient accumulation runs microbatches under lax.scan (grads live in f32
accumulators, model activations in bf16); the optimizer update is fused into
the same program so params/opt-state never leave the device between steps.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.train.optimizer import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
)


def init_opt_state(model, params_or_shapes, materialize: bool = True):
    cfg = model.cfg
    init = adamw_init if cfg.optimizer == "adamw" else adafactor_init
    if materialize:
        return init(params_or_shapes, cfg.optimizer_dtype)
    return jax.eval_shape(
        lambda p: init(p, cfg.optimizer_dtype), params_or_shapes
    )


def make_train_step(model, mesh=None, lr: float = 3e-4, accum_steps: int = 1):
    cfg = model.cfg
    update = adamw_update if cfg.optimizer == "adamw" else adafactor_update

    def loss_fn(params, batch):
        return model.loss(params, batch, mesh=mesh)

    acc_dtype = (
        jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16" else jnp.float32
    )

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # microbatch over the leading batch dim; accumulators in the
            # optimizer dtype (bf16 for the giants — see DESIGN.md §4)
            inv = 1.0 / accum_steps

            def micro(carry, mb):
                acc, tot = carry
                # scale inside the loss: no whole-tree divide afterwards
                l, g = jax.value_and_grad(
                    lambda p, b: loss_fn(p, b) * inv
                )(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(acc_dtype), acc, g
                )
                return (acc, tot + l), None

            split = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (grads, tot), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), split)
            loss = tot
        new_params, new_opt = update(grads, opt_state, params, lr=lr)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model, mesh=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, mesh=mesh)

    return prefill_step


def make_serve_step(model, mesh=None):
    """One decode step: greedy next token + updated caches."""

    def serve_step(params, batch, caches):
        logits, caches = model.decode(params, batch, caches, mesh=mesh)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, caches

    return serve_step
