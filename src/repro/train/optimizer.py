"""Optimizers: AdamW (configurable moment dtype) and Adafactor (factored).

Moment dtype is per-arch config — the 405B/480B archs use bf16 moments so
(params + m + v) · 6 B/param FSDP-shards under the v5e HBM budget
(DESIGN.md §4). Updates always compute in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _moment_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params, moment_dtype: str = "float32"):
    dt = _moment_dtype(moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, opt_state, params,
    lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
    eps: float = 1e-8, weight_decay: float = 0.1,
):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment for matrices; memory ~ O(rows+cols))
# ---------------------------------------------------------------------------


def adafactor_init(params, moment_dtype: str = "float32"):
    dt = _moment_dtype(moment_dtype)

    def st(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], dt),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt),
            }
        return {"v": jnp.zeros(p.shape, dt)}

    return {
        "f": jax.tree.map(st, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(
    grads, opt_state, params, lr: float = 3e-4, eps: float = 1e-30,
    decay: float = 0.8, clip: float = 1.0,
):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(st, g, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if p.ndim >= 2:
            vr = beta * st["vr"].astype(jnp.float32) + (1 - beta) * g2.mean(-1)
            vc = beta * st["vc"].astype(jnp.float32) + (1 - beta) * g2.mean(-2)
            denom = (
                vr[..., :, None] * vc[..., None, :]
                / jnp.maximum(vr.mean(-1)[..., None, None], eps)
            )
            u = gf * jax.lax.rsqrt(denom + eps)
            new_st = {"vr": vr.astype(st["vr"].dtype), "vc": vc.astype(st["vc"].dtype)}
        else:
            v = beta * st["v"].astype(jnp.float32) + (1 - beta) * g2
            u = gf * jax.lax.rsqrt(v + eps)
            new_st = {"v": v.astype(st["v"].dtype)}
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, new_st

    is_st = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    # map over the factored-state tree (is_leaf stops at each {vr,vc}/{v}
    # dict); grads/params subtrees at those paths are the matching arrays
    out = jax.tree.map(upd, opt_state["f"], grads, params, is_leaf=is_st)
    # out leaves are tuples (p_new, state)
    new_params = jax.tree.map(
        lambda t2: t2[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_f = jax.tree.map(
        lambda t2: t2[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return new_params, {"f": new_f, "step": step}
