from repro.train.optimizer import adamw_init, adamw_update, adafactor_init, adafactor_update
from repro.train.step import make_train_step, make_serve_step, make_prefill_step
