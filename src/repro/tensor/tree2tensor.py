"""Tree ensembles → tensor programs (two strategies, as in Hummingbird).

GEMM strategy — the MXU-native one (see DESIGN.md §2): trees become three
dense contractions

    S = X · A          (N,F)·(T,F,I) -> (N,T,I)   split-feature values
    D = (S <= B)                                   decisions
    P = D · C          (N,T,I)·(T,I,L) -> (N,T,L)  path scores
    leaf = (P == Dcount)                           exact-path match
    y = Σ_t leaf · V   + base

All shapes are padded: I (internal nodes) and L (leaves) to the ensemble max
(and to MXU-friendly multiples via the Pallas kernel's BlockSpecs).

Traversal strategy — iterative gather-stepping over padded node arrays
(better for deep/narrow trees where the GEMM's O(F·I + I·L) work explodes).
The runtime-selection corpus (paper §5.2) learns the crossover.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.ml.trees import LEAF, TreeEnsemble


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass
class GemmTreeProgram:
    A: np.ndarray  # (T, F, I) f32
    B: np.ndarray  # (T, I)    f32 thresholds
    C: np.ndarray  # (T, I, L) f32 in {-1,0,1}
    Dcount: np.ndarray  # (T, L) f32 — left-ancestor counts per leaf
    V: np.ndarray  # (T, L) f32 — leaf values × tree weight
    base: float
    post: str
    n_features: int

    @property
    def padded_dims(self) -> tuple[int, int, int]:
        return self.A.shape[1], self.A.shape[2], self.C.shape[2]


def build_gemm_program(
    ens: TreeEnsemble, pad_to: int = 8
) -> GemmTreeProgram:
    slices = ens.tree_slices()
    T = ens.n_trees
    # per-tree internal/leaf enumeration
    internals, leaves = [], []
    for sl in slices:
        ids = np.arange(sl.start, sl.stop)
        internals.append(ids[ens.feature[sl] != LEAF])
        leaves.append(ids[ens.feature[sl] == LEAF])
    I = _round_up(max(max(len(i) for i in internals), 1), pad_to)
    L = _round_up(max(max(len(l) for l in leaves), 1), pad_to)
    F = ens.n_features

    A = np.zeros((T, F, I), dtype=np.float32)
    B = np.full((T, I), np.float32(np.inf))  # padded nodes: x<=inf -> left, harmless
    C = np.zeros((T, I, L), dtype=np.float32)
    Dc = np.full((T, L), np.float32(-1.0))  # padded leaves can never match
    V = np.zeros((T, L), dtype=np.float32)

    for t, sl in enumerate(slices):
        int_ids = {int(n): k for k, n in enumerate(internals[t])}
        leaf_ids = {int(n): k for k, n in enumerate(leaves[t])}
        for n, k in int_ids.items():
            A[t, int(ens.feature[n]), k] = 1.0
            B[t, k] = np.float32(ens.threshold[n])
        w = float(ens.tree_weight[t])
        for n, l in leaf_ids.items():
            V[t, l] = np.float32(w * ens.leaf_value[n])
        # ancestor walk: root-to-leaf paths
        def paths(node, acc, t=t):
            if ens.feature[node] == LEAF:
                l = leaf_ids[int(node)]
                Dc[t, l] = np.float32(sum(1 for _, d in acc if d == 1))
                for anc, d in acc:
                    C[t, int_ids[anc], l] = np.float32(1.0 if d == 1 else -1.0)
                return
            paths(int(ens.left[node]), acc + [(int(node), 1)])
            paths(int(ens.right[node]), acc + [(int(node), 0)])

        import sys

        lim = sys.getrecursionlimit()
        sys.setrecursionlimit(max(lim, (sl.stop - sl.start) * 4 + 100))
        try:
            paths(sl.start, [])
        finally:
            sys.setrecursionlimit(lim)

    return GemmTreeProgram(
        A=A, B=B, C=C, Dcount=Dc, V=V,
        base=float(ens.base_score),
        post=ens.post_transform,
        n_features=F,
    )


def gemm_predict(prog: GemmTreeProgram, X: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp GEMM-strategy inference (also the Pallas kernel's oracle)."""
    S = jnp.einsum("nf,tfi->nti", X.astype(jnp.float32), prog.A)
    D = (S <= prog.B[None]).astype(jnp.float32)
    P = jnp.einsum("nti,til->ntl", D, prog.C)
    match = (P == prog.Dcount[None]).astype(jnp.float32)
    raw = jnp.einsum("ntl,tl->n", match, prog.V) + prog.base
    return raw


@dataclass
class TraversalTreeProgram:
    feature: np.ndarray  # (T, Nmax) int32, -1 for leaf (self-looping children)
    threshold: np.ndarray  # (T, Nmax) f32
    left: np.ndarray  # (T, Nmax) int32 (tree-local)
    right: np.ndarray  # (T, Nmax) int32
    leaf_value: np.ndarray  # (T, Nmax) f32 (× tree weight)
    max_depth: int
    base: float
    post: str
    n_features: int


def build_traversal_program(ens: TreeEnsemble) -> TraversalTreeProgram:
    slices = ens.tree_slices()
    T = ens.n_trees
    Nmax = max(sl.stop - sl.start for sl in slices)
    feature = np.full((T, Nmax), -1, dtype=np.int32)
    threshold = np.zeros((T, Nmax), dtype=np.float32)
    left = np.zeros((T, Nmax), dtype=np.int32)
    right = np.zeros((T, Nmax), dtype=np.int32)
    leaf_value = np.zeros((T, Nmax), dtype=np.float32)
    for t, sl in enumerate(slices):
        n = sl.stop - sl.start
        feature[t, :n] = ens.feature[sl]
        threshold[t, :n] = ens.threshold[sl]
        left[t, :n] = ens.left[sl] - sl.start
        right[t, :n] = ens.right[sl] - sl.start
        w = float(ens.tree_weight[t])
        leaf_value[t, :n] = w * ens.leaf_value[sl]
        # leaves self-loop (already true in TreeEnsemble, re-localized)
        is_leaf = feature[t, :n] == -1
        idx = np.arange(n, dtype=np.int32)
        left[t, :n] = np.where(is_leaf, idx, left[t, :n])
        right[t, :n] = np.where(is_leaf, idx, right[t, :n])
    return TraversalTreeProgram(
        feature=feature,
        threshold=threshold,
        left=left,
        right=right,
        leaf_value=leaf_value,
        max_depth=int(ens.max_depth()),
        base=float(ens.base_score),
        post=ens.post_transform,
        n_features=ens.n_features,
    )


def traversal_predict(prog: TraversalTreeProgram, X: jnp.ndarray) -> jnp.ndarray:
    """Vectorized gather-stepping over (batch × trees)."""
    X = X.astype(jnp.float32)
    n = X.shape[0]
    T = prog.feature.shape[0]
    feature = jnp.asarray(prog.feature)
    threshold = jnp.asarray(prog.threshold)
    left = jnp.asarray(prog.left)
    right = jnp.asarray(prog.right)
    leaf_value = jnp.asarray(prog.leaf_value)
    t_idx = jnp.arange(T)[None, :]  # broadcast over batch

    def step(_, node):  # node: (n, T) tree-local ids
        f = feature[t_idx, node]  # (n, T)
        thr = threshold[t_idx, node]
        xv = jnp.take_along_axis(X, jnp.maximum(f, 0), axis=1)  # (n, T)
        go_left = xv <= thr
        return jnp.where(go_left, left[t_idx, node], right[t_idx, node])

    node0 = jnp.zeros((n, T), dtype=jnp.int32)
    node = jax.lax.fori_loop(0, max(prog.max_depth, 1), step, node0)
    return leaf_value[t_idx, node].sum(axis=1) + prog.base
