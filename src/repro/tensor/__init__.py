"""Tensor runtime: Hummingbird-style compilation of traditional ML to fused
tensor programs (the MLtoDNN target, paper §5.1)."""
from repro.tensor.tree2tensor import (
    GemmTreeProgram,
    TraversalTreeProgram,
    build_gemm_program,
    build_traversal_program,
)
from repro.tensor.compile import compile_pipeline_tensor
