"""Compile a TrainedPipeline into one fused jittable tensor program.

This is the MLtoDNN target (paper §5.1, via Hummingbird): featurizers become
vectorized jnp ops, tree ensembles become GEMM or gather-traversal programs
(strategy picked per-ensemble, Hummingbird-style: GEMM for shallow/wide on
the MXU, traversal for deep/narrow), and the whole thing is one closure that
XLA fuses — the "DNN runtime" execution of the model.

On TPU the tree-GEMM and featurize steps dispatch to the Pallas kernels in
:mod:`repro.kernels`; on CPU they run the pure-jnp oracles (same math).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.ml.pipeline import TrainedPipeline
from repro.ml.trees import TreeEnsemble
from repro.tensor.tree2tensor import (
    build_gemm_program,
    build_traversal_program,
    gemm_predict,
    traversal_predict,
)


@dataclass
class TensorCompilation:
    fn: Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]
    strategy: dict[str, str]  # model output name -> chosen tree strategy
    n_ops: int
    # columns the fused program consumes — surfaced so the StageGraph can
    # infer schema through an otherwise-opaque TensorOp closure
    input_names: tuple[str, ...] = ()
    # values produced by a scaler/one-hot/concat chain collapsed into the
    # fused Pallas featurize kernel (jnp oracle on CPU)
    fused: tuple[str, ...] = ()


# ---------------------------------------------------------------------------
# Coverage predicate (drives the pipeline-splitting partial lowering)
# ---------------------------------------------------------------------------

_SUPPORTED_OPS = frozenset(
    {
        "concat",
        "scaler",
        "one_hot",
        "label_encode",
        "feature_extractor",
        "constant",
        "normalizer",
        "tree_ensemble",
        "linear",
    }
)


def tensor_supported(node) -> bool:
    """Can this pipeline node run in the tensor runtime?

    Unknown ops (e.g. ``python_udf`` — an opaque host callable) are out, as
    are encoders over string/object categories: numpy compares strings fine
    on host, but a jnp program cannot hold them. These are exactly the nodes
    the split analysis routes to the host residual.
    """
    if node.op not in _SUPPORTED_OPS:
        return False
    if node.op == "one_hot":
        return np.asarray(node.attrs["categories"]).dtype.kind not in "OUSV"
    if node.op == "label_encode":
        return np.asarray(node.attrs["classes"]).dtype.kind not in "OUSV"
    return True


# ---------------------------------------------------------------------------
# Fused-featurize targeting: scaler/one-hot/concat chains -> Pallas kernel
# ---------------------------------------------------------------------------


def _detect_featurize_fusions(pipe: TrainedPipeline):
    """Find concat nodes whose whole input chain is the standard featurize
    pattern — ``concat(scaler(concat(numerics)), one_hot(c1), ...)`` over
    graph inputs — and describe each as one fused kernel call.

    Returns ``(fusions, swallowed)``: ``fusions`` maps the id() of each
    fusable final concat node to its kernel arguments; ``swallowed`` holds
    the ids of chain members replaced by the fused step. Intermediates must
    be single-consumer and not graph outputs, so fusing never orphans a
    value. The numeric part, when present, must be the concat's first input
    (the kernel emits numerics-first layout).
    """
    graph_inputs = {s.name for s in pipe.inputs}
    producer = {o: n for n in pipe.nodes for o in n.outputs}
    n_consumers: dict[str, int] = {}
    for n in pipe.nodes:
        for v in n.inputs:
            n_consumers[v] = n_consumers.get(v, 0) + 1
    out_set = set(pipe.outputs)

    def _single_use_intermediate(v: str) -> bool:
        return n_consumers.get(v, 0) == 1 and v not in out_set

    fusions: dict[int, dict] = {}
    swallowed: set[int] = set()
    for node in pipe.nodes:
        if node.op != "concat" or not node.inputs or id(node) in swallowed:
            continue
        numeric: list[str] = []
        offset = scale = None
        cat_cols: list[str] = []
        cat_vals: list[np.ndarray] = []
        segments: list[tuple[int, int]] = []
        members: list = []
        start = 0
        ok = True
        for pos, v in enumerate(node.inputs):
            p = producer.get(v)
            if p is None or not _single_use_intermediate(v):
                ok = False
                break
            if p.op == "scaler" and pos == 0 and not numeric:
                src = producer.get(p.inputs[0])
                if (
                    src is None
                    or src.op != "concat"
                    or not _single_use_intermediate(p.inputs[0])
                    or not src.inputs
                    or any(c not in graph_inputs or c in producer for c in src.inputs)
                ):
                    ok = False
                    break
                offset = np.asarray(p.attrs["offset"], np.float32).reshape(-1)
                scale = np.asarray(p.attrs["scale"], np.float32).reshape(-1)
                if offset.shape[0] != len(src.inputs):
                    ok = False
                    break
                numeric = list(src.inputs)
                members += [src, p]
            elif p.op == "one_hot":
                src_col = p.inputs[0]
                cats = np.asarray(p.attrs["categories"])
                if (
                    src_col not in graph_inputs
                    or src_col in producer
                    or cats.dtype.kind not in "iu"
                ):
                    ok = False
                    break
                segments.append((start, int(cats.shape[0])))
                start += int(cats.shape[0])
                cat_vals.append(cats.astype(np.int32))
                cat_cols.append(src_col)
                members.append(p)
            else:
                ok = False
                break
        if not ok or len(members) < 2:
            continue
        fusions[id(node)] = {
            "numeric": tuple(numeric),
            "offset": offset if offset is not None else np.zeros(0, np.float32),
            "scale": scale if scale is not None else np.zeros(0, np.float32),
            "categorical": tuple(cat_cols),
            "cat_values": (
                np.concatenate(cat_vals)
                if cat_vals
                else np.zeros(0, np.int32)
            ),
            "segments": tuple(segments),
            "out": node.outputs[0],
        }
        swallowed.update(id(m) for m in members)
    return fusions, swallowed


def _featurize_block_n(n_rows: int) -> int:
    """Row-block size for the fused kernel: the row count's power-of-two
    bucket (serving already pads batches to one), clamped to [8, 256] so the
    kernel never pads small batches up to a full 256-row block."""
    b = 1 << max(3, (max(n_rows, 1) - 1).bit_length())
    return min(b, 256)


def _choose_tree_strategy(ens: TreeEnsemble) -> str:
    """GEMM when padded matrices stay MXU-friendly; else gather traversal.

    Heuristic mirrors Hummingbird — and like Hummingbird's, it is
    hardware-specific: the GEMM strategy exists to feed matrix units
    (MXU/TensorCore); on a CPU backend its O(F·I + I·L) dense work loses to
    O(depth) gather-stepping by ~100x (measured, EXPERIMENTS.md §Perf), so
    CPU always picks traversal. The paper's §5.2 point — don't hard-code
    the crossover, learn it per hardware — is enforced by the strategy
    corpus measuring on the live backend either way.
    """
    import jax

    if jax.default_backend() != "tpu":
        return "traversal"
    slices = ens.tree_slices()
    max_nodes = max(sl.stop - sl.start for sl in slices)
    max_internal = (max_nodes + 1) // 2
    return "gemm" if max_internal <= 128 else "traversal"


def compile_pipeline_tensor(
    pipe: TrainedPipeline, strategy: str = "auto", use_pallas: bool | None = None
) -> TensorCompilation:
    # eager coverage validation: reject unsupported pipelines at compile
    # time, not at first trace inside the closure — the partial-lowering
    # path relies on this to decide splits before any plan is built
    bad = sorted({n.op for n in pipe.nodes if not tensor_supported(n)})
    if bad:
        raise ValueError(f"unsupported for tensor lowering: {', '.join(bad)}")

    fusions, swallowed = _detect_featurize_fusions(pipe)
    steps: list[tuple] = []  # (kind, node) in topo order — closed over below
    chosen: dict[str, str] = {}
    fused_outs: list[str] = []
    for node in pipe.nodes:
        if id(node) in swallowed:
            continue
        if id(node) in fusions:
            steps.append(("featurize", node, fusions[id(node)]))
            fused_outs.append(node.outputs[0])
        elif node.op == "tree_ensemble":
            ens = node.attrs["ensemble"]
            strat = strategy if strategy != "auto" else _choose_tree_strategy(ens)
            chosen[node.outputs[0]] = strat
            prog = (
                build_gemm_program(ens)
                if strat == "gemm"
                else build_traversal_program(ens)
            )
            steps.append((strat, node, prog))
        else:
            steps.append((node.op, node, None))

    input_names = list(pipe.input_names())
    outputs = list(pipe.outputs)

    def fn(cols: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        vals: dict[str, jnp.ndarray] = {}
        for name in input_names:
            x = cols[name]
            vals[name] = x[:, None] if x.ndim == 1 else x
        n = next(iter(vals.values())).shape[0] if vals else 0
        for kind, node, prog in steps:
            a = node.attrs
            if kind == "featurize":
                from repro.kernels.ops import featurize_op

                info = prog
                num = (
                    jnp.concatenate(
                        [vals[c].astype(jnp.float32) for c in info["numeric"]],
                        axis=1,
                    )
                    if info["numeric"]
                    else jnp.zeros((n, 0), jnp.float32)
                )
                cat = (
                    jnp.concatenate(
                        [vals[c].astype(jnp.int32) for c in info["categorical"]],
                        axis=1,
                    )
                    if info["categorical"]
                    else jnp.zeros((n, 0), jnp.int32)
                )
                vals[info["out"]] = featurize_op(
                    num, cat,
                    jnp.asarray(info["offset"]), jnp.asarray(info["scale"]),
                    jnp.asarray(info["cat_values"]), info["segments"],
                    block_n=_featurize_block_n(num.shape[0]),
                    use_pallas=use_pallas,
                )
            elif kind == "concat":
                vals[node.outputs[0]] = jnp.concatenate(
                    [vals[i].astype(jnp.float32) for i in node.inputs], axis=1
                )
            elif kind == "scaler":
                x = vals[node.inputs[0]].astype(jnp.float32)
                vals[node.outputs[0]] = (
                    x - jnp.asarray(a["offset"], jnp.float32)
                ) * jnp.asarray(a["scale"], jnp.float32)
            elif kind == "one_hot":
                x = vals[node.inputs[0]].reshape(-1)
                cats = jnp.asarray(np.asarray(a["categories"]))
                vals[node.outputs[0]] = (
                    x[:, None] == cats[None, :]
                ).astype(jnp.float32)
            elif kind == "label_encode":
                x = vals[node.inputs[0]].reshape(-1)
                vals[node.outputs[0]] = jnp.searchsorted(
                    jnp.asarray(np.asarray(a["classes"])), x
                )[:, None]
            elif kind == "feature_extractor":
                idx = jnp.asarray(np.asarray(a["indices"], dtype=np.int32))
                vals[node.outputs[0]] = vals[node.inputs[0]][:, idx]
            elif kind == "constant":
                v = jnp.asarray(
                    np.atleast_1d(np.asarray(a["value"], np.float32))
                )[None, :]
                vals[node.outputs[0]] = jnp.broadcast_to(v, (n, v.shape[1]))
            elif kind == "normalizer":
                x = vals[node.inputs[0]].astype(jnp.float32)
                if a["norm"] == "l1":
                    d = jnp.abs(x).sum(axis=1, keepdims=True)
                elif a["norm"] == "l2":
                    d = jnp.sqrt((x * x).sum(axis=1, keepdims=True))
                else:
                    d = jnp.abs(x).max(axis=1, keepdims=True)
                vals[node.outputs[0]] = x / jnp.where(d == 0.0, 1.0, d)
            elif kind in ("gemm", "traversal"):
                X = vals[node.inputs[0]].astype(jnp.float32)
                if kind == "gemm":
                    if use_pallas:
                        from repro.kernels.ops import pad_gemm_program, tree_gemm_op

                        A, B, C, D, V = pad_gemm_program(
                            prog.A, prog.B, prog.C, prog.Dcount, prog.V
                        )
                        raw = tree_gemm_op(
                            X, A, B, C, D, V, base=prog.base, use_pallas=True
                        )
                    else:
                        raw = gemm_predict(prog, X)
                else:
                    raw = traversal_predict(prog, X)
                score = (
                    1.0 / (1.0 + jnp.exp(-raw)) if prog.post == "logistic" else raw
                )
                thr = float(a.get("decision_threshold", 0.5))
                vals[node.outputs[0]] = score
                if len(node.outputs) > 1:
                    vals[node.outputs[1]] = (score >= thr).astype(jnp.int32)
            elif kind == "linear":
                X = vals[node.inputs[0]].astype(jnp.float32)
                w = jnp.asarray(np.asarray(a["weights"], np.float32))
                z = X @ w + jnp.float32(a["bias"])
                if a.get("post", "none") == "logistic":
                    z = 1.0 / (1.0 + jnp.exp(-z))
                thr = float(a.get("decision_threshold", 0.5))
                vals[node.outputs[0]] = z
                if len(node.outputs) > 1:
                    vals[node.outputs[1]] = (z >= thr).astype(jnp.int32)
            else:
                raise ValueError(kind)
        return {o: vals[o] for o in outputs}

    # canonical content token: the closure is a pure function of the
    # pipeline + compilation choices, so plans embedding it (TensorOp)
    # fingerprint stably across objects and processes instead of by id()
    from repro.core.fingerprint import fingerprint as _fingerprint

    fn.__fingerprint_token__ = _fingerprint(
        # "fz1" versions the fused-featurize emission so artifacts compiled
        # before chain fusion existed can never alias the new programs
        "tensor_compile", "fz1", pipe, strategy, use_pallas,
        sorted(chosen.items()), tuple(fused_outs),
    )
    fn.__input_names__ = tuple(input_names)
    return TensorCompilation(
        fn=fn, strategy=chosen, n_ops=len(steps),
        input_names=tuple(input_names), fused=tuple(fused_outs),
    )


# ---------------------------------------------------------------------------
# Relational kernel emission (targeted by the Join / Aggregate stage steps)
# ---------------------------------------------------------------------------
#
# The relational side of the kernel runtime lives here with the rest of the
# tensor-runtime codegen: the stage IR (exec/stages.py) decides *where* a
# Join or Filter→Aggregate chain sits in a pure stage, these helpers decide
# *how* it lowers — the Pallas gather-join / masked segmented-aggregate ops
# when shapes qualify, the legacy jnp composition otherwise. The upstream
# filter's validity mask is threaded in as the kernel mask, so Filter→Join
# and Filter→Aggregate chains fuse without materializing filtered rows.


def join_kernel_qualifies(plan, dim, fk, ds) -> bool:
    """Can this Join lower to the gather-join kernel? Requires the engine's
    baked dim-sort entry with its uniqueness marker (the one-hot matmul
    gather needs unique dim keys), integer keys on both sides, f32 payload
    columns, and at least one payload column to gather."""
    if ds is None or "unique" not in ds:
        return False
    if not plan.dim_columns:
        return False
    keys = dim[plan.dim_key]
    if not (
        jnp.issubdtype(keys.dtype, jnp.integer)
        and jnp.issubdtype(fk.dtype, jnp.integer)
    ):
        return False
    return all(dim[c].dtype == jnp.float32 for c in plan.dim_columns)


def emit_join_kernel(plan, dim, fk, ds):
    """Emit the gather-join kernel call for a qualifying Join. Returns
    ``(brought, hit)``: the gathered dim columns (zero where the key
    missed) and the per-row hit mask to AND into row validity."""
    from repro.kernels.ops import gather_join_op

    order = ds["order"]
    spay = jnp.stack(
        [dim[c][order] for c in plan.dim_columns], axis=1
    ).astype(jnp.float32)
    gathered, hit = gather_join_op(
        fk.astype(jnp.int32), ds["keys"].astype(jnp.int32), spay
    )
    brought = {
        c: gathered[:, j] for j, c in enumerate(plan.dim_columns)
    }
    return brought, hit


def emit_aggregate_kernel(aggs, cols, w, sid, num_segments):
    """Emit one masked segmented-aggregate kernel call covering every agg of
    an Aggregate op (sum/mean/count share a single one-hot matmul; min/max
    ride the same pass). ``w`` is the fused filter/validity mask."""
    from repro.kernels.ops import segment_agg_op

    src: list[str] = []
    for _, op, col in aggs:
        if op != "count" and col not in src:
            src.append(col)
    n = w.shape[0]
    if src:
        vals = jnp.stack([cols[c].astype(jnp.float32) for c in src], axis=1)
    else:
        vals = jnp.zeros((n, 0), jnp.float32)
    counts, sums, mins, maxs = segment_agg_op(
        vals, w, sid, num_segments=num_segments
    )
    idx = {c: j for j, c in enumerate(src)}
    out = {}
    for name, op, col in aggs:
        if op == "count":
            out[name] = counts
        elif op == "sum":
            out[name] = sums[:, idx[col]]
        elif op == "mean":
            out[name] = sums[:, idx[col]] / jnp.maximum(counts, 1.0)
        elif op == "min":
            out[name] = jnp.where(counts > 0, mins[:, idx[col]], 0.0)
        elif op == "max":
            out[name] = jnp.where(counts > 0, maxs[:, idx[col]], 0.0)
        else:
            raise ValueError(op)
    return out
