"""Compile a TrainedPipeline into one fused jittable tensor program.

This is the MLtoDNN target (paper §5.1, via Hummingbird): featurizers become
vectorized jnp ops, tree ensembles become GEMM or gather-traversal programs
(strategy picked per-ensemble, Hummingbird-style: GEMM for shallow/wide on
the MXU, traversal for deep/narrow), and the whole thing is one closure that
XLA fuses — the "DNN runtime" execution of the model.

On TPU the tree-GEMM and featurize steps dispatch to the Pallas kernels in
:mod:`repro.kernels`; on CPU they run the pure-jnp oracles (same math).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.ml.pipeline import TrainedPipeline
from repro.ml.trees import TreeEnsemble
from repro.tensor.tree2tensor import (
    build_gemm_program,
    build_traversal_program,
    gemm_predict,
    traversal_predict,
)


@dataclass
class TensorCompilation:
    fn: Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]
    strategy: dict[str, str]  # model output name -> chosen tree strategy
    n_ops: int
    # columns the fused program consumes — surfaced so the StageGraph can
    # infer schema through an otherwise-opaque TensorOp closure
    input_names: tuple[str, ...] = ()


def _choose_tree_strategy(ens: TreeEnsemble) -> str:
    """GEMM when padded matrices stay MXU-friendly; else gather traversal.

    Heuristic mirrors Hummingbird — and like Hummingbird's, it is
    hardware-specific: the GEMM strategy exists to feed matrix units
    (MXU/TensorCore); on a CPU backend its O(F·I + I·L) dense work loses to
    O(depth) gather-stepping by ~100x (measured, EXPERIMENTS.md §Perf), so
    CPU always picks traversal. The paper's §5.2 point — don't hard-code
    the crossover, learn it per hardware — is enforced by the strategy
    corpus measuring on the live backend either way.
    """
    import jax

    if jax.default_backend() != "tpu":
        return "traversal"
    slices = ens.tree_slices()
    max_nodes = max(sl.stop - sl.start for sl in slices)
    max_internal = (max_nodes + 1) // 2
    return "gemm" if max_internal <= 128 else "traversal"


def compile_pipeline_tensor(
    pipe: TrainedPipeline, strategy: str = "auto", use_pallas: bool | None = None
) -> TensorCompilation:
    steps: list[tuple] = []  # (kind, node) in topo order — closed over below
    chosen: dict[str, str] = {}
    for node in pipe.nodes:
        if node.op == "tree_ensemble":
            ens = node.attrs["ensemble"]
            strat = strategy if strategy != "auto" else _choose_tree_strategy(ens)
            chosen[node.outputs[0]] = strat
            prog = (
                build_gemm_program(ens)
                if strat == "gemm"
                else build_traversal_program(ens)
            )
            steps.append((strat, node, prog))
        else:
            steps.append((node.op, node, None))

    input_names = list(pipe.input_names())
    outputs = list(pipe.outputs)

    def fn(cols: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        vals: dict[str, jnp.ndarray] = {}
        for name in input_names:
            x = cols[name]
            vals[name] = x[:, None] if x.ndim == 1 else x
        n = next(iter(vals.values())).shape[0] if vals else 0
        for kind, node, prog in steps:
            a = node.attrs
            if kind == "concat":
                vals[node.outputs[0]] = jnp.concatenate(
                    [vals[i].astype(jnp.float32) for i in node.inputs], axis=1
                )
            elif kind == "scaler":
                x = vals[node.inputs[0]].astype(jnp.float32)
                vals[node.outputs[0]] = (
                    x - jnp.asarray(a["offset"], jnp.float32)
                ) * jnp.asarray(a["scale"], jnp.float32)
            elif kind == "one_hot":
                x = vals[node.inputs[0]].reshape(-1)
                cats = jnp.asarray(np.asarray(a["categories"]))
                vals[node.outputs[0]] = (
                    x[:, None] == cats[None, :]
                ).astype(jnp.float32)
            elif kind == "label_encode":
                x = vals[node.inputs[0]].reshape(-1)
                vals[node.outputs[0]] = jnp.searchsorted(
                    jnp.asarray(np.asarray(a["classes"])), x
                )[:, None]
            elif kind == "feature_extractor":
                idx = jnp.asarray(np.asarray(a["indices"], dtype=np.int32))
                vals[node.outputs[0]] = vals[node.inputs[0]][:, idx]
            elif kind == "constant":
                v = jnp.asarray(
                    np.atleast_1d(np.asarray(a["value"], np.float32))
                )[None, :]
                vals[node.outputs[0]] = jnp.broadcast_to(v, (n, v.shape[1]))
            elif kind == "normalizer":
                x = vals[node.inputs[0]].astype(jnp.float32)
                if a["norm"] == "l1":
                    d = jnp.abs(x).sum(axis=1, keepdims=True)
                elif a["norm"] == "l2":
                    d = jnp.sqrt((x * x).sum(axis=1, keepdims=True))
                else:
                    d = jnp.abs(x).max(axis=1, keepdims=True)
                vals[node.outputs[0]] = x / jnp.where(d == 0.0, 1.0, d)
            elif kind in ("gemm", "traversal"):
                X = vals[node.inputs[0]].astype(jnp.float32)
                if kind == "gemm":
                    if use_pallas:
                        from repro.kernels.ops import pad_gemm_program, tree_gemm_op

                        A, B, C, D, V = pad_gemm_program(
                            prog.A, prog.B, prog.C, prog.Dcount, prog.V
                        )
                        raw = tree_gemm_op(
                            X, A, B, C, D, V, base=prog.base, use_pallas=True
                        )
                    else:
                        raw = gemm_predict(prog, X)
                else:
                    raw = traversal_predict(prog, X)
                score = (
                    1.0 / (1.0 + jnp.exp(-raw)) if prog.post == "logistic" else raw
                )
                thr = float(a.get("decision_threshold", 0.5))
                vals[node.outputs[0]] = score
                if len(node.outputs) > 1:
                    vals[node.outputs[1]] = (score >= thr).astype(jnp.int32)
            elif kind == "linear":
                X = vals[node.inputs[0]].astype(jnp.float32)
                w = jnp.asarray(np.asarray(a["weights"], np.float32))
                z = X @ w + jnp.float32(a["bias"])
                if a.get("post", "none") == "logistic":
                    z = 1.0 / (1.0 + jnp.exp(-z))
                thr = float(a.get("decision_threshold", 0.5))
                vals[node.outputs[0]] = z
                if len(node.outputs) > 1:
                    vals[node.outputs[1]] = (z >= thr).astype(jnp.int32)
            else:
                raise ValueError(kind)
        return {o: vals[o] for o in outputs}

    # canonical content token: the closure is a pure function of the
    # pipeline + compilation choices, so plans embedding it (TensorOp)
    # fingerprint stably across objects and processes instead of by id()
    from repro.core.fingerprint import fingerprint as _fingerprint

    fn.__fingerprint_token__ = _fingerprint(
        "tensor_compile", pipe, strategy, use_pallas, sorted(chosen.items())
    )
    fn.__input_names__ = tuple(input_names)
    return TensorCompilation(
        fn=fn, strategy=chosen, n_ops=len(steps),
        input_names=tuple(input_names),
    )
