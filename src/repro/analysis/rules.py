"""Typed rule registry for the plan verifier and the concurrency lint.

Every check the analysis layer performs is a named :class:`Rule`; every
failure is a :class:`Violation` carrying the rule id, so diagnostics are
greppable ("which rule fired?") and tests can assert a *specific* rule
rejected a *specific* corruption. Rules are grouped by scope:

  * ``logical`` — invariants of the logical plan / PredictionQuery, checked
    differentially after every optimizer rewrite rule;
  * ``graph``   — structural invariants of the lowered :class:`StageGraph`;
  * ``exec``    — abstract-execution invariants (``jax.eval_shape`` over
    shape buckets: schema, dtypes, row-polymorphism);
  * ``lint``    — static source checks (lock discipline, forbidden
    patterns), independent of any particular plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Rule:
    """One named invariant the analysis layer enforces."""

    id: str
    scope: str  # "logical" | "graph" | "exec" | "lint" | "registry"
    description: str


@dataclass
class Violation:
    """One rule failure: the rule id, where it fired, and why."""

    rule: str
    message: str
    # context: a stage label, optimizer rewrite-rule name, or file:line
    where: str = ""

    def __str__(self) -> str:
        loc = f" {self.where}:" if self.where else ""
        return f"[{self.rule}]{loc} {self.message}"


class VerificationWarning(UserWarning):
    """Raised as a warning (``verify='warn'``) instead of an error."""


_REGISTRY: dict[str, Rule] = {}


def register(rule_id: str, scope: str, description: str) -> Rule:
    rule = Rule(rule_id, scope, description)
    if rule_id in _REGISTRY:
        raise ValueError(f"duplicate rule id {rule_id!r}")
    _REGISTRY[rule_id] = rule
    return rule


def rule_catalog() -> list[Rule]:
    """All registered rules, in registration order (docs + CLI listing)."""
    return list(_REGISTRY.values())


def violation(rule: Rule, message: str, where: str = "") -> Violation:
    return Violation(rule=rule.id, message=message, where=where)


# -- verifier rules ----------------------------------------------------------

GRAPH_SHAPE = register(
    "graph-shape", "graph",
    "stage indices are contiguous, kinds valid, pure stages carry a fn and "
    "host stages exactly one MLUdf, no two adjacent pure stages",
)
SCHEMA_CHAIN = register(
    "schema-chain", "graph",
    "declared stage schemas chain: each stage's in_columns match the "
    "upstream stage's out_columns and its out_columns match re-inference",
)
CONSUMES_BALANCE = register(
    "consumes-balance", "graph",
    "every produced __pv_* block column is consumed exactly once "
    "downstream, by an operator that actually reads it",
)
BLOCK_LEAK = register(
    "block-leak", "graph",
    "no reserved __pv_* block column reaches the query output schema",
)
PLACEMENT_PURE = register(
    "placement-pure", "graph",
    "pure stages contain only jnp-executable operators; host stages "
    "contain exactly the MLUdf boundary",
)
RESIDUAL_MINIMAL = register(
    "residual-minimal", "graph",
    "split-lowered MLUdf residuals are minimal: re-splitting the residual "
    "against tensor_supported yields no further prefix or suffix",
)
FINGERPRINT_STABLE = register(
    "fingerprint-stable", "graph",
    "re-lowering the plan reproduces every chained stage fingerprint, and "
    "no fingerprint token embeds a memory-address repr",
)
FINGERPRINT_DETERMINISTIC = register(
    "fingerprint-deterministic", "graph",
    "the plan fingerprint is content-addressed: rebuilding the plan from "
    "fresh node/container objects does not change it",
)

SCHEMA_EXEC = register(
    "schema-exec", "exec",
    "abstract execution (eval_shape) of each pure stage succeeds and "
    "produces exactly the declared out_columns (host stages run on a "
    "zero-row batch)",
)
SCHEMA_DTYPE = register(
    "schema-dtype", "exec",
    "output dtypes are bucket-invariant and the validity mask is boolean",
)
BUCKET_SAFETY = register(
    "bucket-safety", "exec",
    "pure stages are row-polymorphic: output leading dims either scale "
    "with the row bucket or are bucket-independent, so warm re-bucketing "
    "cannot retrace",
)
SEGMENT_THREADING = register(
    "segment-threading", "exec",
    "segment ids survive to the end of the graph whenever the graph needs "
    "them (host boundaries or aggregates under coalesced serving)",
)

PIPELINE_GRAPH = register(
    "pipeline-graph", "logical",
    "every LPredict pipeline is an acyclic single-producer DAG whose "
    "declared outputs are actually produced",
)
LOGICAL_SCHEMA = register(
    "logical-schema", "logical",
    "every logical operator references only columns its child provides",
)

# -- lint rules --------------------------------------------------------------

LOCK_ORDER = register(
    "lock-order", "lint",
    "the lock-acquisition graph (with one-level call edges) is acyclic — "
    "no lock-order inversions",
)
LOCK_REENTRY = register(
    "lock-reentry", "lint",
    "a non-reentrant threading.Lock is never re-acquired while held",
)
UNLOCKED_MUTATION = register(
    "unlocked-mutation", "lint",
    "no instance field is mutated both inside and outside a lock "
    "(outside __init__; helpers only ever called under a lock inherit it)",
)
FINGERPRINT_HYGIENE_SRC = register(
    "fingerprint-hygiene-src", "lint",
    "__fingerprint_token__ assignments are content-addressed: no id()/"
    "repr()/hash()/time.* and no interpolated f-strings in the token",
)
HOST_IN_JIT = register(
    "host-in-jit", "lint",
    "no host callbacks (numpy, time, print) inside jitted stage bodies",
)
WALLCLOCK_TIMING = register(
    "wallclock-timing", "lint",
    "runtime code measures durations with perf_counter/monotonic, never "
    "time.time() (wall clock steps under NTP)",
)

# -- model-registry rules ----------------------------------------------------

REGISTRY_STATE = register(
    "registry-state", "registry",
    "every model version's recorded history follows the published → "
    "warming → ready → live → retired state machine, and each model has "
    "exactly one live version (the registry's routing target)",
)
REGISTRY_ROUTE = register(
    "registry-route", "registry",
    "registry and server agree: every tracked route's live/shadow labels "
    "match the registry's live/shadow versions, and every staged label on "
    "a server route is a version the registry knows",
)
REGISTRY_WARM = register(
    "registry-warm", "registry",
    "no cutover was forced cold: every route's last cutover had zero "
    "unwarmed ladder entries (require_warm=False leaves a recorded deficit)",
)

# -- fault-tolerance rules ---------------------------------------------------

RETRY_STATE = register(
    "retry-state", "serving",
    "scheduler retry accounting is sane: cumulative retries bound the "
    "pending redo depth, and every queued redo entry's attempt count is "
    "positive and below its queue's RetryPolicy max_attempts",
)
BREAKER_STATE = register(
    "breaker-state", "serving",
    "circuit-breaker state is consistent on every route version: a "
    "degraded version has a compiled fallback plan (fingerprint-forked "
    "from the primary), failure counts stay below the trip threshold "
    "unless degraded, and trip counts never exceed recorded failures",
)
RECOVERY_JOURNAL = register(
    "recovery-journal", "registry",
    "the crash-recovery journal agrees with the in-memory registry: "
    "live/shadow/split pointers, version counts and states, and tracked "
    "route names in the journal match the registry that wrote it",
)


@dataclass
class AnalysisResult:
    """Outcome of one analysis pass (verifier run or lint run)."""

    violations: list[Violation] = field(default_factory=list)
    # one line per check group that ran clean, for reporting
    passed: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def extend(self, other: "AnalysisResult") -> None:
        self.violations.extend(other.violations)
        self.passed.extend(other.passed)

    def describe(self) -> str:
        lines = [str(v) for v in self.violations]
        lines += [f"ok: {p}" for p in self.passed]
        return "\n".join(lines)
