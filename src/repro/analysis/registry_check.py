"""Registry state-machine checks: replay the model lifecycle's records.

The :class:`~repro.serve.registry.ModelRegistry` *enforces* its state
machine at transition time; these checks *re-derive* the invariants from
the recorded evidence — every version's transition history, the live/shadow
pointers, and the server routes the registry tracks — so a bug that
corrupted state through a path the enforcement missed (or a future
refactor that forgets a transition) is caught by an independent reading,
not by the same code that made the mistake.

Three rules (see ``repro.analysis.rules``):

  * ``registry-state`` — each version's history is a walk through
    ``ALLOWED_TRANSITIONS`` starting at ``published``, and each model has
    exactly one live version, the one its ``_live`` pointer routes to.
  * ``registry-route`` — registry and server agree: a tracked route's live
    and shadow labels match the registry's pointers, and every staged
    label on the route names a version the registry published.
  * ``registry-warm`` — no cutover went out cold: every route's last
    cutover recorded a zero warm deficit (``require_warm=False`` leaves
    the unwarmed ladder-entry count behind as evidence).

Run standalone via :func:`check_registry` or as part of the
``python -m repro.analysis`` gate's lifecycle scenario.
"""
from __future__ import annotations

from repro.analysis.rules import (
    REGISTRY_ROUTE,
    REGISTRY_STATE,
    REGISTRY_WARM,
    Violation,
)
from repro.serve.registry import ALLOWED_TRANSITIONS


def check_registry(session) -> list[Violation]:
    """Audit a session's model registry against the recorded lifecycle
    evidence; returns one :class:`Violation` per broken invariant."""
    out: list[Violation] = []
    registry = session.models
    with registry._lock:
        snap = registry.snapshot()
        routes = {
            name: list(registry._routes.get(name, ()))
            for name in registry._versions
        }
    for name, model in sorted(snap.items()):
        out.extend(_check_state(name, model))
        out.extend(_check_routes(name, model, routes.get(name, [])))
    return out


def _check_state(name: str, model: dict) -> list[Violation]:
    out: list[Violation] = []
    for v in model["versions"]:
        ref = f"{name}@{v['version']}"
        hist = v["history"]
        if not hist or hist[0] != "published":
            out.append(Violation(
                REGISTRY_STATE.id,
                f"history does not start at 'published': {hist}",
                where=ref,
            ))
            continue
        for prev, nxt in zip(hist, hist[1:]):
            if nxt not in ALLOWED_TRANSITIONS.get(prev, frozenset()):
                out.append(Violation(
                    REGISTRY_STATE.id,
                    f"recorded transition {prev!r} -> {nxt!r} is not in the "
                    f"state machine (history: {hist})",
                    where=ref,
                ))
        if v["state"] != hist[-1]:
            out.append(Violation(
                REGISTRY_STATE.id,
                f"state {v['state']!r} disagrees with the last recorded "
                f"transition {hist[-1]!r}",
                where=ref,
            ))
    live_versions = [v["version"] for v in model["versions"]
                     if v["state"] == "live"]
    if len(live_versions) != 1:
        out.append(Violation(
            REGISTRY_STATE.id,
            f"expected exactly one live version, found "
            f"{live_versions or 'none'}",
            where=name,
        ))
    elif model["live"] != live_versions[0]:
        out.append(Violation(
            REGISTRY_STATE.id,
            f"live pointer routes to v{model['live']} but v"
            f"{live_versions[0]} holds the 'live' state",
            where=name,
        ))
    return out


def _check_routes(name: str, model: dict, routes: list) -> list[Violation]:
    out: list[Violation] = []
    live = model["live"]
    shadow = model["shadow"]
    known = {f"v{v['version']}" for v in model["versions"]}
    for rt in routes:
        where = f"{name}:{rt.serve_name}"
        route = rt.server.routes.get(rt.serve_name)
        if route is None:
            out.append(Violation(
                REGISTRY_ROUTE.id,
                "registry tracks a route the server no longer has",
                where=where,
            ))
            continue
        snap = rt.server.route_snapshot(rt.serve_name)
        if live is not None and snap["live"] != f"v{live}":
            out.append(Violation(
                REGISTRY_ROUTE.id,
                f"server routes live traffic to {snap['live']} but the "
                f"registry's live version is v{live}",
                where=where,
            ))
        want_shadow = None if shadow is None else f"v{shadow}"
        if snap["shadow"] != want_shadow:
            out.append(Violation(
                REGISTRY_ROUTE.id,
                f"server shadow {snap['shadow']!r} disagrees with the "
                f"registry's {want_shadow!r}",
                where=where,
            ))
        unknown = sorted(set(snap["versions"]) - known)
        if unknown:
            out.append(Violation(
                REGISTRY_ROUTE.id,
                f"route stages version labels the registry never "
                f"published: {unknown}",
                where=where,
            ))
        if snap["last_cutover_deficit"]:
            out.append(Violation(
                REGISTRY_WARM.id,
                f"last cutover went out cold: "
                f"{snap['last_cutover_deficit']} unwarmed ladder "
                f"entries (require_warm=False)",
                where=where,
            ))
    return out
