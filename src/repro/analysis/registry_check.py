"""Registry state-machine checks: replay the model lifecycle's records.

The :class:`~repro.serve.registry.ModelRegistry` *enforces* its state
machine at transition time; these checks *re-derive* the invariants from
the recorded evidence — every version's transition history, the live/shadow
pointers, and the server routes the registry tracks — so a bug that
corrupted state through a path the enforcement missed (or a future
refactor that forgets a transition) is caught by an independent reading,
not by the same code that made the mistake.

Three rules (see ``repro.analysis.rules``):

  * ``registry-state`` — each version's history is a walk through
    ``ALLOWED_TRANSITIONS`` starting at ``published``, and each model has
    exactly one live version, the one its ``_live`` pointer routes to.
  * ``registry-route`` — registry and server agree: a tracked route's live
    and shadow labels match the registry's pointers, and every staged
    label on the route names a version the registry published.
  * ``registry-warm`` — no cutover went out cold: every route's last
    cutover recorded a zero warm deficit (``require_warm=False`` leaves
    the unwarmed ladder-entry count behind as evidence).

Three more rules audit the fault-tolerance layer riding the same
session: ``retry-state`` (scheduler redo bookkeeping), ``breaker-state``
(circuit-breaker/fallback consistency on every route version), and
``recovery-journal`` (the crash-recovery journal in the artifact store
agrees with the in-memory registry that wrote it).

Run standalone via :func:`check_registry` or as part of the
``python -m repro.analysis`` gate's lifecycle scenario.
"""
from __future__ import annotations

from repro.analysis.rules import (
    BREAKER_STATE,
    RECOVERY_JOURNAL,
    REGISTRY_ROUTE,
    REGISTRY_STATE,
    REGISTRY_WARM,
    RETRY_STATE,
    Violation,
)
from repro.serve.registry import ALLOWED_TRANSITIONS


def check_registry(session) -> list[Violation]:
    """Audit a session's model registry against the recorded lifecycle
    evidence; returns one :class:`Violation` per broken invariant."""
    out: list[Violation] = []
    registry = session.models
    with registry._lock:
        snap = registry.snapshot()
        routes = {
            name: list(registry._routes.get(name, ()))
            for name in registry._versions
        }
    for name, model in sorted(snap.items()):
        out.extend(_check_state(name, model))
        out.extend(_check_routes(name, model, routes.get(name, [])))
    out.extend(check_fault_tolerance(session))
    return out


def check_fault_tolerance(session) -> list[Violation]:
    """Audit the session's retry/breaker/recovery bookkeeping (quiescent
    reads — run between flushes, like the rest of the gate)."""
    out: list[Violation] = []
    srv = getattr(session, "_server", None)
    if srv is not None:
        out.extend(_check_retry(srv))
        out.extend(_check_breaker(srv))
    out.extend(_check_journal(session))
    return out


def _check_retry(srv) -> list[Violation]:
    out: list[Violation] = []
    sch = srv.scheduler
    with sch._cv:
        redo_depth = 0
        for name, q in sch._queues.items():
            policy = q.retry if q.retry is not None else sch.default_retry
            for _group, attempt, _not_before in q.redo:
                redo_depth += 1
                if not 1 <= attempt < policy.max_attempts:
                    out.append(Violation(
                        RETRY_STATE.id,
                        f"redo entry carries attempt {attempt}, outside "
                        f"[1, {policy.max_attempts}) for this queue's "
                        f"RetryPolicy",
                        where=name,
                    ))
        if sch.retries < redo_depth:
            out.append(Violation(
                RETRY_STATE.id,
                f"{redo_depth} groups await re-dispatch but only "
                f"{sch.retries} retries were ever recorded",
                where="scheduler",
            ))
    return out


def _check_breaker(srv) -> list[Violation]:
    out: list[Violation] = []
    with srv._lock:
        regs = dict(srv.queries)
        for route in srv.routes.values():
            regs.update(
                (f"{route.name}:{label}", reg)
                for label, reg in route.versions.items()
            )
        trips = 0
        for where, reg in sorted(regs.items()):
            trips += reg.breaker_trips
            if reg.breaker_failures < 0:
                out.append(Violation(
                    BREAKER_STATE.id,
                    f"negative breaker failure count "
                    f"{reg.breaker_failures}",
                    where=where,
                ))
            if reg.fallback is not None and reg.breaker_trips < 1:
                out.append(Violation(
                    BREAKER_STATE.id,
                    "a fallback plan is installed but no breaker trip was "
                    "recorded",
                    where=where,
                ))
            if reg.degraded and reg.fallback is None:
                out.append(Violation(
                    BREAKER_STATE.id,
                    "registration is degraded with no fallback plan "
                    "compiled (trip claimed but never completed)",
                    where=where,
                ))
        # regs are shared between `queries` and route.versions (the live
        # label aliases the primary registration), so summed trips can
        # double-count aliases — the server total must never exceed it,
        # and must be positive whenever any registration tripped
        if trips and not srv.stats.breaker_trips:
            out.append(Violation(
                BREAKER_STATE.id,
                f"registrations record {trips} breaker trip(s) but the "
                f"server counted none",
                where="server",
            ))
    return out


def _check_journal(session) -> list[Violation]:
    store = getattr(session, "artifact_store", None)
    registry = session.models
    if store is None:
        return []
    if store.stats.registry_skipped:
        # a journal write was dropped (unpicklable state, by design
        # fail-soft) — the on-disk journal is known-stale, so disagreement
        # with the in-memory registry is expected, not a violation
        return []
    state = store.load_registry(session._journal_key())
    with registry._lock:
        snap = registry.snapshot()
        tracked = {
            name: sorted(r.serve_name for r in registry._routes.get(name, ()))
            for name in registry._versions
        }
    if state is None:
        if snap:
            return [Violation(
                RECOVERY_JOURNAL.id,
                f"registry holds models {sorted(snap)} but the artifact "
                f"store has no recovery journal for this session's tables",
                where="journal",
            )]
        return []
    out: list[Violation] = []
    jmodels = state.get("models", {})
    if sorted(jmodels) != sorted(snap):
        out.append(Violation(
            RECOVERY_JOURNAL.id,
            f"journal names models {sorted(jmodels)} but the registry "
            f"holds {sorted(snap)}",
            where="journal",
        ))
    for name in sorted(set(jmodels) & set(snap)):
        jrec, rec = jmodels[name], snap[name]
        for field in ("live", "shadow", "split"):
            if jrec.get(field) != rec[field]:
                out.append(Violation(
                    RECOVERY_JOURNAL.id,
                    f"journal {field}={jrec.get(field)!r} disagrees with "
                    f"the registry's {rec[field]!r}",
                    where=name,
                ))
        jstates = [(v["version"], v["state"]) for v in jrec.get("versions", ())]
        rstates = [(v["version"], v["state"]) for v in rec["versions"]]
        if jstates != rstates:
            out.append(Violation(
                RECOVERY_JOURNAL.id,
                f"journal version states {jstates} disagree with the "
                f"registry's {rstates}",
                where=name,
            ))
        jroutes = sorted(
            r["serve_name"] for r in state.get("routes", {}).get(name, ())
        )
        if jroutes != tracked.get(name, []):
            out.append(Violation(
                RECOVERY_JOURNAL.id,
                f"journal routes {jroutes} disagree with the tracked "
                f"routes {tracked.get(name, [])}",
                where=name,
            ))
    return out


def _check_state(name: str, model: dict) -> list[Violation]:
    out: list[Violation] = []
    for v in model["versions"]:
        ref = f"{name}@{v['version']}"
        hist = v["history"]
        if not hist or hist[0] != "published":
            out.append(Violation(
                REGISTRY_STATE.id,
                f"history does not start at 'published': {hist}",
                where=ref,
            ))
            continue
        for prev, nxt in zip(hist, hist[1:]):
            if nxt not in ALLOWED_TRANSITIONS.get(prev, frozenset()):
                out.append(Violation(
                    REGISTRY_STATE.id,
                    f"recorded transition {prev!r} -> {nxt!r} is not in the "
                    f"state machine (history: {hist})",
                    where=ref,
                ))
        if v["state"] != hist[-1]:
            out.append(Violation(
                REGISTRY_STATE.id,
                f"state {v['state']!r} disagrees with the last recorded "
                f"transition {hist[-1]!r}",
                where=ref,
            ))
    live_versions = [v["version"] for v in model["versions"]
                     if v["state"] == "live"]
    if len(live_versions) != 1:
        out.append(Violation(
            REGISTRY_STATE.id,
            f"expected exactly one live version, found "
            f"{live_versions or 'none'}",
            where=name,
        ))
    elif model["live"] != live_versions[0]:
        out.append(Violation(
            REGISTRY_STATE.id,
            f"live pointer routes to v{model['live']} but v"
            f"{live_versions[0]} holds the 'live' state",
            where=name,
        ))
    return out


def _check_routes(name: str, model: dict, routes: list) -> list[Violation]:
    out: list[Violation] = []
    live = model["live"]
    shadow = model["shadow"]
    known = {f"v{v['version']}" for v in model["versions"]}
    for rt in routes:
        where = f"{name}:{rt.serve_name}"
        route = rt.server.routes.get(rt.serve_name)
        if route is None:
            out.append(Violation(
                REGISTRY_ROUTE.id,
                "registry tracks a route the server no longer has",
                where=where,
            ))
            continue
        snap = rt.server.route_snapshot(rt.serve_name)
        if live is not None and snap["live"] != f"v{live}":
            out.append(Violation(
                REGISTRY_ROUTE.id,
                f"server routes live traffic to {snap['live']} but the "
                f"registry's live version is v{live}",
                where=where,
            ))
        want_shadow = None if shadow is None else f"v{shadow}"
        if snap["shadow"] != want_shadow:
            out.append(Violation(
                REGISTRY_ROUTE.id,
                f"server shadow {snap['shadow']!r} disagrees with the "
                f"registry's {want_shadow!r}",
                where=where,
            ))
        unknown = sorted(set(snap["versions"]) - known)
        if unknown:
            out.append(Violation(
                REGISTRY_ROUTE.id,
                f"route stages version labels the registry never "
                f"published: {unknown}",
                where=where,
            ))
        if snap["last_cutover_deficit"]:
            out.append(Violation(
                REGISTRY_WARM.id,
                f"last cutover went out cold: "
                f"{snap['last_cutover_deficit']} unwarmed ladder "
                f"entries (require_warm=False)",
                where=where,
            ))
    return out
