"""Static verifier for logical plans and the lowered StageGraph IR.

Three layers of checks, all reporting through the typed rule registry in
:mod:`repro.analysis.rules`:

  * **logical** (:func:`check_logical`) — cheap invariants of the
    PredictionQuery, run differentially by the optimizer after every rewrite
    rule so a violation names the rule that introduced it;
  * **graph** (:func:`check_graph`) — structural invariants of the lowered
    stage chain: schema chaining, ``__pv_*`` consumes-balance, runtime
    placement, residual minimality, fingerprint hygiene;
  * **exec** (:func:`check_exec`) — abstract execution via
    ``jax.eval_shape`` at two row buckets: every pure stage must trace, emit
    exactly its declared schema with bucket-invariant dtypes, and be
    row-polymorphic (so warm re-bucketing cannot retrace). Host stages run
    for real on a zero-row batch (cheap, and exactly what serving does to
    discover trailing shapes).

Modes: ``off`` (skip), ``warn`` (``VerificationWarning`` + report lines),
``strict`` (raise :class:`~repro.errors.PlanVerificationError`). The mode
defaults to the ``RAVEN_VERIFY`` environment variable so CI can force
``strict`` without touching call sites.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import numpy as np

from repro.analysis import rules as R
from repro.analysis.rules import Violation, violation

# reserved block-column prefix (split-lowering cut values)
from repro.ml.pipeline import cut_column

BLOCK_PREFIX = cut_column("")

_MODES = ("off", "warn", "strict")


def resolve_verify_mode(value: Any = None) -> str:
    """Normalize a user-supplied verify mode.

    ``None`` defers to ``RAVEN_VERIFY`` (default ``off``); booleans map to
    ``strict``/``off``; strings must be one of ``off``/``warn``/``strict``.
    """
    if value is None:
        value = os.environ.get("RAVEN_VERIFY") or "off"
    if isinstance(value, bool):
        value = "strict" if value else "off"
    if value not in _MODES:
        raise ValueError(
            f"verify mode must be one of {_MODES}, got {value!r}"
        )
    return value


def enforce(
    violations: list[Violation], mode: str, context: str = "plan"
) -> list[str]:
    """Apply a verify mode to a violation list.

    Returns human-readable report lines (for ``explain()``); raises
    :class:`PlanVerificationError` under ``strict``, emits a
    :class:`VerificationWarning` under ``warn``.
    """
    if mode == "off" or not violations:
        return [] if mode == "off" else [f"{context}: ok"]
    lines = [f"{context}: {v}" for v in violations]
    if mode == "strict":
        from repro.errors import PlanVerificationError

        raise PlanVerificationError(
            f"plan verification failed ({context}):\n  "
            + "\n  ".join(str(v) for v in violations),
            violations=violations,
        )
    import warnings

    for ln in lines:
        warnings.warn(ln, R.VerificationWarning, stacklevel=3)
    return lines


# ---------------------------------------------------------------------------
# Logical checks (differential, per rewrite rule)
# ---------------------------------------------------------------------------


def check_logical(query, where: str = "") -> list[Violation]:
    """Invariants of a PredictionQuery's logical plan."""
    from repro.core.ir import (
        LAggregate,
        LFilter,
        LJoin,
        LPredict,
        LProject,
        LScan,
    )
    from repro.relational.expr import columns_of

    out: list[Violation] = []

    def pipe_check(pred: LPredict) -> None:
        pipe = pred.pipeline
        try:
            pipe.copy().toposort()
        except ValueError as e:
            out.append(violation(R.PIPELINE_GRAPH, str(e), where))
            return
        produced: set[str] = set(pipe.input_names())
        for n in pipe.nodes:
            for o in n.outputs:
                if o in produced:
                    out.append(violation(
                        R.PIPELINE_GRAPH,
                        f"value {o!r} has multiple producers", where,
                    ))
                produced.add(o)
        for o in pipe.outputs:
            if o not in produced:
                out.append(violation(
                    R.PIPELINE_GRAPH,
                    f"declared output {o!r} is never produced", where,
                ))

    def avail(p) -> list[str]:
        if isinstance(p, LScan):
            return list(p.columns)
        cols = avail(p.child)
        have = set(cols)

        def need(names, what):
            missing = [c for c in names if c not in have]
            if missing:
                out.append(violation(
                    R.LOGICAL_SCHEMA,
                    f"{what} references missing column(s) {missing} "
                    f"(child provides {sorted(have)})", where,
                ))

        if isinstance(p, LJoin):
            need([p.fact_key], "join key")
            return cols + list(p.dim_columns)
        if isinstance(p, LFilter):
            need(sorted(columns_of(p.expr)), "filter predicate")
            return cols
        if isinstance(p, LProject):
            if p.keep is not None:
                need(list(p.keep), "projection keep-list")
            for name, e in p.exprs.items():
                need(sorted(columns_of(e)), f"projection expr {name!r}")
            base = list(p.keep) if p.keep is not None else cols
            return base + [c for c in p.exprs if c not in base]
        if isinstance(p, LPredict):
            pipe_check(p)
            need(p.pipeline.input_names(), "predict pipeline inputs")
            return cols + list(p.output_names)
        if isinstance(p, LAggregate):
            for _, op, col in p.aggs:
                if op != "count":
                    need([col], f"aggregate {op}")
            return [a[0] for a in p.aggs]
        raise TypeError(type(p))

    avail(query.plan)
    return out


# ---------------------------------------------------------------------------
# Structural graph checks
# ---------------------------------------------------------------------------


def _op_reads(op) -> Optional[tuple[str, ...]]:
    """Columns an ML operator consumes from its input schema, when known.

    MLUdf declares them via its pipeline; TensorOp closures are opaque
    except for the ``__input_names__`` schema the tensor compiler stamps.
    Returns ``None`` when unknowable (untagged TensorOp closure).
    """
    from repro.relational.engine import MLUdf, TensorOp

    if isinstance(op, MLUdf):
        return tuple(op.pipeline.input_names())
    if isinstance(op, TensorOp):
        ins = getattr(op.fn, "__input_names__", None)
        return tuple(ins) if ins is not None else None
    return ()


def check_graph(graph) -> list[Violation]:
    """Structural invariants of a lowered :class:`StageGraph`."""
    out: list[Violation] = []
    out += _check_graph_shape(graph)
    out += _check_schema_chain(graph)
    out += _check_consumes_balance(graph)
    out += _check_block_leak(graph)
    out += _check_placement(graph)
    out += _check_residual_minimal(graph)
    out += _check_fingerprint_stable(graph)
    out += _check_fingerprint_deterministic(graph)
    return out


def _check_graph_shape(graph) -> list[Violation]:
    from repro.relational.engine import MLUdf, Scan

    out: list[Violation] = []
    if not graph.stages:
        return [violation(R.GRAPH_SHAPE, "graph has no stages")]
    for i, s in enumerate(graph.stages):
        w = f"stage {i}"
        if s.index != i:
            out.append(violation(
                R.GRAPH_SHAPE, f"index {s.index} != position {i}", w))
        if s.kind not in ("pure", "host"):
            out.append(violation(R.GRAPH_SHAPE, f"unknown kind {s.kind!r}", w))
            continue
        if s.kind == "pure":
            if s.fn is None:
                out.append(violation(R.GRAPH_SHAPE, "pure stage has no fn", w))
            if s.udf is not None:
                out.append(violation(
                    R.GRAPH_SHAPE, "pure stage carries a udf", w))
            if i > 0 and graph.stages[i - 1].kind == "pure":
                out.append(violation(
                    R.GRAPH_SHAPE,
                    "adjacent pure stages (segments must be maximal)", w))
        else:
            if s.udf is None or len(s.ops) != 1 or not isinstance(
                s.ops[0], MLUdf
            ):
                out.append(violation(
                    R.GRAPH_SHAPE,
                    "host stage must carry exactly one MLUdf", w))
    first = graph.stages[0]
    if not first.ops or not isinstance(first.ops[0], Scan):
        out.append(violation(
            R.GRAPH_SHAPE, "graph does not start at a Scan", "stage 0"))
    return out


def _check_schema_chain(graph) -> list[Violation]:
    from repro.exec.stages import _segment_out_cols

    out: list[Violation] = []
    prev_out: Optional[tuple[str, ...]] = None
    for s in graph.stages:
        w = f"stage {s.index} ({s.label})"
        if prev_out is not None:
            if s.kind == "pure" and s.in_columns != prev_out:
                out.append(violation(
                    R.SCHEMA_CHAIN,
                    f"in_columns {s.in_columns} != upstream out_columns "
                    f"{prev_out}", w))
            elif s.kind == "host" and s.in_columns is not None:
                missing = [c for c in s.in_columns if c not in prev_out]
                if missing:
                    out.append(violation(
                        R.SCHEMA_CHAIN,
                        f"host stage reads {missing} absent from upstream "
                        f"out_columns {prev_out}", w))
        try:
            inferred = tuple(_segment_out_cols(
                s.ops, list(prev_out) if prev_out is not None else None))
        except TypeError:
            inferred = None
        if inferred is not None and tuple(s.out_columns) != inferred:
            out.append(violation(
                R.SCHEMA_CHAIN,
                f"declared out_columns {tuple(s.out_columns)} != inferred "
                f"{inferred}", w))
        prev_out = tuple(s.out_columns)
    return out


def _check_consumes_balance(graph) -> list[Violation]:
    from repro.relational.engine import MLUdf, TensorOp

    out: list[Violation] = []
    produced: dict[str, str] = {}
    consumed: dict[str, str] = {}
    for stage in graph.stages:
        for op in stage.ops:
            label = f"stage {stage.index} {type(op).__name__}"
            reads = _op_reads(op)
            if reads:
                for c in reads:
                    if not c.startswith(BLOCK_PREFIX):
                        continue
                    if c in consumed:
                        out.append(violation(
                            R.CONSUMES_BALANCE,
                            f"block column {c!r} read after being consumed "
                            f"by {consumed[c]}", label))
                    elif c not in produced:
                        out.append(violation(
                            R.CONSUMES_BALANCE,
                            f"block column {c!r} read but never produced "
                            f"upstream", label))
            for c in getattr(op, "consumes", ()) or ():
                if c not in produced:
                    out.append(violation(
                        R.CONSUMES_BALANCE,
                        f"consumes {c!r} which no upstream operator "
                        f"produced", label))
                elif c in consumed:
                    out.append(violation(
                        R.CONSUMES_BALANCE,
                        f"block column {c!r} consumed twice (first by "
                        f"{consumed[c]})", label))
                else:
                    consumed[c] = label
                if reads is not None and c not in reads:
                    out.append(violation(
                        R.CONSUMES_BALANCE,
                        f"consumes {c!r} without reading it", label))
            if isinstance(op, (MLUdf, TensorOp)):
                for c in op.output_names:
                    if c.startswith(BLOCK_PREFIX):
                        produced[c] = label
    for c, label in produced.items():
        if c not in consumed:
            out.append(violation(
                R.CONSUMES_BALANCE,
                f"block column {c!r} produced by {label} but never "
                f"consumed", label))
    return out


def _check_block_leak(graph) -> list[Violation]:
    leaked = [
        c for c in graph.stages[-1].out_columns
        if c.startswith(BLOCK_PREFIX)
    ] if graph.stages else []
    if leaked:
        return [violation(
            R.BLOCK_LEAK,
            f"reserved block column(s) {leaked} leak into the query "
            f"output schema",
            f"stage {graph.stages[-1].index}")]
    return []


def _check_placement(graph) -> list[Violation]:
    from repro.relational.engine import (
        Aggregate, Filter, Join, MLUdf, Project, Scan, TensorOp,
    )

    pure_ok = (Scan, Join, Filter, Project, TensorOp, Aggregate)
    out: list[Violation] = []
    for s in graph.stages:
        w = f"stage {s.index} ({s.label})"
        for op in s.ops:
            if s.kind == "pure" and not isinstance(op, pure_ok):
                out.append(violation(
                    R.PLACEMENT_PURE,
                    f"host-only operator {type(op).__name__} inside a pure "
                    f"stage", w))
            elif s.kind == "host" and not isinstance(op, MLUdf):
                out.append(violation(
                    R.PLACEMENT_PURE,
                    f"pure operator {type(op).__name__} inside a host "
                    f"stage", w))
    return out


def _check_residual_minimal(graph) -> list[Violation]:
    from repro.ml.pipeline import split_pipeline
    from repro.tensor.compile import tensor_supported

    out: list[Violation] = []
    for s in graph.stages:
        if s.kind != "host" or s.udf is None:
            continue
        udf = s.udf
        split_context = bool(udf.consumes) or any(
            c.startswith(BLOCK_PREFIX)
            for c in [*udf.pipeline.input_names(), *udf.output_names]
        )
        if not split_context:
            # monolithic MLUdf: the optimizer chose the host runtime for
            # the whole pipeline (transform='none'); minimality not claimed
            continue
        w = f"stage {s.index} ({s.label})"
        try:
            resplit = split_pipeline(udf.pipeline, tensor_supported)
        except Exception as e:  # corrupt pipeline: report, don't crash
            out.append(violation(
                R.RESIDUAL_MINIMAL,
                f"re-split of residual failed: {e}", w))
            continue
        if resplit.fully_supported:
            out.append(violation(
                R.RESIDUAL_MINIMAL,
                "residual pipeline is fully tensor-supported — it should "
                "not be a host boundary at all", w))
        elif resplit.prefix is not None or resplit.suffix is not None:
            extra = [
                seg for seg, part in
                (("prefix", resplit.prefix), ("suffix", resplit.suffix))
                if part is not None
            ]
            out.append(violation(
                R.RESIDUAL_MINIMAL,
                f"residual is not minimal: re-splitting extracts a tensor "
                f"{' and '.join(extra)}", w))
    return out


_ADDR_RE = re.compile(r"\b0x[0-9a-fA-F]{6,}\b|\bat 0x")


def _iter_tokens(graph):
    """Yield ``(where, token)`` for every fingerprint token in the graph."""
    from repro.relational.engine import MLUdf, TensorOp

    for s in graph.stages:
        for op in s.ops:
            if isinstance(op, TensorOp):
                tok = getattr(op.fn, "__fingerprint_token__", None)
                if isinstance(tok, str):
                    yield f"stage {s.index} TensorOp.fn", tok
            elif isinstance(op, MLUdf):
                for n in op.pipeline.nodes:
                    for v in n.attrs.values():
                        tok = getattr(v, "__fingerprint_token__", None)
                        if isinstance(tok, str):
                            yield (
                                f"stage {s.index} pipeline op "
                                f"{n.op} attr", tok,
                            )


def _check_fingerprint_stable(graph) -> list[Violation]:
    from repro.exec.stages import build_stage_graph

    out: list[Violation] = []
    rebuilt = build_stage_graph(graph.plan)
    if len(rebuilt.stages) != len(graph.stages):
        out.append(violation(
            R.FINGERPRINT_STABLE,
            f"re-lowering produced {len(rebuilt.stages)} stages, graph has "
            f"{len(graph.stages)}"))
    else:
        for a, b in zip(graph.stages, rebuilt.stages):
            if a.fingerprint != b.fingerprint:
                out.append(violation(
                    R.FINGERPRINT_STABLE,
                    f"chained fingerprint not reproducible: "
                    f"{a.fingerprint[:12]}… != {b.fingerprint[:12]}…",
                    f"stage {a.index} ({a.label})"))
    for where, tok in _iter_tokens(graph):
        if _ADDR_RE.search(tok):
            out.append(violation(
                R.FINGERPRINT_STABLE,
                f"fingerprint token embeds a memory-address repr: "
                f"{tok[:60]!r}", where))
    return out


def _replanted(p):
    """Rebuild a physical plan from fresh node and container objects.

    Exprs, closures, and pipelines are kept by reference (identity-hashed
    components must stay identical); everything rebuilt here — node
    dataclasses, lists, tuples, dicts — must not affect a content-addressed
    fingerprint. Plans are short linear chains, so recursion is safe where
    ``copy.deepcopy`` (through MLtoSQL's deep Case chains) would not be.
    """
    import dataclasses

    from repro.relational.engine import plan_children

    kids = plan_children(p)
    changes: dict[str, Any] = {}
    if kids:
        changes["child"] = _replanted(kids[0])
    for f in dataclasses.fields(p):
        v = getattr(p, f.name)
        if f.name == "child":
            continue
        if isinstance(v, list):
            changes[f.name] = list(v)
        elif isinstance(v, tuple):
            changes[f.name] = tuple(v)
        elif isinstance(v, dict):
            changes[f.name] = dict(v)
    return dataclasses.replace(p, **changes)


def _check_fingerprint_deterministic(graph) -> list[Violation]:
    from repro.relational.engine import plan_fingerprint

    pins1: list = []
    pins2: list = []
    fp1 = plan_fingerprint(graph.plan, pins=pins1)
    fp2 = plan_fingerprint(_replanted(graph.plan), pins=pins2)
    if fp1 != fp2:
        return [violation(
            R.FINGERPRINT_DETERMINISTIC,
            f"plan fingerprint changed under node/container rebuild "
            f"({fp1[:12]}… != {fp2[:12]}…) — some component hashes by "
            f"object identity or container order")]
    return []


# ---------------------------------------------------------------------------
# Abstract-execution checks (eval_shape at two row buckets)
# ---------------------------------------------------------------------------

# memo: a graph's exec verdict is a pure function of its final chained
# fingerprint (which covers every stage) and the source-table schema
_EXEC_MEMO: dict[tuple, list[Violation]] = {}


def _table_schema_key(graph, tables) -> tuple:
    from repro.relational.engine import Join, dimsort_entry, walk_plan

    parts = []
    # dim-key uniqueness changes the traced Join program (kernel vs jnp
    # gather), so it must fork the memo entry even at identical schemas
    for p in walk_plan(graph.plan):
        if isinstance(p, Join) and p.dim_table in tables:
            tab = tables[p.dim_table]
            if p.dim_key in tab:
                uniq = "unique" in dimsort_entry(tab[p.dim_key])
                parts.append(("__dimsort__", p.dim_table, uniq))
    for s in graph.stages:
        for t in sorted(s.reads):
            for c in s.reads[t]:
                arr = np.asarray(tables[t][c])
                parts.append((t, c, str(arr.dtype), arr.shape[1:]))
    return tuple(parts)


def check_exec(graph, tables, buckets: tuple[int, int] = (8, 16)) -> list[Violation]:
    """Abstractly execute ``graph`` at two row buckets and compare.

    ``tables`` maps table name -> {column -> array}; only shapes and dtypes
    are used (fact-table rows are replaced by the bucket size). Graphs that
    read non-numeric source columns (string categoricals) are skipped —
    they cannot enter a jnp program, and serving feeds them through host
    boundaries where real execution already validates them.
    """
    for s in graph.stages:
        for t, cols in s.reads.items():
            if t not in tables:
                return [violation(
                    R.SCHEMA_EXEC, f"plan reads unknown table {t!r}",
                    f"stage {s.index}")]
            for c in cols:
                if c not in tables[t]:
                    return [violation(
                        R.SCHEMA_EXEC,
                        f"plan reads unknown column {t}.{c}",
                        f"stage {s.index}")]
                if np.asarray(tables[t][c]).dtype.kind not in "biufc":
                    return []  # non-numeric source: skip abstract execution
    key = (graph.stages[-1].fingerprint, buckets, _table_schema_key(graph, tables))
    hit = _EXEC_MEMO.get(key)
    if hit is not None:
        return list(hit)
    out: list[Violation] = []
    results = {}
    for b in buckets:
        results[b] = _abstract_run(graph, tables, b, out)
        if results[b] is None:
            break
    b1, b2 = buckets
    if results.get(b1) is not None and results.get(b2) is not None:
        out += _compare_buckets(graph, results[b1], results[b2], b1, b2)
    _EXEC_MEMO[key] = list(out)
    return out


def _abstract_run(graph, tables, b: int, out: list[Violation]):
    import jax
    import jax.numpy as jnp

    from repro.exec.stages import (
        DIMSORT_KEY,
        MID_SEG,
        MID_TABLE,
        MID_VALID,
        PARAMS_KEY,
        ROW_SEG_KEY,
        ROW_VALID_KEY,
        SEG_COUNT_KEY,
        SEG_SLOTS_KEY,
        run_udf,
    )
    from repro.relational.engine import Join, dimsort_entry, plan_params, walk_plan

    fact = graph.stages[0].ops[0].table
    env: dict[str, Any] = {}
    for t in {t for s in graph.stages for t in s.reads}:
        cols = {}
        for c, v in tables[t].items():
            arr = np.asarray(v)
            dt = jnp.asarray(arr[:0]).dtype  # jax-canonical (x64 demotion)
            shape = (b,) + arr.shape[1:] if t == fact else arr.shape
            cols[c] = jax.ShapeDtypeStruct(shape, dt)
        env[t] = cols
    env[ROW_VALID_KEY] = jax.ShapeDtypeStruct((b,), jnp.bool_)
    params = plan_params(graph.plan)
    if params:
        env[PARAMS_KEY] = {
            n: jax.ShapeDtypeStruct((), jnp.float32) for n in params
        }
    segs = graph.needs_segments
    if segs:
        env[ROW_SEG_KEY] = jax.ShapeDtypeStruct((b,), jnp.int32)
        env[SEG_SLOTS_KEY] = jax.ShapeDtypeStruct((4,), jnp.int32)
        env[SEG_COUNT_KEY] = jax.ShapeDtypeStruct((), jnp.int32)
    # mirror the engine's baked dim-sort injection (concrete arrays are fine
    # under eval_shape) so abstract execution traces the same Join program —
    # including the gather-join kernel path when the join qualifies — that
    # serving will run, not just the argsort fallback
    ds = {}
    for p in walk_plan(graph.plan):
        if isinstance(p, Join) and p.dim_table in tables:
            tab = tables[p.dim_table]
            if p.dim_key in tab:
                ds[p.dim_table] = dimsort_entry(tab[p.dim_key])
    if ds:
        env[DIMSORT_KEY] = ds

    state = None
    for stage in graph.stages:
        w = f"stage {stage.index} ({stage.label})"
        if stage.kind == "pure":
            try:
                state = jax.eval_shape(stage.fn, env)
            except Exception as e:
                out.append(violation(
                    R.SCHEMA_EXEC,
                    f"abstract execution failed at bucket {b}: "
                    f"{type(e).__name__}: {e}", w))
                return None
            cols, valid, seg = state
            if set(cols) != set(stage.out_columns):
                out.append(violation(
                    R.SCHEMA_EXEC,
                    f"abstract output columns {sorted(cols)} != declared "
                    f"{sorted(stage.out_columns)}", w))
                return None
            if valid.dtype != jnp.bool_:
                out.append(violation(
                    R.SCHEMA_DTYPE,
                    f"validity mask has dtype {valid.dtype}, expected "
                    f"bool", w))
        else:
            cols, valid, seg = state
            zero = {
                k: np.zeros((0,) + tuple(v.shape[1:]), dtype=v.dtype)
                for k, v in cols.items()
            }
            try:
                res = run_udf(stage.udf, zero)
            except Exception as e:
                out.append(violation(
                    R.SCHEMA_EXEC,
                    f"zero-row host execution failed: "
                    f"{type(e).__name__}: {e}", w))
                return None
            if set(res) != set(stage.out_columns):
                out.append(violation(
                    R.SCHEMA_EXEC,
                    f"host output columns {sorted(res)} != declared "
                    f"{sorted(stage.out_columns)}", w))
                return None
            mid = {
                k: jax.ShapeDtypeStruct(
                    (b,) + tuple(np.asarray(v).shape[1:]),
                    jnp.asarray(np.asarray(v)[:0]).dtype,
                )
                for k, v in res.items()
            }
            mid[MID_VALID] = jax.ShapeDtypeStruct((b,), jnp.bool_)
            if segs:
                mid[MID_SEG] = jax.ShapeDtypeStruct((b,), jnp.int32)
            env = dict(env)
            env[MID_TABLE] = mid
            state = (
                {k: v for k, v in mid.items() if k not in (MID_VALID, MID_SEG)},
                mid[MID_VALID],
                mid.get(MID_SEG),
            )
    return state


def _compare_buckets(graph, s1, s2, b1: int, b2: int) -> list[Violation]:
    out: list[Violation] = []
    last = graph.stages[-1]
    w = f"stage {last.index} ({last.label})"
    cols1, valid1, seg1 = s1
    cols2, valid2, seg2 = s2
    for c in cols1:
        if c not in cols2:
            continue
        if cols1[c].dtype != cols2[c].dtype:
            out.append(violation(
                R.SCHEMA_DTYPE,
                f"column {c!r} drifts dtype across buckets: "
                f"{cols1[c].dtype} at {b1} vs {cols2[c].dtype} at {b2}", w))
        if not cols1[c].shape or not cols2[c].shape:
            continue
        d1, d2 = cols1[c].shape[0], cols2[c].shape[0]
        if d1 != d2 and d1 * b2 != d2 * b1:
            out.append(violation(
                R.BUCKET_SAFETY,
                f"column {c!r} leading dim neither bucket-independent nor "
                f"bucket-proportional ({d1} at {b1} vs {d2} at {b2}) — "
                f"re-bucketing would retrace", w))
    if graph.needs_segments and seg2 is None:
        out.append(violation(
            R.SEGMENT_THREADING,
            "graph needs segment ids but drops them before the final "
            "stage", w))
    return out


# ---------------------------------------------------------------------------
# Convenience front door
# ---------------------------------------------------------------------------


def verify_graph(
    graph,
    tables: Optional[dict] = None,
    *,
    mode: str = "strict",
    context: str = "plan",
) -> list[str]:
    """Run all graph (and, given tables, exec) checks and apply ``mode``."""
    mode = resolve_verify_mode(mode)
    if mode == "off":
        return []
    vs = check_graph(graph)
    if tables is not None:
        vs += check_exec(graph, tables)
    return enforce(vs, mode, context)


def verify_plan(
    plan,
    tables: Optional[dict] = None,
    *,
    mode: str = "strict",
    context: str = "plan",
) -> list[str]:
    """Lower ``plan`` to a StageGraph and verify it."""
    from repro.exec.stages import build_stage_graph

    mode = resolve_verify_mode(mode)
    if mode == "off":
        return []
    return verify_graph(
        build_stage_graph(plan), tables, mode=mode, context=context
    )
