"""``python -m repro.analysis`` — the static-analysis CI gate.

Runs (1) the concurrency/forbidden-pattern lint over the package sources
and (2) the plan verifier, in strict coverage, over a deterministic scenario
sweep that exercises every lowering path the optimizer can emit today:
MLtoSQL projection plans, fully-fused MLtoDNN TensorOps, split
``TensorOp → MLUdf → TensorOp`` chains with ``__pv_*`` block columns,
monolithic host MLUdfs (both fallback and cost-model-chosen), segmented
aggregates, and relational-kernel chains (filter→join→group-by with
min/max over a unique-key dim table). Exits nonzero on any violation,
printing each with its rule id.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.analysis.rules import AnalysisResult, Violation, rule_catalog


def _toy_pipeline(with_udf: bool = False):
    """A hand-built featurize+linear pipeline (no training: fixed weights,
    so the gate is deterministic and fast)."""
    from repro.ml.pipeline import InputSpec, PipelineNode, TrainedPipeline

    nodes = [
        PipelineNode("concat", ["a", "b"], ["num_raw"], {}),
        PipelineNode(
            "scaler", ["num_raw"], ["num_scaled"],
            {
                "offset": np.array([0.1, -0.2]),
                "scale": np.array([1.5, 0.75]),
            },
        ),
        PipelineNode("concat", ["num_scaled"], ["features"], {}),
    ]
    feat = "features"
    if with_udf:
        def _bump(x):
            return x + 0.125

        _bump.__fingerprint_token__ = "analysis-cli-python-udf-v1"
        nodes.append(
            PipelineNode("python_udf", [feat], ["tweaked"], {"fn": _bump})
        )
        feat = "tweaked"
    nodes.append(
        PipelineNode(
            "linear", [feat], ["score", "label"],
            {
                "weights": np.array([0.8, -0.5]),
                "bias": 0.25,
                "post": "logistic",
            },
        )
    )
    return TrainedPipeline(
        inputs=[InputSpec("a", "numeric"), InputSpec("b", "numeric")],
        outputs=["score", "label"],
        nodes=nodes,
    )


def _scenarios():
    """(name, PredictionQuery, OptimizerOptions, tables) per lowering path."""
    from repro.core.cost import CostModel
    from repro.core.ir import (
        LAggregate,
        LFilter,
        LJoin,
        LPredict,
        LScan,
        PredictionQuery,
    )
    from repro.core.optimizer import OptimizerOptions
    from repro.relational.expr import Bin, Col, Const

    rng = np.random.default_rng(7)
    tables = {
        "t": {
            "a": rng.normal(size=32),
            "b": rng.normal(size=32),
            "k": rng.integers(0, 8, size=32).astype(np.int32),
        },
        # unique int keys + f32 payload: qualifies for the gather-join kernel
        "d": {
            "dk": np.arange(8, dtype=np.int32),
            "v1": (np.arange(8) * 0.25).astype(np.float32),
        },
    }

    def scan():
        return LScan("t", ["a", "b", "k"])

    def predict(child, with_udf=False):
        return LPredict(
            child, _toy_pipeline(with_udf), ["score", "label"]
        )

    def q(plan):
        return PredictionQuery(plan)

    def opts(transform):
        return OptimizerOptions(transform=transform, verify="off")

    yield ("mltosql", q(predict(scan())), opts("sql"), tables)
    yield ("mltodnn-full", q(predict(scan())), opts("dnn"), tables)
    yield ("mltodnn-split", q(predict(scan(), with_udf=True)),
           opts("dnn"), tables)
    yield ("host-udf", q(predict(scan())), opts("none"), tables)
    yield (
        "filtered-aggregate",
        q(LAggregate(
            LFilter(predict(scan()), Bin("gt", Col("score"), Const(0.5))),
            [("n", "count", ""), ("avg_score", "mean", "score")],
        )),
        opts("dnn"),
        tables,
    )
    # filter→join→group-by over the relational kernels (gather_join +
    # segment_agg): join brings an f32 payload off a unique-key dim table,
    # the filter folds into the aggregate mask, min/max exercise the
    # extremum lanes
    yield (
        "relational-kernels",
        q(LAggregate(
            LFilter(
                LJoin(scan(), "d", "k", "dk", ["v1"]),
                Bin("gt", Col("a"), Const(0.0)),
            ),
            [
                ("n", "count", ""), ("sum_v1", "sum", "v1"),
                ("min_v1", "min", "v1"), ("max_v1", "max", "v1"),
                ("avg_a", "mean", "a"),
            ],
        )),
        opts("none"),
        tables,
    )
    # join feeding a predict split: the kernel join fuses into the pure
    # prefix stage around the host residual
    yield (
        "join-predict-split",
        q(predict(LJoin(scan(), "d", "k", "dk", ["v1"]), with_udf=True)),
        opts("dnn"),
        tables,
    )
    # the cost model prices the split's boundary crossings above the tensor
    # speedup and collapses it to one monolithic host MLUdf
    cost_opts = OptimizerOptions(
        transform="dnn", verify="off",
        cost_model=CostModel(
            crossing_ns_per_row=1e7, segment_fixed_us=1e6
        ),
    )
    yield ("cost-monolithic", q(predict(scan(), with_udf=True)),
           cost_opts, tables)


def _verify_scenarios() -> AnalysisResult:
    from repro.analysis.verifier import check_exec, check_graph, check_logical
    from repro.core.optimizer import RavenOptimizer
    from repro.exec.stages import build_stage_graph

    res = AnalysisResult()
    for name, query, opts, tables in _scenarios():
        vs = check_logical(query, where="input")
        plan, _report = RavenOptimizer(options=opts).optimize(query)
        graph = build_stage_graph(plan)
        vs += check_graph(graph)
        vs += check_exec(graph, tables)
        for v in vs:
            v.where = f"{name}: {v.where}" if v.where else name
        res.violations += vs
        if not vs:
            res.passed.append(
                f"scenario {name!r}: {len(graph.stages)} stage(s) verified "
                f"(logical+graph+exec)"
            )
    return res


def _verify_lifecycle() -> AnalysisResult:
    """Drive one publish → shadow → split → cutover lifecycle end-to-end
    and audit the recorded evidence with :func:`check_registry` — the
    registry rules need real state to replay, so the gate makes some."""
    from repro.analysis.registry_check import check_registry
    from repro.session import connect

    res = AnalysisResult()
    rng = np.random.default_rng(11)
    tables = {
        "t": {
            "a": rng.normal(size=64),
            "b": rng.normal(size=64),
            "k": rng.integers(0, 8, size=64).astype(np.int32),
        },
    }
    db = connect(tables, stats="auto")
    db.models.publish("gate", _toy_pipeline())
    prep = db.sql(
        "SELECT * FROM PREDICT(model='gate', data=t) AS p"
    ).prepare(transform="sql")
    prep.serve("gate_q")
    batch = {"a": rng.normal(size=16), "b": rng.normal(size=16),
             "k": rng.integers(0, 8, size=16).astype(np.int32)}
    prep.submit(batch)
    db.flush()

    db.models.publish("gate", _toy_pipeline(with_udf=True), warm="sync")
    db.models.shadow("gate", 2)
    prep.submit(batch)
    db.flush()
    db.models.split("gate", {2: 0.25})
    prep.submit(batch)
    db.flush()
    db.models.split("gate", {})
    db.models.cutover("gate", 2)
    prep.submit(batch)
    db.flush()
    db.models.retire("gate", 1)

    vs = check_registry(db)
    for v in vs:
        v.where = f"lifecycle: {v.where}" if v.where else "lifecycle"
    res.violations += vs
    if not vs:
        snap = db.models.snapshot()["gate"]
        states = [f"v{v['version']}={v['state']}" for v in snap["versions"]]
        res.passed.append(
            "lifecycle scenario: publish→shadow→split→cutover→retire "
            f"audited clean ({', '.join(states)})"
        )
    return res


def _verify_faultdrill() -> AnalysisResult:
    """Drive the fault-tolerance machinery end-to-end — transient faults
    retried through the scheduler, a policy-triggered rollback, and a
    journal round-trip recovered into a fresh session — and audit both
    sessions with :func:`check_registry` (which includes the retry-state /
    breaker-state / recovery-journal rules)."""
    import tempfile

    from repro.analysis.registry_check import check_registry
    from repro.exec.faults import FaultPlan, RetryPolicy, RollbackPolicy
    from repro.options import ConnectOptions, ServeOptions
    from repro.session import connect

    res = AnalysisResult()
    rng = np.random.default_rng(13)
    tables = {
        "t": {
            "a": rng.normal(size=64),
            "b": rng.normal(size=64),
            "k": rng.integers(0, 8, size=64).astype(np.int32),
        },
    }
    batch = {"a": rng.normal(size=16), "b": rng.normal(size=16),
             "k": rng.integers(0, 8, size=16).astype(np.int32)}
    plan = FaultPlan({"stage": {"times": 2}}, seed=3)
    with tempfile.TemporaryDirectory() as cache:
        db = connect(tables, stats="auto", options=ConnectOptions(
            cache_dir=cache, faults=plan,
        ))
        db.models.publish("gate", _toy_pipeline())
        prep = db.sql(
            "SELECT * FROM PREDICT(model='gate', data=t) AS p"
        ).prepare(transform="sql")
        prep.serve("gate_q", options=ServeOptions(
            retry=RetryPolicy(max_attempts=4, backoff_ms=0.25),
        ))
        for _ in range(3):
            req = prep.submit(batch)
            db.flush()
            req.wait(timeout=60.0)
        # v2 must pickle (the journal persists pipelines); the with_udf
        # variant closes over a local function, which pickle rejects —
        # exactly the fail-soft skip path, but not what this drill tests
        db.models.publish("gate", _toy_pipeline(), warm="sync")
        db.models.cutover("gate", 2)
        for _ in range(3):
            req = prep.submit(batch)
            db.flush()
            req.wait(timeout=60.0)
        restored = db.models.check_rollback("gate", RollbackPolicy(
            max_p99_ratio=1e-9, min_requests=1,
        ))
        vs = check_registry(db)
        retries = db.server.scheduler.retries
        if restored is None or restored.version != 1:
            vs.append(Violation(
                "recovery-journal",
                f"forced rollback policy did not restore v1 (got "
                f"{restored})", where="faultdrill",
            ))
        if not retries:
            vs.append(Violation(
                "retry-state",
                "injected transient stage faults produced no scheduler "
                "retries", where="faultdrill",
            ))
        db.close()

        db2 = connect(tables, stats="auto", options=ConnectOptions(
            cache_dir=cache,
        ))
        counts = db2.recover()
        if not counts.get("recovered") or counts.get("skipped"):
            vs.append(Violation(
                "recovery-journal",
                f"recover() did not restore the journaled topology: "
                f"{counts}", where="faultdrill",
            ))
        vs += check_registry(db2)
        db2.close()
    for v in vs:
        v.where = f"faultdrill: {v.where}" if v.where else "faultdrill"
    res.violations += vs
    if not vs:
        res.passed.append(
            f"faultdrill scenario: {retries} transient retries recovered, "
            f"rollback restored v1, journal recovered clean "
            f"({counts['routes']} route(s))"
        )
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Raven static analysis: plan verifier + concurrency lint",
    )
    ap.add_argument(
        "--lint-only", action="store_true",
        help="run only the source lint (skip plan verification)",
    )
    ap.add_argument(
        "--verify-only", action="store_true",
        help="run only the plan-verification sweep (skip the source lint)",
    )
    ap.add_argument(
        "--rules", action="store_true", help="print the rule catalog and exit",
    )
    args = ap.parse_args(argv)

    if args.rules:
        for r in rule_catalog():
            print(f"{r.id:<28} {r.scope:<8} {r.description}")
        return 0

    result = AnalysisResult()
    if not args.verify_only:
        from repro.analysis.concurrency import lint_repo

        result.extend(lint_repo())
    if not args.lint_only:
        result.extend(_verify_scenarios())
        result.extend(_verify_lifecycle())
        result.extend(_verify_faultdrill())

    print(result.describe())
    if result.violations:
        print(
            f"\nanalysis FAILED: {len(result.violations)} violation(s)",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
