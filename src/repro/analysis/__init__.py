"""Static analysis for Raven: plan/StageGraph verifier + concurrency lint.

Public surface:

  * :func:`repro.analysis.verifier.check_logical` /
    :func:`~repro.analysis.verifier.check_graph` /
    :func:`~repro.analysis.verifier.check_exec` — the three verifier layers;
  * :func:`repro.analysis.verifier.verify_plan` — lower + verify in one call;
  * :func:`repro.analysis.concurrency.lint_repo` — lock-discipline and
    forbidden-pattern lint over the package sources;
  * ``python -m repro.analysis`` — both passes as a CI gate.
"""
from repro.analysis.rules import (  # noqa: F401
    AnalysisResult,
    Rule,
    VerificationWarning,
    Violation,
    rule_catalog,
)
from repro.analysis.runtime import (  # noqa: F401
    RuntimeInvariantError,
    asserts_enabled,
    runtime_assert,
)
from repro.analysis.verifier import (  # noqa: F401
    check_exec,
    check_graph,
    check_logical,
    resolve_verify_mode,
    verify_graph,
    verify_plan,
)
