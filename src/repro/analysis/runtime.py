"""Runtime-assertion mode: verifier invariants as cheap serving-path checks.

``RAVEN_ANALYSIS_ASSERTS=1`` arms :func:`runtime_assert` call sites placed
at the scheduler and query-server hot spots (request routing, group
dispatch, result finish). They are read-at-call-time so a test can flip the
env var without rebuilding anything, and they are ordinary ``if`` checks —
never ``assert`` statements — so ``python -O`` cannot silently strip them.
Disabled (the default), each site costs one dict lookup.
"""
from __future__ import annotations

import os


class RuntimeInvariantError(AssertionError):
    """A serving-path invariant failed under RAVEN_ANALYSIS_ASSERTS=1."""


def asserts_enabled() -> bool:
    return os.environ.get("RAVEN_ANALYSIS_ASSERTS", "") not in (
        "", "0", "false", "off",
    )


def runtime_assert(cond: bool, message: str) -> None:
    """Raise :class:`RuntimeInvariantError` when armed and ``cond`` fails.

    Call sites should guard expensive condition construction with
    :func:`asserts_enabled` themselves; passing a cheap boolean here is
    fine unguarded.
    """
    if not cond and asserts_enabled():
        raise RuntimeInvariantError(f"RAVEN_ANALYSIS_ASSERTS: {message}")
