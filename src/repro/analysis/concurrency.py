"""Concurrency and forbidden-pattern lint over the runtime sources.

The serving stack (scheduler, pipeline executor, artifact store, query
server) is threaded, and its documented lock discipline lives only in
comments. This module turns that discipline into an AST pass:

  * **lock-order** — build the lock-acquisition graph from ``with
    self._lock:`` blocks (including one-level edges through ``self.method()``
    calls made while holding a lock) and reject cycles;
  * **lock-reentry** — re-acquiring a held non-reentrant ``threading.Lock``
    deadlocks; flag it statically (``RLock``/``Condition`` are reentrant);
  * **unlocked-mutation** — an instance field assigned both inside and
    outside lock blocks is a data race waiting for a scheduler. Helper
    methods whose every intra-class call site holds a lock inherit that
    lock (the ``_accrue``-style caller-holds-lock idiom); ``__init__`` is
    exempt (no concurrent access before construction completes).

Plus repo-wide forbidden patterns: non-content-addressed
``__fingerprint_token__`` assignments, host callbacks (numpy/time/print)
inside jitted stage bodies, and ``time.time()`` used for duration
measurement in runtime code.

Suppressions: a line ending in ``# analysis: allow[rule-id]`` silences that
rule on that line (used where the discipline is intentionally violated and
documented).
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Optional

from repro.analysis import rules as R
from repro.analysis.rules import AnalysisResult, Violation, violation

# lock-discipline lint targets (relative to the repro package root); the
# pattern rules below run over every source file
CONCURRENCY_FILES = (
    "exec/scheduler.py",
    "exec/pipeline.py",
    "exec/artifact_store.py",
    "serve/query_server.py",
    "serve/registry.py",
)

# runtime subtrees where wall-clock timing is forbidden (perf_counter /
# monotonic only — time.time() steps under NTP and breaks durations)
RUNTIME_DIRS = ("exec", "serve", "core", "relational")

_LOCK_FACTORIES = {"Lock", "RLock", "Condition"}
_REENTRANT = {"RLock", "Condition"}  # Condition() wraps an RLock


def _allowed(lines: list[str], lineno: int, rule_id: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    text = lines[lineno - 1]
    return (
        f"# analysis: allow[{rule_id}]" in text
        or text.rstrip().endswith("# analysis: allow")
    )


# ---------------------------------------------------------------------------
# Lock-discipline lint
# ---------------------------------------------------------------------------


@dataclass
class _MethodInfo:
    name: str
    # (field path, held locks at mutation, lineno)
    mutations: list[tuple[str, tuple[str, ...], int]] = field(
        default_factory=list)
    # (lock field, locks already held, lineno)
    acquisitions: list[tuple[str, tuple[str, ...], int]] = field(
        default_factory=list)
    # (callee method name, locks held at call, lineno)
    calls: list[tuple[str, tuple[str, ...], int]] = field(
        default_factory=list)


def _self_attr_path(node: ast.AST) -> Optional[str]:
    """Dotted path for ``self.a.b…`` (subscripts collapse to their base)."""
    parts: list[str] = []
    while True:
        if isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Name):
            return ".".join(reversed(parts)) if node.id == "self" else None
        else:
            return None


def _lock_fields(cls: ast.ClassDef) -> dict[str, str]:
    """``self.X = threading.Lock()`` style fields -> factory name."""
    locks: dict[str, str] = {}
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign) or not isinstance(
            node.value, ast.Call
        ):
            continue
        fn = node.value.func
        name = None
        if isinstance(fn, ast.Attribute) and fn.attr in _LOCK_FACTORIES:
            name = fn.attr
        elif isinstance(fn, ast.Name) and fn.id in _LOCK_FACTORIES:
            name = fn.id
        if name is None:
            continue
        for t in node.targets:
            path = _self_attr_path(t)
            if path and "." not in path:
                locks[path] = name
    return locks


def _analyze_method(fn: ast.FunctionDef, locks: dict[str, str]) -> _MethodInfo:
    info = _MethodInfo(fn.name)

    def visit(node: ast.AST, held: tuple[str, ...]) -> None:
        if isinstance(node, ast.With):
            new_held = held
            for item in node.items:
                path = _self_attr_path(item.context_expr)
                if path in locks:
                    info.acquisitions.append((path, new_held, node.lineno))
                    new_held = new_held + (path,)
            for stmt in node.body:
                visit(stmt, new_held)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            # nested closures run later, under unknown lock state: skip
            return
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                path = _self_attr_path(t)
                if path and path not in locks:
                    info.mutations.append((path, held, node.lineno))
        if isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id == "self"
            ):
                info.calls.append((f.attr, held, node.lineno))
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for stmt in fn.body:
        visit(stmt, ())
    return info


def _lint_class(
    cls: ast.ClassDef,
    lines: list[str],
    relpath: str,
    edges: dict[tuple[str, str], str],
) -> list[Violation]:
    locks = _lock_fields(cls)
    if not locks:
        return []
    out: list[Violation] = []
    methods = {
        n.name: _analyze_method(n, locks)
        for n in cls.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }

    # reentry + direct acquisition-order edges
    for m in methods.values():
        for lock, held, lineno in m.acquisitions:
            where = f"{relpath}:{lineno}"
            if lock in held and locks[lock] not in _REENTRANT:
                if not _allowed(lines, lineno, R.LOCK_REENTRY.id):
                    out.append(violation(
                        R.LOCK_REENTRY,
                        f"{cls.name}.{m.name} re-acquires non-reentrant "
                        f"lock self.{lock} while holding it", where))
            for h in held:
                if h != lock:
                    edges.setdefault(
                        (f"{cls.name}.{h}", f"{cls.name}.{lock}"), where)

    # one-level interprocedural edges: calling a method that acquires a
    # lock while already holding one orders (held -> callee's lock)
    for m in methods.values():
        for callee, held, lineno in m.calls:
            if not held or callee not in methods:
                continue
            for lock, inner_held, _ in methods[callee].acquisitions:
                if inner_held:
                    continue  # already ordered by its own outer lock
                for h in held:
                    if h != lock:
                        edges.setdefault(
                            (f"{cls.name}.{h}", f"{cls.name}.{lock}"),
                            f"{relpath}:{lineno}")

    # caller-holds-lock promotion: a helper only ever invoked under a lock
    # inherits that lock for its (top-level) mutations
    call_sites: dict[str, list[tuple[str, ...]]] = {}
    for m in methods.values():
        if m.name == "__init__":
            continue
        for callee, held, _ in m.calls:
            if callee in methods:
                call_sites.setdefault(callee, []).append(held)
    promoted = {
        name for name, sites in call_sites.items()
        if sites and all(s for s in sites)
    }

    # unlocked-mutation: a path assigned both under a lock and outside one
    locked_paths: set[str] = set()
    unlocked: dict[str, tuple[str, int]] = {}
    for m in methods.values():
        if m.name == "__init__":
            continue
        inherits = m.name in promoted
        for path, held, lineno in m.mutations:
            if held or inherits:
                locked_paths.add(path)
            elif path not in unlocked:
                unlocked[path] = (m.name, lineno)
    for path in sorted(locked_paths & set(unlocked)):
        mname, lineno = unlocked[path]
        if _allowed(lines, lineno, R.UNLOCKED_MUTATION.id):
            continue
        out.append(violation(
            R.UNLOCKED_MUTATION,
            f"{cls.name}.{mname} mutates self.{path} outside any lock, "
            f"but it is also mutated under a lock elsewhere",
            f"{relpath}:{lineno}"))
    return out


def _check_lock_cycles(edges: dict[tuple[str, str], str]) -> list[Violation]:
    graph: dict[str, set[str]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    out: list[Violation] = []
    seen_cycles: set[frozenset] = set()
    for start in graph:
        stack = [(start, [start])]
        while stack:
            node, path = stack.pop()
            for nxt in graph.get(node, ()):
                if nxt == start:
                    cyc = frozenset(path)
                    if cyc in seen_cycles:
                        continue
                    seen_cycles.add(cyc)
                    where = edges.get((node, nxt), "")
                    out.append(violation(
                        R.LOCK_ORDER,
                        "lock-order inversion: "
                        + " -> ".join(path + [start]), where))
                elif nxt not in path:
                    stack.append((nxt, path + [nxt]))
    return out


# ---------------------------------------------------------------------------
# Forbidden-pattern lint (repo-wide)
# ---------------------------------------------------------------------------


def _token_value_violations(
    value: ast.AST, lines: list[str], relpath: str
) -> list[Violation]:
    out = []
    for node in ast.walk(value):
        bad = None
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in (
                "id", "repr", "hash", "hex", "vars"
            ):
                bad = f"{f.id}() is identity/representation-based"
            elif (
                isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id == "time"
            ):
                bad = f"time.{f.attr}() makes the token time-dependent"
        elif isinstance(node, ast.JoinedStr) and any(
            isinstance(v, ast.FormattedValue) for v in node.values
        ):
            bad = (
                "interpolated f-string — object interpolation embeds "
                "reprs/addresses"
            )
        if bad is None:
            continue
        lineno = getattr(node, "lineno", value.lineno)
        if not _allowed(lines, lineno, R.FINGERPRINT_HYGIENE_SRC.id):
            out.append(violation(
                R.FINGERPRINT_HYGIENE_SRC,
                f"__fingerprint_token__ built from {bad}",
                f"{relpath}:{lineno}"))
    return out


def _jitted_bodies(tree: ast.Module) -> list[ast.FunctionDef]:
    """Function bodies that execute under jit: args to ``jax.jit``/``jit``
    resolvable by name, plus the ``fn`` closures built by ``pure_step``."""
    defs: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, node)
    bodies: list[ast.FunctionDef] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            is_jit = (isinstance(f, ast.Attribute) and f.attr == "jit") or (
                isinstance(f, ast.Name) and f.id == "jit"
            )
            if is_jit and node.args and isinstance(node.args[0], ast.Name):
                fn = defs.get(node.args[0].id)
                if fn is not None:
                    bodies.append(fn)
    pure_step = defs.get("pure_step")
    if pure_step is not None:
        bodies += [
            n for n in ast.walk(pure_step)
            if isinstance(n, ast.FunctionDef) and n.name == "fn"
        ]
    return bodies


def _host_in_jit_violations(
    tree: ast.Module, lines: list[str], relpath: str
) -> list[Violation]:
    out = []
    for fn in _jitted_bodies(tree):
        for node in ast.walk(fn):
            bad = None
            if isinstance(node, ast.Name) and node.id == "np":
                bad = "numpy (np) host computation"
            elif (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "time"
            ):
                bad = f"time.{node.attr} host callback"
            elif (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"
            ):
                bad = "print() host callback"
            if bad is None:
                continue
            lineno = getattr(node, "lineno", fn.lineno)
            if not _allowed(lines, lineno, R.HOST_IN_JIT.id):
                out.append(violation(
                    R.HOST_IN_JIT,
                    f"{bad} inside jitted body {fn.name!r} — it would run "
                    f"at trace time or break under jit",
                    f"{relpath}:{lineno}"))
    return out


def _pattern_violations(
    tree: ast.Module, lines: list[str], relpath: str
) -> list[Violation]:
    out: list[Violation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            if any(
                isinstance(t, ast.Attribute)
                and t.attr == "__fingerprint_token__"
                for t in node.targets
            ):
                out += _token_value_violations(node.value, lines, relpath)
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "time"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "time"
        ):
            top = relpath.replace("\\", "/").split("/")[0]
            if top in RUNTIME_DIRS and not _allowed(
                lines, node.lineno, R.WALLCLOCK_TIMING.id
            ):
                out.append(violation(
                    R.WALLCLOCK_TIMING,
                    "time.time() in runtime code — use perf_counter()/"
                    "monotonic() for durations",
                    f"{relpath}:{node.lineno}"))
    out += _host_in_jit_violations(tree, lines, relpath)
    return out


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------


def lint_source(
    source: str,
    relpath: str = "<string>",
    *,
    locks: bool = True,
    patterns: bool = True,
) -> list[Violation]:
    """Lint one source string (test/tooling entry point)."""
    tree = ast.parse(source)
    lines = source.splitlines()
    out: list[Violation] = []
    if locks:
        edges: dict[tuple[str, str], str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                out += _lint_class(node, lines, relpath, edges)
        out += _check_lock_cycles(edges)
    if patterns:
        out += _pattern_violations(tree, lines, relpath)
    return out


def lint_repo(src_root: Optional[str] = None) -> AnalysisResult:
    """Lint the repro package: lock discipline on the threaded runtime
    files, forbidden patterns everywhere."""
    if src_root is None:
        import repro

        src_root = os.path.dirname(os.path.abspath(repro.__file__))
    result = AnalysisResult()
    edges: dict[tuple[str, str], str] = {}
    lock_targets = {os.path.join(src_root, p) for p in CONCURRENCY_FILES}
    n_files = 0
    for dirpath, _, filenames in os.walk(src_root):
        for name in sorted(filenames):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            relpath = os.path.relpath(path, src_root)
            with open(path) as f:
                source = f.read()
            try:
                tree = ast.parse(source)
            except SyntaxError as e:
                result.violations.append(Violation(
                    "lock-order", f"unparseable source: {e}", relpath))
                continue
            lines = source.splitlines()
            n_files += 1
            if path in lock_targets:
                for node in ast.walk(tree):
                    if isinstance(node, ast.ClassDef):
                        result.violations += _lint_class(
                            node, lines, relpath, edges)
            result.violations += _pattern_violations(tree, lines, relpath)
    result.violations += _check_lock_cycles(edges)
    if not result.violations:
        result.passed.append(
            f"concurrency+pattern lint over {n_files} files "
            f"({len(CONCURRENCY_FILES)} lock-discipline targets)")
    return result
