"""Static executed-cost analysis over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each instruction ONCE — a ``lax.scan``
over 126 layers contributes its body a single time, and the FSDP all-gathers
*inside* that scan are likewise counted once (we verified both empirically;
see EXPERIMENTS.md §Roofline methodology). For roofline purposes we need
*executed* totals, so this module re-derives costs from ``compiled.as_text()``
and multiplies every ``while`` body by its ``known_trip_count`` backend
config (present for all lax.scan/fori loops), recursively.

Per-device semantics: the optimized module is the per-device SPMD program, so
every number reported here is per-chip — exactly what the roofline terms
divide by.

What is counted:
  * flops       — ``dot`` ops: 2 × output elems × contracted elems (descends
                  into fusion/call bodies; convolutions similarly).
  * bytes       — HBM-traffic model: Σ over materializing instructions of
                  (operand bytes + output bytes), fusions at their boundary
                  (inputs+outputs only) — the same model as XLA's
                  HloCostAnalysis "bytes accessed", plus loop trip scaling.
  * collectives — result bytes per kind (all-gather / all-reduce /
                  reduce-scatter / all-to-all / collective-permute), trip-
                  scaled; ``-start``/``-done`` async pairs counted once.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s4": 1, "u4": 1,  # round up
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(?P<name>%[\w.\-]+)\s*=\s*(?P<rest>.*)$"
)
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(?P<name>%[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count.*?"n"\s*:\s*"(\d+)"')
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=)(%[\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops that do not touch HBM (metadata / aliasing / control)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
    "while", "conditional", "call", "fusion",  # handled by recursion/boundary
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _type_elems(type_str: str) -> int:
    # first array shape only (dot outputs are single arrays)
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 1
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s == "}" or s == "})":
            cur = None
            continue
        if s.endswith("{") and ("=" not in s.split("(")[0]):
            m = _COMP_HDR_RE.match(s)
            if m:
                cur = Computation(m.group("name"))
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        rest = m.group("rest")
        om = _OPCODE_RE.search(" " + rest)
        if not om:
            continue
        opcode = om.group(1)
        # om indices are relative to " " + rest: shift back by 1
        type_str = rest[: max(om.start() - 1, 0)].strip()
        tail = rest[om.end() - 2:]  # from '(' of the operand list
        pm = _OPERANDS_RE.match(tail)
        operand_str = pm.group(1) if pm else ""
        # operands print either bare ("%x") or type-prefixed
        # ("f32[64,128]{1,0} %x") depending on the dump flavor: keep the
        # %name token either way
        operands = []
        for o in re.split(r",(?![^\[]*\])", operand_str):
            nm = re.search(r"%[\w.\-]+", o)
            if nm:
                operands.append(nm.group(0))
        attrs = tail[pm.end():] if pm else tail
        instr = Instr(
            m.group("name"), type_str, opcode, operands, attrs,
            is_root=line.lstrip().startswith("ROOT "),
        )
        cur.instrs.append(instr)
        cur.by_name[instr.name] = instr
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict[str, float] = field(default_factory=dict)
    transcendentals: float = 0.0
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.transcendentals += mult * other.transcendentals
        self.unknown_trip_loops += other.unknown_trip_loops
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + mult * v


def _operand_type(comp: Computation, name: str) -> str:
    ins = comp.by_name.get(name)
    return ins.type_str if ins else ""


def _inplace_update_bytes(
    comps: dict[str, Computation], comp: Computation, ins: Instr
) -> float | None:
    """In-place update ops alias their buffer operand: XLA writes only the
    update region (dynamic-update-slice) / the scattered rows (scatter), so
    counting operands+output would inflate traffic by buffer/update — ~80x
    for per-layer KV-cache writes into (L,B,S,KH,hd) stacks. Returns the
    corrected byte count, or None if ``ins`` is not such an op.

    Handles both standalone ops and fusions whose ROOT is the update op
    (XLA wraps them as '*dynamic-update-slice*_fusion' / 'wrapped_scatter')."""
    _UPDATES = ("dynamic-update-slice", "scatter")
    _SLICES = ("dynamic-slice", "gather", "slice")
    op = ins.opcode
    if op in _SLICES:
        # slicing/gathering touches only the extracted region: read + write
        # of the result (counting the full source would charge e.g. every
        # per-layer KV-cache slice with the whole (L,B,S,KH,hd) stack, or
        # every embedding lookup with the whole vocab table)
        return 2.0 * _type_bytes(ins.type_str)
    if op in _UPDATES:
        upd_idx = 1 if op == "dynamic-update-slice" else 2
        if len(ins.operands) <= upd_idx:
            return float(_type_bytes(ins.type_str))
        # read update + write region (+ small indices, ignored)
        return 2.0 * _type_bytes(_operand_type(comp, ins.operands[upd_idx]))
    if op != "fusion":
        return None
    called = _CALLED_RE.findall(ins.attrs)
    sub = comps.get(called[0]) if called else None
    if sub is None or not sub.instrs:
        return None
    roots = [i for i in sub.instrs if i.is_root]
    root = roots[0] if roots else sub.instrs[-1]
    if root.opcode in _UPDATES:
        upd_idx = 1 if root.opcode == "dynamic-update-slice" else 2
        if len(root.operands) <= upd_idx:
            return float(_type_bytes(ins.type_str))
        return 2.0 * _type_bytes(_operand_type(sub, root.operands[upd_idx]))
    # cast/slice-only fusions: bodies made purely of dtype casts, layout
    # bitcasts and slice/update ops. The casts exist because the CPU backend
    # emulates bf16 in f32 and round-trips the FULL loop-carried buffer per
    # iteration — on the TPU target (native bf16, in-place DUS aliasing) only
    # the touched region moves. Charge 2x the updated/sliced region.
    _CASTY = {"convert", "bitcast", "copy", "reshape"} | set(_SLICES) | set(
        _UPDATES
    )
    body = [
        i for i in sub.instrs if i.opcode not in ("parameter", "constant")
    ]
    if body and all(i.opcode in _CASTY for i in body):
        touched = 0.0
        for i in body:
            if i.opcode == "dynamic-update-slice" and len(i.operands) > 1:
                touched += 2.0 * _type_bytes(
                    _operand_type(sub, i.operands[1])
                )
            elif i.opcode == "scatter" and len(i.operands) > 2:
                touched += 2.0 * _type_bytes(
                    _operand_type(sub, i.operands[2])
                )
            elif i.opcode in _SLICES:
                touched += 2.0 * _type_bytes(i.type_str)
        if touched > 0:
            return touched
        return 2.0 * _type_bytes(ins.type_str)  # pure cast: read + write once

    # general fusion: per-operand utilization — a parameter consumed ONLY by
    # slice/gather ops contributes its slice results, not the full buffer
    # (catches convert-of-a-cache-slice fusions whose root is the convert)
    params = [i for i in sub.instrs if i.opcode == "parameter"]
    if not params:
        return None
    sliced_any = False
    total = float(_type_bytes(ins.type_str))  # output write
    by_param = {pi.name: pi for pi in params}
    consumers: dict[str, list] = {pi.name: [] for pi in params}
    for j in sub.instrs:
        for o in j.operands:
            if o in by_param:
                consumers[o].append(j)
    for operand, pi in zip(ins.operands, params):
        cons = consumers.get(pi.name, [])
        if cons and all(c.opcode in _SLICES for c in cons):
            total += sum(_type_bytes(c.type_str) for c in cons)
            sliced_any = True
        else:
            total += _type_bytes(_operand_type(comp, operand))
    return total if sliced_any else None


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = _type_elems(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs_t = _operand_type(comp, ins.operands[0]) if ins.operands else ""
    sm = _SHAPE_RE.search(lhs_t)
    contracted = 1
    if sm:
        dims = [int(d) for d in sm.group(2).split(",") if d]
        for c in cdims:
            if c < len(dims):
                contracted *= dims[c]
    return 2.0 * out_elems * contracted


def _conv_flops(comp: Computation, ins: Instr) -> float:
    # flops = 2 × output elems × (kernel elems × Cin / feature_group)
    out_elems = _type_elems(ins.type_str)
    rhs_t = _operand_type(comp, ins.operands[1]) if len(ins.operands) > 1 else ""
    sm = _SHAPE_RE.search(rhs_t)
    if not sm:
        return 0.0
    dims = [int(d) for d in sm.group(2).split(",") if d]
    out_feat = max(dims) if dims else 1  # conservative: exclude output-feature dim
    kernel = 1
    for d in dims:
        kernel *= d
    return 2.0 * out_elems * max(kernel // max(out_feat, 1), 1)


def analyze_computation(
    comps: dict[str, Computation],
    name: str,
    *,
    count_bytes: bool = True,
    _depth: int = 0,
) -> Cost:
    cost = Cost()
    comp = comps.get(name)
    if comp is None or _depth > 64:
        return cost
    for ins in comp.instrs:
        op = ins.opcode
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            if op.endswith("-done"):
                continue  # counted at -start
            cost.collective_bytes[base] = (
                cost.collective_bytes.get(base, 0.0) + _type_bytes(ins.type_str)
            )
            if count_bytes:
                cost.bytes += _type_bytes(ins.type_str) + sum(
                    _type_bytes(_operand_type(comp, o)) for o in ins.operands
                )
            continue
        if op == "dot":
            cost.flops += _dot_flops(comp, ins)
        elif op == "convolution":
            cost.flops += _conv_flops(comp, ins)
        if op == "while":
            m = _TRIP_RE.search(ins.attrs)
            trip = int(m.group(1)) if m else 1
            if not m:
                cost.unknown_trip_loops += 1
            called = _CALLED_RE.findall(ins.attrs)
            body = [c for c in called]  # condition cost is negligible but cheap
            for c in body:
                sub = analyze_computation(
                    comps, c, count_bytes=count_bytes, _depth=_depth + 1
                )
                cost.add(sub, mult=float(trip))
            continue
        if op == "conditional":
            bm = _BRANCHES_RE.search(ins.attrs)
            branches = (
                [b.strip() for b in bm.group(1).split(",")] if bm else []
            )
            for c in branches:
                # upper bound: all branches counted
                cost.add(
                    analyze_computation(
                        comps, c, count_bytes=count_bytes, _depth=_depth + 1
                    )
                )
            continue
        if op in ("call", "fusion", "custom-call", "reduce", "sort", "map",
                  "reduce-window", "select-and-scatter", "scatter",
                  "async-start"):
            # flops recursion into called computations (dot inside fusion);
            # bytes stay at the boundary (fusion = one HBM round trip)
            for c in _CALLED_RE.findall(ins.attrs):
                sub = analyze_computation(
                    comps, c, count_bytes=False, _depth=_depth + 1
                )
                cost.add(sub)
        if count_bytes and (op not in _FREE_OPS or op in ("fusion", "call")):
            fixed = _inplace_update_bytes(comps, comp, ins)
            if fixed is not None:
                cost.bytes += fixed
            else:
                cost.bytes += _type_bytes(ins.type_str) + sum(
                    _type_bytes(_operand_type(comp, o)) for o in ins.operands
                )
    return cost


def per_opcode_bytes(text: str, top: int = 12) -> list[tuple[str, float]]:
    """Trip-scaled byte attribution per opcode — the §Perf profiling view."""
    comps = parse_hlo(text)
    acc: dict[str, float] = {}

    def walk(name: str, mult: float, depth: int = 0) -> None:
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        for ins in comp.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op == "while":
                m = _TRIP_RE.search(ins.attrs)
                trip = int(m.group(1)) if m else 1
                for c in _CALLED_RE.findall(ins.attrs):
                    walk(c, mult * trip, depth + 1)
                continue
            if op.endswith("-done"):
                continue
            if op in _FREE_OPS and op not in ("fusion", "call"):
                continue
            b = _inplace_update_bytes(comps, comp, ins)
            if b is None:
                b = _type_bytes(ins.type_str) + sum(
                    _type_bytes(_operand_type(comp, o)) for o in ins.operands
                )
            acc[base] = acc.get(base, 0.0) + mult * b

    m = re.search(r"^ENTRY\s+(%[\w.\-]+)", text, re.MULTILINE)
    if m:
        walk(m.group(1), 1.0)
    return sorted(acc.items(), key=lambda kv: -kv[1])[:top]


def per_source_bytes(text: str, top: int = 15) -> list[tuple[str, float]]:
    """Trip-scaled byte attribution per op_name metadata prefix (maps bytes
    back to the jax source construct that emitted them)."""
    comps = parse_hlo(text)
    acc: dict[str, float] = {}
    name_re = re.compile(r'op_name="([^"]*)"')

    def walk(name: str, mult: float, depth: int = 0) -> None:
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                m = _TRIP_RE.search(ins.attrs)
                trip = int(m.group(1)) if m else 1
                for c in _CALLED_RE.findall(ins.attrs):
                    walk(c, mult * trip, depth + 1)
                continue
            if op.endswith("-done"):
                continue
            if op in _FREE_OPS and op not in ("fusion", "call"):
                continue
            b = _inplace_update_bytes(comps, comp, ins)
            if b is None:
                b = _type_bytes(ins.type_str) + sum(
                    _type_bytes(_operand_type(comp, o)) for o in ins.operands
                )
            nm = name_re.search(ins.attrs)
            key = "?"
            if nm:
                parts = nm.group(1).split("/")
                # keep the informative tail: last two path segments
                key = "/".join(parts[-2:]) if len(parts) > 1 else parts[-1]
            acc[key] = acc.get(key, 0.0) + mult * b

    m = re.search(r"^ENTRY\s+(%[\w.\-]+)", text, re.MULTILINE)
    if m:
        walk(m.group(1), 1.0)
    return sorted(acc.items(), key=lambda kv: -kv[1])[:top]


def analyze_hlo(text: str) -> Cost:
    comps = parse_hlo(text)
    entry = None
    m = re.search(r"^ENTRY\s+(%[\w.\-]+)", text, re.MULTILINE)
    if m:
        entry = m.group(1)
    else:  # fall back: computation named %main*
        for n in comps:
            if n.startswith("%main"):
                entry = n
                break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return analyze_computation(comps, entry)
