"""Production-shaped training driver (CPU-runnable on reduced configs).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 20

Wires together every fault-tolerance layer from DESIGN.md §5:
  * deterministic sharded TokenLoader (dead-host shard reassignment),
  * StragglerMonitor (slow-step flagging, shard rebalancing),
  * CheckpointManager (async atomic saves, retention, resume),
  * preemption handling (SIGTERM → final blocking checkpoint → clean exit),
  * optional int8 error-feedback gradient compression (inter-pod analog).

On a real cluster the same driver runs under ``jax.distributed`` with the
production mesh from ``launch/mesh.py``; on CPU it uses the 1-device mesh and
reduced configs so the whole loop (including restart) is testable.
"""
from __future__ import annotations

import argparse
import signal
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint, restore_onto_mesh
from repro.configs import ARCHS, get_config, reduced_config
from repro.data.loader import TokenLoader
from repro.distributed import StepTimer, StragglerMonitor, ef_init, compressed_gradient_update
from repro.models import build_model
from repro.train.step import init_opt_state, make_train_step


def train_loop(
    arch: str = "qwen2-0.5b",
    reduced: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    lr: float = 1e-3,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    resume: bool = False,
    compress: bool = False,
    kill_host: int | None = None,
    kill_at_step: int = -1,
    seed: int = 0,
    log_every: int = 10,
    print_fn=print,
) -> dict:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    opt_state = init_opt_state(model, params)
    ef_state = ef_init(params) if compress else None

    monitor = StragglerMonitor(n_hosts=4)
    loader = TokenLoader(
        global_batch=batch, seq_len=seq, vocab=cfg.vocab_size,
        seed=seed, n_shards=4, monitor=monitor,
    )
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None

    start_step = 0
    if resume and mgr is not None and mgr.latest_step() is not None:
        s, tree, meta = load_checkpoint(ckpt_dir)
        shardings = jax.tree.map(lambda x: None, tree)
        state = restore_onto_mesh(tree, shardings)
        params, opt_state = state["params"], state["opt"]
        # leaf dtypes ride through restore_onto_mesh's bf16 re-view
        start_step = s + 1
        print_fn(f"resumed from step {s}")

    raw_step = make_train_step(model, lr=lr)

    if compress:
        def step_fn(params, opt_state, batch, ef):
            # quantize/EF-roundtrip the grads the way the inter-pod hop would
            from repro.train.optimizer import adamw_update, adafactor_update
            loss, grads = jax.value_and_grad(
                lambda p, b: model.loss(p, b)
            )(params, batch)
            grads, ef = compressed_gradient_update(grads, ef)
            upd = adamw_update if cfg.optimizer == "adamw" else adafactor_update
            new_p, new_o = upd(grads, opt_state, params, lr=lr)
            return new_p, new_o, {"loss": loss}, ef

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jit_step = jax.jit(raw_step, donate_argnums=(0, 1))

    # preemption: SIGTERM triggers one final blocking checkpoint
    preempted = {"flag": False}

    def _on_term(sig, frame):
        preempted["flag"] = True

    old = signal.signal(signal.SIGTERM, _on_term)

    losses = []
    try:
        for step in range(start_step, steps):
            if kill_host is not None and step == kill_at_step:
                monitor.mark_dead(kill_host)  # simulate a host failure
                print_fn(f"host {kill_host} marked dead at step {step}; "
                         f"shards reassigned")
            # every host materializes its assigned shards; on this 1-host run
            # we assemble the full global batch (shard math identical)
            all_shards = [
                s for h, ss in monitor.plan_shards(loader.n_shards).items()
                for s in ss
            ]
            np_batch = loader.batch(step, sorted(all_shards))
            dev_batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
            with StepTimer(monitor) as t:
                if compress:
                    params, opt_state, metrics, ef_state = jit_step(
                        params, opt_state, dev_batch, ef_state
                    )
                else:
                    params, opt_state, metrics = jit_step(
                        params, opt_state, dev_batch
                    )
                loss = float(metrics["loss"])
            losses.append(loss)
            if t.was_straggler:
                print_fn(f"step {step}: straggler step ({t.last:.2f}s)")
            if step % log_every == 0:
                print_fn(f"step {step}: loss={loss:.4f} ({t.last:.2f}s)")
            if mgr is not None and ckpt_every and (step + 1) % ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state})
            if preempted["flag"]:
                print_fn(f"preempted at step {step}: draining checkpoint")
                if mgr is not None:
                    mgr.save(step, {"params": params, "opt": opt_state},
                             blocking=True)
                break
    finally:
        if mgr is not None:
            mgr.flush()
        signal.signal(signal.SIGTERM, old)

    return {"losses": losses, "params": params, "final_step": step}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train_loop(**{k.replace("-", "_"): v for k, v in vars(args).items()})
    first, last = out["losses"][0], out["losses"][-1]
    print(f"done: loss {first:.4f} -> {last:.4f}")
    sys.exit(0 if np.isfinite(last) else 1)


if __name__ == "__main__":
    main()
