"""Input/activation sharding assignment for the dry-run and launchers.

Batch dims shard over the (pod×)data axes; KV/attention head dims and
expert/state dims shard over `model`, guarded by divisibility (dims smaller
than the axis stay replicated rather than degenerately padded — e.g. the
B=1 long_500k cells)."""
from __future__ import annotations

import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.base import fsdp_axes


def _axsize(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return int(mesh.shape[ax])


def _maybe(mesh, ax, dim: int):
    """Use axis only if the dim divides evenly (else replicate)."""
    return ax if dim % max(_axsize(mesh, ax), 1) == 0 and dim >= _axsize(mesh, ax) else None


def input_spec_for(name: str, shape: tuple, mesh) -> P:
    ax = fsdp_axes(mesh)
    d, m = ax.data, ax.model
    nd = len(shape)
    if name in ("tokens", "labels", "lengths"):
        return P(_maybe(mesh, d, shape[0]), *([None] * (nd - 1)))
    if name in ("frames", "patches"):
        return P(_maybe(mesh, d, shape[0]), None, None)
    if name in ("k_cache", "v_cache", "xk_cache", "xv_cache"):
        # (L, B, S, KH, hd): prefer head sharding; fall back to sequence
        # sharding over `model` when KH doesn't divide (ring-style)
        kh_ax = _maybe(mesh, m, shape[3])
        s_ax = _maybe(mesh, m, shape[2]) if kh_ax is None else None
        return P(None, _maybe(mesh, d, shape[1]), s_ax, kh_ax, None)
    if name == "ssm_h":  # (L, B, H, N, P)
        return P(None, _maybe(mesh, d, shape[1]), _maybe(mesh, m, shape[2]), None, None)
    if name == "conv_buf":  # (L, B, K-1, Ck)
        return P(None, _maybe(mesh, d, shape[1]), None, _maybe(mesh, m, shape[3]))
    if name in ("mh", "mn"):  # (nm, B*H, 1, P, ...)
        return P(None, _maybe(mesh, d, shape[1]), None, _maybe(mesh, m, shape[3]), None)
    if name in ("sc", "sn", "sm"):  # (ns, B, D)
        return P(None, _maybe(mesh, d, shape[1]), _maybe(mesh, m, shape[2]))
    if name == "sy":  # (ns, B, H, P)
        return P(None, _maybe(mesh, d, shape[1]), None, _maybe(mesh, m, shape[3]))
    return P(*([None] * nd))


def batch_shardings(specs: dict, mesh) -> dict:
    return {
        k: NamedSharding(mesh, input_spec_for(k, v.shape, mesh))
        for k, v in specs.items()
    }
