import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: jax locks device count on first use.
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and record memory / cost / collective-traffic analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--out benchmarks/results]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each --all cell runs in a fresh subprocess (compiler state isolation). The
JSON records feed EXPERIMENTS.md §Dry-run and the §Roofline analysis.
"""
import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import batch_shardings, input_spec_for
from repro.models import build_model
from repro.models.base import (
    SHAPES,
    active_param_count,
    param_count,
    shardings_for,
    struct,
)
from repro.models.zoo import decode_caches_from_specs
from repro.train.step import init_opt_state, make_prefill_step, make_serve_step, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(\(?[a-z0-9]+\[[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo):
        types, kind = m.group(1), m.group(2)
        total = 0
        for dt, dims in _SHAPE_RE.findall(types):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + total
    return out


def _to_struct(shapes, dtype):
    return jax.tree.map(
        lambda s: struct(s, dtype), shapes, is_leaf=lambda x: isinstance(x, tuple)
    )


def _parse_override(kv: str):
    k, _, v = kv.partition("=")
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    return k, v


def lower_cell(
    arch: str, shape_name: str, multi_pod: bool, overrides: dict | None = None
) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sp = SHAPES[shape_name]
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": sp.kind,
    }
    ok, why = cfg.supports_shape(shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params_s = _to_struct(model.shapes, dt)
    ps = shardings_for(params_s, mesh)
    batch_s = model.input_specs(sp)
    bs = batch_shardings(batch_s, mesh)

    t0 = time.time()
    with mesh:
        if sp.kind == "train":
            opt_s = init_opt_state(model, params_s, materialize=False)
            opt_sh = shardings_for(opt_s, mesh)
            step = make_train_step(model, mesh=mesh, accum_steps=cfg.accum_steps)
            lowered = jax.jit(
                step, in_shardings=(ps, opt_sh, bs),
                out_shardings=(ps, opt_sh, None),
                donate_argnums=(0, 1),  # params/opt alias in-place
            ).lower(params_s, opt_s, batch_s)
        elif sp.kind == "prefill":
            step = make_prefill_step(model, mesh=mesh)
            lowered = jax.jit(step, in_shardings=(ps, bs)).lower(params_s, batch_s)
        else:  # decode
            caches_s = decode_caches_from_specs(model, sp)
            cache_names = [
                k for k in batch_s if k not in ("tokens", "lengths")
            ]
            cache_sh = tuple(
                jax.sharding.NamedSharding(
                    mesh, input_spec_for(n, batch_s[n].shape, mesh)
                )
                for n in cache_names
            )
            small = {
                "tokens": batch_s["tokens"],
                "lengths": batch_s["lengths"],
            }
            small_sh = {k: bs[k] for k in small}
            step = make_serve_step(model, mesh=mesh)
            lowered = jax.jit(
                step,
                in_shardings=(ps, small_sh, cache_sh),
                out_shardings=(None, None, cache_sh),
                donate_argnums=(2,),  # caches update in-place
            ).lower(params_s, small, caches_s)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    # executed-cost analysis: while bodies × known_trip_count (per-device).
    # cost_analysis() counts loop bodies once — see hlo_analysis docstring.
    from repro.launch.hlo_analysis import analyze_hlo

    exec_cost = analyze_hlo(hlo_text)
    n_tokens = sp.global_batch * (sp.seq_len if sp.kind != "decode" else 1)
    n_params = param_count(cfg)
    n_active = active_param_count(cfg)
    mult = {"train": 6, "prefill": 2, "decode": 2}[sp.kind]
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        arg_bytes=int(ma.argument_size_in_bytes),
        alias_bytes=int(ma.alias_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        code_bytes=int(ma.generated_code_size_in_bytes),
        hlo_flops=float(ca.get("flops", 0.0)),
        hlo_bytes=float(ca.get("bytes accessed", 0.0)),
        exec_flops=float(exec_cost.flops),
        exec_bytes=float(exec_cost.bytes),
        exec_collective_bytes={
            k: float(v) for k, v in exec_cost.collective_bytes.items()
        },
        unknown_trip_loops=int(exec_cost.unknown_trip_loops),
        collective_bytes=coll,
        model_flops=float(mult * n_active * n_tokens),
        n_params=n_params,
        n_active_params=n_active,
        n_tokens=n_tokens,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument(
        "--set", action="append", default=[], metavar="KEY=VALUE",
        help="ArchConfig overrides (perf iterations)",
    )
    ap.add_argument("--tag", default=None, help="suffix for the record file")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        failures = 0
        mesh_tag = "mp" if args.multi_pod else "sp"
        for arch in ARCHS:
            for shape in SHAPES:
                path = os.path.join(
                    args.out, f"dryrun_{mesh_tag}_{arch}_{shape}.json"
                )
                if args.skip_existing and os.path.exists(path):
                    try:
                        with open(path) as f:
                            if json.load(f).get("status") in ("ok", "skipped"):
                                print(f"[{mesh_tag}] {arch} × {shape}: cached")
                                continue
                    except Exception:
                        pass
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--out", args.out,
                ]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd)
                failures += int(r.returncode != 0)
        print(f"dry-run sweep done; {failures} failures")
        sys.exit(1 if failures else 0)

    overrides = dict(map(_parse_override, args.set))
    rec = lower_cell(args.arch, args.shape, args.multi_pod, overrides or None)
    if overrides:
        rec["overrides"] = overrides
    mesh_tag = "mp" if args.multi_pod else "sp"
    suffix = f"_{args.tag}" if args.tag else ""
    path = os.path.join(
        args.out, f"dryrun_{mesh_tag}_{args.arch}_{args.shape}{suffix}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = (
        f"temp={rec['temp_bytes']/1e9:.2f}GB flops={rec['hlo_flops']:.3e} "
        f"compile={rec['compile_s']}s"
        if status == "ok"
        else rec.get("reason", "")
    )
    print(f"[{rec['mesh']}] {args.arch} × {args.shape}: {status} {extra}")


if __name__ == "__main__":
    main()
