from repro.distributed.compression import (
    ErrorFeedbackState,
    compressed_gradient_update,
    ef_init,
    ef_int8_compress,
    ef_int8_decompress,
)
from repro.distributed.straggler import StepTimer, StragglerMonitor
from repro.distributed.collectives import hierarchical_psum

__all__ = [
    "ef_init",
    "ef_int8_compress",
    "ef_int8_decompress",
    "ErrorFeedbackState",
    "compressed_gradient_update",
    "StepTimer",
    "StragglerMonitor",
    "hierarchical_psum",
]
