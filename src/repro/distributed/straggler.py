"""Straggler detection + data-shard rebalancing (fault-tolerance layer).

At multi-thousand-chip scale the step time is gated by the slowest
participant. The monitor keeps a robust running estimate (median/MAD over a
sliding window) of per-step wall time and of per-host data-loading time, and
flags (a) globally slow steps, (b) persistently slow hosts. The loader
consumes ``plan_shards()`` which re-weights shard assignment away from slow
hosts (work-stealing style) and reassigns the shards of dead hosts.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class StragglerMonitor:
    n_hosts: int = 1
    window: int = 32
    z_threshold: float = 4.0
    persist_steps: int = 8

    _steps: deque = field(default_factory=lambda: deque(maxlen=256))
    _host_times: dict = field(default_factory=dict)  # host -> deque
    _slow_streak: dict = field(default_factory=dict)
    dead_hosts: set = field(default_factory=set)

    # -- recording --------------------------------------------------------

    def record_step(self, seconds: float) -> bool:
        """Record a global step time; returns True if it's a straggler step."""
        hist = list(self._steps)
        self._steps.append(seconds)
        if len(hist) < 8:
            return False
        med = _median(hist)
        mad = _median([abs(x - med) for x in hist]) or 1e-9
        return (seconds - med) / (1.4826 * mad) > self.z_threshold

    def record_host(self, host: int, seconds: float) -> None:
        dq = self._host_times.setdefault(host, deque(maxlen=self.window))
        dq.append(seconds)

    def mark_dead(self, host: int) -> None:
        self.dead_hosts.add(host)

    def mark_alive(self, host: int) -> None:
        self.dead_hosts.discard(host)
        self._slow_streak.pop(host, None)

    # -- analysis ---------------------------------------------------------

    def slow_hosts(self) -> list[int]:
        """Hosts whose median load time is persistently above the fleet."""
        meds = {
            h: _median(list(dq))
            for h, dq in self._host_times.items()
            if len(dq) >= 4 and h not in self.dead_hosts
        }
        if len(meds) < 2:
            return []
        fleet = _median(list(meds.values()))
        out = []
        for h, m in meds.items():
            if m > 1.5 * fleet:
                self._slow_streak[h] = self._slow_streak.get(h, 0) + 1
            else:
                self._slow_streak[h] = 0
            if self._slow_streak.get(h, 0) >= self.persist_steps:
                out.append(h)
        return out

    # -- shard planning ----------------------------------------------------

    def plan_shards(self, n_shards: int) -> dict[int, list[int]]:
        """Deterministic shard→host assignment skipping dead hosts and
        down-weighting slow ones (they get ⌈half⌉ share)."""
        alive = [h for h in range(self.n_hosts) if h not in self.dead_hosts]
        if not alive:
            raise RuntimeError("no alive hosts")
        slow = set(self.slow_hosts())
        weights = [0.5 if h in slow else 1.0 for h in alive]
        total = sum(weights)
        # largest-remainder apportionment, deterministic
        quota = [n_shards * w / total for w in weights]
        counts = [int(q) for q in quota]
        rem = n_shards - sum(counts)
        order = sorted(
            range(len(alive)), key=lambda i: quota[i] - counts[i], reverse=True
        )
        for i in order[:rem]:
            counts[i] += 1
        plan: dict[int, list[int]] = {h: [] for h in alive}
        s = 0
        for h, c in zip(alive, counts):
            plan[h] = list(range(s, s + c))
            s += c
        return plan


def _median(xs: list[float]) -> float:
    ys = sorted(xs)
    n = len(ys)
    if n == 0:
        return 0.0
    return ys[n // 2] if n % 2 else 0.5 * (ys[n // 2 - 1] + ys[n // 2])


class StepTimer:
    """Context-manager sugar for the train loop."""

    def __init__(self, monitor: StragglerMonitor):
        self.monitor = monitor
        self.last: Optional[float] = None
        self.was_straggler = False

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.last = time.perf_counter() - self._t0
        self.was_straggler = self.monitor.record_step(self.last)
        return False
