"""Gradient compression for DCN-bound inter-pod all-reduce.

int8 error-feedback quantization: each leaf is quantized per-row (last-axis
blocks) to int8 with an f32 scale; the quantization error is carried in a
residual accumulator and added back before the next step's quantization, so
the *cumulative* transmitted gradient is unbiased (EF-SGD / 1-bit-Adam
family). At 512+ chips the inter-pod gradient all-reduce is the DCN
bottleneck; int8 cuts transmitted bytes 4× vs f32 (2× vs bf16).

All functions are pure/jittable; the train loop owns the residual state.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching grads (f32)


def ef_init(grads_or_params: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_or_params
        )
    )


def _amax(g: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(g.astype(jnp.float32)), axis=-1, keepdims=True)


def _scale_of(amax: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(amax > 0, amax / 127.0, 1.0)


def _quant_leaf(
    g: jnp.ndarray, scale: jnp.ndarray | None = None
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization over the last axis."""
    gf = g.astype(jnp.float32)
    if scale is None:
        scale = _scale_of(_amax(gf))
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_int8_compress(
    grads: Any, state: ErrorFeedbackState, scales: Any = None
) -> tuple[Any, Any, ErrorFeedbackState]:
    """Returns (q_tree, scale_tree, new_state). Residual carries the error.
    ``scales`` overrides the per-row scales (the all-reduce path needs a
    globally agreed scale)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, state.residual
    )
    if scales is None:
        scales = jax.tree.map(lambda c: _scale_of(_amax(c)), corrected)
    q = jax.tree.map(lambda c, s: _quant_leaf(c, s)[0], corrected, scales)
    new_res = jax.tree.map(
        lambda c, qq, ss: c - _dequant_leaf(qq, ss), corrected, q, scales
    )
    return q, scales, ErrorFeedbackState(residual=new_res)


def ef_int8_decompress(q: Any, scale: Any) -> Any:
    return jax.tree.map(_dequant_leaf, q, scale)


def compressed_gradient_update(grads, state, *, axis_name: str | None = None):
    """Quantize → (optionally psum over ``axis_name``) → dequantize.

    Inside shard_map, pass the inter-pod axis name: participants first agree
    on a per-row scale (pmax over the axis — an O(rows) collective, negligible
    next to the payload), then int8 payloads cross the DCN boundary and the
    f32 mean is reconstructed locally. Outside shard_map (axis_name=None) it
    is a pure quantize/dequantize round with EF."""
    if axis_name is not None:
        corrected = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, state.residual
        )
        scales = jax.tree.map(
            lambda c: _scale_of(jax.lax.pmax(_amax(c), axis_name)), corrected
        )
        q, s, new_state = ef_int8_compress(grads, state, scales)
        # sum int32 payloads (int8 would overflow at >127 pods), average after
        n = jax.lax.psum(1, axis_name)
        q = jax.tree.map(
            lambda x: jax.lax.psum(x.astype(jnp.int32), axis_name), q
        )
        deq = jax.tree.map(
            lambda qq, ss: qq.astype(jnp.float32) * ss / n, q, s
        )
    else:
        q, s, new_state = ef_int8_compress(grads, state)
        deq = ef_int8_decompress(q, s)
    return deq, new_state
