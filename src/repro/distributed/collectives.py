"""Collective helpers for the multi-pod mesh.

``hierarchical_psum`` implements the two-level gradient reduction from
DESIGN.md §5: reduce-scatter + all-gather *inside* a pod over ICI, with the
inter-pod (DCN) hop carrying only each chip's 1/N_intra shard — the standard
bandwidth-optimal hierarchy. Inside shard_map it lowers to exactly
reduce-scatter(data) → all-reduce(pod) → all-gather(data); outside a
shard_map it degrades to a plain tree-sum (tests, single-device)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hierarchical_psum(x, intra_axis: str = "data", inter_axis: str = "pod"):
    """psum over (intra, inter) with the DCN hop at 1/|intra| volume."""
    try:
        jax.lax.axis_index(intra_axis)  # raises NameError outside shard_map
    except NameError:
        return x

    def one(leaf):
        n = jax.lax.psum(1, intra_axis)
        flat = leaf.reshape(-1)
        pad = (-flat.shape[0]) % n
        flat = jnp.pad(flat, (0, pad))
        # reduce-scatter over ICI: each chip owns a 1/n shard of the sum
        shard = jax.lax.psum_scatter(
            flat.reshape(n, -1), intra_axis, scatter_dimension=0, tiled=False
        )
        # inter-pod all-reduce over DCN on the shard only
        try:
            jax.lax.axis_index(inter_axis)
            shard = jax.lax.psum(shard, inter_axis)
        except NameError:
            pass
        # all-gather back over ICI
        full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=False)
        return full.reshape(-1)[: leaf.size].reshape(leaf.shape)

    return jax.tree.map(one, x)
