"""Raven-style end-to-end optimization of ML prediction queries.

The front door (conventionally imported as ``raven``)::

    import repro as raven

    db = raven.connect(tables, stats="auto")
    db.models.publish("risk", pipe)
    prep = db.sql(
        "SELECT * FROM PREDICT(model='risk', data=patients) WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.6})
    print(prep.explain())
    out = prep(batch)            # one-shot
    prep.serve()                 # bucketed, cached serving
    req = prep.submit(batch)
    db.flush()

Lower layers (``repro.core``, ``repro.sql``, ``repro.relational``,
``repro.serve``) remain importable directly for rule-level work.
"""
from repro.errors import (
    FaultInjectedError,
    RavenError,
    RecoveryError,
    RegistryStateError,
    RequestFailedError,
    RequestTimeoutError,
    ServerOverloadedError,
    SQLSyntaxError,
    StaleQueryError,
    TransientError,
    TransientFaultError,
    UnboundParameterError,
    UnknownColumnError,
    UnknownModelError,
    UnknownModelVersionError,
    UnknownParameterError,
    UnknownQueryError,
    UnknownTableError,
)
from repro.options import ConnectOptions, ServeOptions
from repro.session import (
    PreparedQuery,
    Query,
    QueryBuilder,
    Session,
    connect,
)

# after repro.session: the session import initializes the relational layer
# before repro.serve's / repro.exec's package imports touch the stage IR
# (import cycle)
from repro.exec.faults import FaultPlan, RetryPolicy, RollbackPolicy
from repro.serve.registry import ModelRegistry, ModelVersion

__all__ = [
    "connect",
    "Session",
    "Query",
    "QueryBuilder",
    "PreparedQuery",
    "RavenError",
    "SQLSyntaxError",
    "UnknownModelError",
    "UnknownTableError",
    "UnknownColumnError",
    "UnboundParameterError",
    "UnknownParameterError",
    "UnknownQueryError",
    "StaleQueryError",
    "ServerOverloadedError",
    "UnknownModelVersionError",
    "RegistryStateError",
    "ConnectOptions",
    "ServeOptions",
    "ModelRegistry",
    "ModelVersion",
    "FaultPlan",
    "RetryPolicy",
    "RollbackPolicy",
    "FaultInjectedError",
    "TransientError",
    "TransientFaultError",
    "RequestTimeoutError",
    "RequestFailedError",
    "RecoveryError",
]
