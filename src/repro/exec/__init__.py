"""Physical execution layer: the StageGraph IR, the pipelined executor, the
request scheduler, and the persistent artifact store.

``repro.exec.stages`` is the typed intermediate representation between the
optimizer's physical plan and the runtime: a linear graph of declarative,
content-fingerprinted stages (maximal pure-jnp segments and MLUdf host
boundaries). ``repro.exec.pipeline`` executes that graph with host/device
overlap across request groups; ``repro.exec.scheduler`` is the fair,
backpressured multi-queue pump that feeds it (it also keeps the original
single-deadline ``RequestPump`` for simple embedders).
``repro.exec.artifact_store`` persists optimizer output and AOT-exported
stage executables across processes, keyed on the stage IR's chained content
fingerprints.
"""
from repro.exec.artifact_store import ArtifactStore, StoreStats, env_digest
from repro.exec.pipeline import PipelineExecutor
from repro.exec.scheduler import QueryQueue, RequestPump, Scheduler
from repro.exec.stages import (
    RunResult,
    Stage,
    StageGraph,
    build_stage_graph,
    describe_segments,
    donation_enabled,
    plan_segments,
    run_graph,
    seg_bucket,
)

__all__ = [
    "ArtifactStore",
    "PipelineExecutor",
    "QueryQueue",
    "RequestPump",
    "RunResult",
    "Scheduler",
    "StoreStats",
    "env_digest",
    "Stage",
    "StageGraph",
    "build_stage_graph",
    "describe_segments",
    "donation_enabled",
    "plan_segments",
    "run_graph",
    "seg_bucket",
]
