"""Physical execution layer: the StageGraph IR, the request pump, and the
persistent artifact store.

``repro.exec.stages`` is the typed intermediate representation between the
optimizer's physical plan and the runtime: a linear graph of declarative,
content-fingerprinted stages (maximal pure-jnp segments and MLUdf host
boundaries). ``repro.exec.pump`` drives latency-targeted background flushing
for the serving layer. ``repro.exec.artifact_store`` persists optimizer
output and AOT-exported stage executables across processes, keyed on the
stage IR's chained content fingerprints.
"""
from repro.exec.artifact_store import ArtifactStore, StoreStats, env_digest
from repro.exec.pump import RequestPump
from repro.exec.stages import (
    RunResult,
    Stage,
    StageGraph,
    build_stage_graph,
    describe_segments,
    plan_segments,
    run_graph,
    seg_bucket,
)

__all__ = [
    "ArtifactStore",
    "RequestPump",
    "RunResult",
    "StoreStats",
    "env_digest",
    "Stage",
    "StageGraph",
    "build_stage_graph",
    "describe_segments",
    "plan_segments",
    "run_graph",
    "seg_bucket",
]
