"""Physical execution layer: the StageGraph IR and the async request pump.

``repro.exec.stages`` is the typed intermediate representation between the
optimizer's physical plan and the runtime: a linear graph of declarative,
content-fingerprinted stages (maximal pure-jnp segments and MLUdf host
boundaries). ``repro.exec.pump`` drives latency-targeted background flushing
for the serving layer.
"""
from repro.exec.pump import RequestPump
from repro.exec.stages import (
    RunResult,
    Stage,
    StageGraph,
    build_stage_graph,
    describe_segments,
    plan_segments,
    run_graph,
    seg_bucket,
)

__all__ = [
    "RequestPump",
    "RunResult",
    "Stage",
    "StageGraph",
    "build_stage_graph",
    "describe_segments",
    "plan_segments",
    "run_graph",
    "seg_bucket",
]
