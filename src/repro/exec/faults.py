"""Deterministic fault injection + the fault-tolerance policy types.

Every recovery path in the serving stack — group retry, circuit-breaker
degradation, automated rollback, crash recovery — needs a *reproducible*
trigger, or its tests devolve into sleeps and luck. This module provides
one: a seeded :class:`FaultPlan` installable process-wide (via
``connect(options=ConnectOptions(faults=...))`` or the ``RAVEN_FAULTS``
env var) whose specs fire at named sites instrumented throughout the
stack:

==============  ============================================================
site            instrumented where
==============  ============================================================
``dispatch``    ``PredictionQueryServer._dispatch_group`` — the whole group
                dispatch raises before any stage runs
``stage``       ``_StageRunner`` — a pure (jitted) stage raises at call time
``compile``     ``_StageRunner`` — raises only when the call would trace a
                new specialization (a "compile" failure, not a run failure)
``udf``         ``host_step`` — the MLUdf host boundary raises
``store-read``  ``ArtifactStore.load_stage``/``load_plan`` — the entry is
                treated as corrupt (quarantined + counted), caller falls
                back to live compilation
``latency``     ``_StageRunner`` — injects a stall of ``delay_ms`` instead
                of an error (slow-stage spike)
``worker``      ``Scheduler`` dispatch path — the scheduler worker "dies"
                mid-dispatch; the popped group must be requeued, not lost
==============  ============================================================

Firing is a pure function of ``(seed, site, per-spec call counter)`` — no
RNG state, no wall clock — so a plan injects the *same* faults at the same
call indices on every run regardless of thread interleaving within a site.

The policy types live here too (rather than in the scheduler / registry
modules that consume them) so ``repro.options`` can reference them without
import cycles: :class:`RetryPolicy` drives group retry with exponential
backoff + deterministic jitter, and :class:`RollbackPolicy` sets the
thresholds the registry's ``RollbackGuard`` watches.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
from dataclasses import dataclass
from typing import Optional

from repro.errors import FaultInjectedError, TransientFaultError

SITES = (
    "dispatch", "stage", "compile", "udf", "store-read", "latency", "worker",
)


def _unit_hash(*parts) -> float:
    """Deterministic pseudo-uniform value in [0, 1) from the parts."""
    h = hashlib.sha1(":".join(str(p) for p in parts).encode()).hexdigest()
    return int(h[:12], 16) / float(16 ** 12)


@dataclass(frozen=True)
class FaultSpec:
    """One injection rule: fire at ``site`` on matching calls.

    ``rate`` is the per-call firing probability (decided deterministically
    from the plan seed and the call index); ``times`` caps total firings
    (None = unlimited); ``after`` skips the first N matching calls;
    ``match`` restricts firing to calls whose token (stage fingerprint,
    queue name, ...) contains the substring; ``transient`` picks the raised
    type (:class:`~repro.errors.TransientFaultError` — retryable — vs the
    terminal :class:`~repro.errors.FaultInjectedError`); ``delay_ms`` turns
    the firing into a stall instead of an error (``site="latency"``)."""

    site: str
    rate: float = 1.0
    times: Optional[int] = None
    after: int = 0
    match: str = ""
    transient: bool = True
    delay_ms: float = 0.0


class FaultPlan:
    """A seeded set of :class:`FaultSpec` rules with per-spec counters.

    Thread-safe; ``injected()`` reports how many faults actually fired per
    site, which the serving layer surfaces through ``stats_snapshot()``.
    """

    def __init__(self, specs=(), seed: int = 0):
        # normalize the convenient spellings: a {site: {key: val}} dict, a
        # list of FaultSpec / site-name strings, or a ready spec tuple
        norm: list[FaultSpec] = []
        items = specs.items() if isinstance(specs, dict) else (
            (s, None) for s in specs
        )
        for s, kw in items:
            if isinstance(s, FaultSpec):
                norm.append(s)
            elif isinstance(s, str):
                norm.append(FaultSpec(site=s, **(kw or {})))
            else:
                raise TypeError(
                    f"FaultPlan spec must be FaultSpec or site name, got "
                    f"{type(s).__name__}"
                )
        for s in norm:
            if s.site not in SITES:
                raise ValueError(
                    f"FaultPlan: unknown site {s.site!r} (sites: {SITES})"
                )
        self.specs: tuple[FaultSpec, ...] = tuple(norm)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._calls = [0] * len(self.specs)
        self._fired = [0] * len(self.specs)

    def __fingerprint_token__(self):
        return ("FaultPlan", self.seed) + tuple(
            (s.site, s.rate, s.times, s.after, s.match, s.transient,
             s.delay_ms)
            for s in self.specs
        )

    def check(self, site: str, token: str = "") -> Optional[FaultSpec]:
        """Count a call at ``site`` and return the spec to apply, if any."""
        with self._lock:
            for i, s in enumerate(self.specs):
                if s.site != site:
                    continue
                if s.match and s.match not in token:
                    continue
                k = self._calls[i]
                self._calls[i] = k + 1
                if k < s.after:
                    continue
                if s.times is not None and self._fired[i] >= s.times:
                    continue
                if s.rate < 1.0 and _unit_hash(self.seed, site, i, k) >= s.rate:
                    continue
                self._fired[i] += 1
                return s
        return None

    def injected(self) -> dict[str, int]:
        """Faults actually fired, keyed by site."""
        with self._lock:
            out: dict[str, int] = {}
            for s, n in zip(self.specs, self._fired):
                if n:
                    out[s.site] = out.get(s.site, 0) + n
            return out

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse the ``RAVEN_FAULTS`` env format.

        ``"seed=7;stage:times=2;latency:delay_ms=50,rate=0.5"`` — rules are
        ``;``-separated, each ``site:key=val,key=val``; a bare ``seed=N``
        rule sets the plan seed.
        """
        specs: list[FaultSpec] = []
        seed = 0
        for part in filter(None, (p.strip() for p in text.split(";"))):
            if part.startswith("seed="):
                seed = int(part[5:])
                continue
            site, _, rest = part.partition(":")
            site = site.strip()
            if site not in SITES:
                raise ValueError(
                    f"RAVEN_FAULTS: unknown site {site!r} (sites: {SITES})"
                )
            kw: dict = {}
            for item in filter(None, (i.strip() for i in rest.split(","))):
                key, _, val = item.partition("=")
                if key in ("rate", "delay_ms"):
                    kw[key] = float(val)
                elif key in ("times", "after"):
                    kw[key] = int(val)
                elif key == "transient":
                    kw[key] = val.lower() not in ("0", "false", "no")
                elif key == "match":
                    kw[key] = val
                else:
                    raise ValueError(f"RAVEN_FAULTS: unknown key {key!r}")
            specs.append(FaultSpec(site=site, **kw))
        return cls(specs, seed=seed)


# -- process-wide installation (mirrors engine.set_artifact_store) -----------

_FAULT_PLAN: Optional[FaultPlan] = None
_ENV_PLAN: tuple[str, Optional[FaultPlan]] = ("", None)
_INSTALL_LOCK = threading.Lock()


def set_fault_plan(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install (or clear, with None) the process-wide fault plan; returns
    the previous one."""
    global _FAULT_PLAN
    with _INSTALL_LOCK:
        prev, _FAULT_PLAN = _FAULT_PLAN, plan
    return prev


def get_fault_plan() -> Optional[FaultPlan]:
    """The installed plan, else one parsed from ``RAVEN_FAULTS`` (cached by
    env-string value), else None."""
    global _ENV_PLAN
    plan = _FAULT_PLAN
    if plan is not None:
        return plan
    text = os.environ.get("RAVEN_FAULTS", "")
    if not text:
        return None
    with _INSTALL_LOCK:
        if _ENV_PLAN[0] != text:
            _ENV_PLAN = (text, FaultPlan.parse(text))
        return _ENV_PLAN[1]


def maybe_inject(site: str, token: str = "") -> None:
    """Fault hook: no-op without a plan; with one, count the call and —
    when the matching spec fires — stall (``delay_ms``) or raise the typed
    injected error. Instrumented sites call this unconditionally; the
    no-plan path is one module-global read."""
    plan = get_fault_plan()
    if plan is None:
        return
    spec = plan.check(site, token)
    if spec is None:
        return
    if spec.delay_ms > 0:
        time.sleep(spec.delay_ms / 1e3)
        return
    if spec.transient:
        raise TransientFaultError(site, token)
    raise FaultInjectedError(site, token)


# -- retry policy ------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Group-retry policy for transient dispatch failures.

    A dispatched group that fails with a
    :class:`~repro.errors.TransientError` is requeued whole (coalescing
    preserved) up to ``max_attempts`` total dispatches, with exponential
    backoff (``backoff_ms * multiplier**(attempt-1)``) plus deterministic
    jitter (a fraction of the base delay derived from the queue name and
    attempt index — no RNG, so schedules replay identically).
    ``deadline_ms`` bounds the total time since the oldest request in the
    group was submitted: once exceeded, the group fails terminally even if
    attempts remain."""

    max_attempts: int = 3
    backoff_ms: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.25
    deadline_ms: Optional[float] = None

    def delay_s(self, attempt: int, key: str = "") -> float:
        """Backoff before dispatch attempt ``attempt`` (attempt 0 = first
        try, never delayed)."""
        if attempt <= 0:
            return 0.0
        base = self.backoff_ms * (self.multiplier ** (attempt - 1))
        frac = _unit_hash("retry-jitter", key, attempt)
        return base * (1.0 + self.jitter * frac) / 1e3


# -- rollback policy ---------------------------------------------------------


@dataclass(frozen=True)
class RollbackPolicy:
    """Thresholds the registry's ``RollbackGuard`` watches on the live
    version after a cutover. All three signals come from the per-version
    ``VersionStats`` the server already collects; a None threshold disables
    that signal. ``min_requests`` gates judgement until the live version
    has served enough traffic to make the rates meaningful."""

    max_error_rate: Optional[float] = None      # errors / dispatch groups
    max_shadow_diff_rate: Optional[float] = None  # diff rows / shadow rows
    max_p99_ratio: Optional[float] = None       # p99 vs pre-cutover baseline
    min_requests: int = 8
