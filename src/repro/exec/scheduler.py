"""Fair, backpressured multi-queue request scheduling for the serving layer.

This generalizes the single-deadline :class:`RequestPump` (kept below for
embedders that drive one flush callable): instead of one global pending list
flushed wholesale, every served query gets its own queue with its own latency
target and bounds, and one pump thread schedules *groups* across them:

  * **earliest-deadline-first** — each queue's deadline is its oldest
    request's submit time plus that queue's ``max_latency_ms``, so a small
    latency-sensitive query is flushed ahead of a bulk query that arrived
    earlier but can afford to wait;
  * **coalesce-width cap** — one dispatched group takes at most
    ``max_coalesce`` rows off a queue, so a huge backlog is served as a
    sequence of bounded groups (which the pipelined executor overlaps)
    instead of one monolithic flush that monopolizes the server;
  * **bounded queues / backpressure** — ``max_pending`` caps a queue's
    depth; a submit against a full queue blocks until the scheduler frees
    space (or its timeout expires) or fails fast with
    :class:`~repro.errors.ServerOverloadedError`;
  * **bounded dispatch** — at most ``max_inflight`` groups run concurrently,
    so the pump never buries the device/boundary pool under an unbounded
    pile of dispatched work.

The scheduler owns no execution logic: ``dispatch(name, group)`` — supplied
by the server — must return a future resolving when the group's requests
are finished. Failure routing is split by retryability: a group future that
fails with a :class:`~repro.errors.TransientError` is *requeued whole* (the
coalesced group stays one unit) under the queue's
:class:`~repro.exec.faults.RetryPolicy` — exponential backoff rides the
queue's deadline machinery, no thread ever sleeps — until attempts or the
per-query deadline run out, at which point the ``fail`` callback delivers a
typed :class:`~repro.errors.RequestFailedError` to every waiter in the
group (no orphaned waiters, ever). Non-transient failures are expected to
be marked on the affected requests by the dispatch callback itself; the
scheduler still runs ``fail`` defensively and records ``last_error``.
``drain()`` is the synchronous path: it pops and dispatches *everything*
immediately — including requeued groups, whose backoff it ignores (a flush
means "serve now") — which is exactly the old ``server.flush()`` contract,
so the scheduler works with no pump thread at all.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.analysis.runtime import asserts_enabled, runtime_assert
from repro.errors import (
    RequestFailedError,
    ServerOverloadedError,
    TransientError,
)
from repro.exec.faults import RetryPolicy, maybe_inject


@dataclass
class QueryQueue:
    """Per-query pending queue + scheduling knobs."""

    name: str
    reqs: deque = field(default_factory=deque)  # (request, n_rows)
    max_latency_ms: Optional[float] = None  # None -> scheduler default
    max_pending: Optional[int] = None       # None -> unbounded
    max_coalesce: Optional[int] = None      # rows/group; None -> sched default
    last_pop: float = 0.0  # when this queue last got service (fairness key)
    retry: Optional[RetryPolicy] = None     # None -> scheduler default
    # transiently-failed groups awaiting re-dispatch: (group, attempt,
    # not_before) — kept whole so retry never re-splits a coalesced group
    redo: deque = field(default_factory=deque)

    @property
    def depth(self) -> int:
        return len(self.reqs)


def _default_fail(group: list, e: BaseException) -> None:
    """Terminal-failure delivery for bare schedulers (no server): attach
    the error to every not-yet-settled request and wake its waiters. The
    serving layer passes its own ``_fail_group`` instead."""
    for r in group:
        if getattr(r, "done", False):
            continue
        r.error = e
        ev = getattr(r, "_event", None)
        if ev is not None:
            ev.set()


class Scheduler:
    """One pump thread, many queues; EDF flush order; bounded everything."""

    def __init__(
        self,
        dispatch: Callable[[str, list], "Future"],
        *,
        default_latency_ms: float = 5.0,
        default_coalesce: Optional[int] = None,
        max_inflight: int = 4,
        default_retry: Optional[RetryPolicy] = None,
        fail: Optional[Callable[[list, BaseException], None]] = None,
    ):
        self._dispatch = dispatch
        self._fail = fail if fail is not None else _default_fail
        self.default_latency_ms = float(default_latency_ms)
        self.default_coalesce = default_coalesce
        self.max_inflight = max(1, int(max_inflight))
        # retry applies only to TransientError failures, so it is on by
        # default: deterministic failures never enter the retry path
        self.default_retry = (
            default_retry if default_retry is not None else RetryPolicy()
        )
        self._cv = threading.Condition()
        self._queues: dict[str, QueryQueue] = {}
        self._thread: Optional[threading.Thread] = None
        self._stopped = False
        self._inflight = 0
        # pump-group generations: drain() waits only for groups the pump
        # had popped *before* it was called (bounded under sustained load)
        self._pump_started = 0
        self._pump_settled = 0
        # counters (reads are advisory; mutations under _cv)
        self.flushes = 0  # pump-initiated group dispatches
        self.backpressure_waits = 0
        self.overloads = 0
        self.max_queue_depth = 0
        self.retries = 0            # groups requeued after a transient failure
        self.retries_exhausted = 0  # groups failed terminally after retries
        self.last_error: Optional[BaseException] = None

    # -- queue management -----------------------------------------------------

    def configure(
        self,
        name: str,
        *,
        max_latency_ms: Optional[float] = None,
        max_pending: Optional[int] = None,
        max_coalesce: Optional[int] = None,
        retry: Optional[RetryPolicy] = None,
    ) -> QueryQueue:
        """Create (or retune) the queue for ``name``; None leaves a knob."""
        with self._cv:
            q = self._queues.get(name)
            if q is None:
                q = self._queues[name] = QueryQueue(name=name)
            if max_latency_ms is not None:
                q.max_latency_ms = float(max_latency_ms)
            if max_pending is not None:
                q.max_pending = int(max_pending)
            if max_coalesce is not None:
                q.max_coalesce = int(max_coalesce)
            if retry is not None:
                q.retry = retry
            return q

    def depths(self) -> dict[str, int]:
        with self._cv:
            return {n: q.depth for n, q in self._queues.items() if q.depth}

    def hold(self):
        """Context manager freezing group selection for an atomic routing
        change (version cutover). Both the pump loop and ``drain()`` pop
        groups under ``_cv`` but *dispatch outside it*, so while held no new
        group can be popped — yet already-dispatched groups keep executing
        and enqueues keep landing. The caller mutates routing inside the
        ``with`` block; every group popped afterwards sees the new route.
        """
        return self._cv

    def snapshot(self) -> dict[str, Any]:
        with self._cv:
            return {
                "pump_flushes": self.flushes,
                "groups_inflight": self._inflight,
                "backpressure_waits": self.backpressure_waits,
                "overloads": self.overloads,
                "max_queue_depth": self.max_queue_depth,
                "retries": self.retries,
                "retries_exhausted": self.retries_exhausted,
                "redo_depth": sum(
                    len(q.redo) for q in self._queues.values()
                ),
            }

    # -- producer side --------------------------------------------------------

    def enqueue(
        self,
        name: str,
        req,
        n_rows: int,
        *,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> None:
        """Queue one request; applies the queue's ``max_pending`` bound."""
        with self._cv:
            q = self._queues.get(name)
            if q is None:
                q = self._queues[name] = QueryQueue(name=name)
            if q.max_pending is not None and q.depth >= q.max_pending:
                if not block:
                    self.overloads += 1
                    raise ServerOverloadedError(self._overload_msg(q))
                if timeout is None and not self.running:
                    # nothing will ever free space: the synchronous protocol
                    # drains via flush(), which this blocked caller can
                    # never reach — fail fast instead of hanging forever
                    self.overloads += 1
                    raise ServerOverloadedError(
                        self._overload_msg(q) + " (no pump thread is "
                        "running: call flush(), or submit with a timeout)"
                    )
                self.backpressure_waits += 1
                end = None if timeout is None else time.monotonic() + timeout
                while q.depth >= q.max_pending:
                    if timeout is None and not self.running:
                        # the pump died (stop() racing this wait): nothing
                        # will free space anymore — reject, don't strand
                        self.overloads += 1
                        raise ServerOverloadedError(self._overload_msg(q))
                    left = None if end is None else end - time.monotonic()
                    if left is not None and left <= 0:
                        self.overloads += 1
                        raise ServerOverloadedError(self._overload_msg(q))
                    self._cv.wait(left if left is not None else 1.0)
            q.reqs.append((req, int(n_rows)))
            self.max_queue_depth = max(self.max_queue_depth, q.depth)
            self._cv.notify_all()

    def _overload_msg(self, q: QueryQueue) -> str:
        return (
            f"query '{q.name}' is overloaded: {q.depth} pending requests "
            f"at max_pending={q.max_pending} — shed load, raise the bound, "
            f"or wait for the scheduler to catch up"
        )

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "Scheduler":
        with self._cv:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stopped = False
            self._thread = threading.Thread(
                target=self._loop, name="raven-scheduler", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Stop the pump thread, then drain anything still pending."""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout)
        self.drain()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- scheduling -----------------------------------------------------------

    def _deadline(self, q: QueryQueue) -> float:
        """When ``q`` next wants service: its oldest fresh request's latency
        deadline, or a requeued group's backoff expiry — whichever is
        sooner. Backoff is therefore just a deadline in the future: the
        pump's existing timed wait implements it with no sleeping thread."""
        ds = []
        if q.reqs:
            target = (
                q.max_latency_ms if q.max_latency_ms is not None
                else self.default_latency_ms
            )
            ds.append(q.reqs[0][0].t_submit + target / 1e3)
        if q.redo:
            ds.append(min(nb for _g, _a, nb in q.redo))
        return min(ds)

    def _earliest(self, now: Optional[float] = None) -> Optional[QueryQueue]:
        """The nonempty queue to serve next: earliest deadline first, with a
        fairness guard — among queues *already past* their deadline, the
        least-recently-served wins. Pure EDF would let a deep bulk backlog
        (every group maximally overdue) monopolize the pump: a small query's
        later-submitted requests have later deadlines, so they would starve
        exactly when the server is busiest. Rotating overdue queues bounds a
        small query's wait to ~one group of every other queue."""
        if now is None:
            now = time.perf_counter()
        best: Optional[QueryQueue] = None
        best_key: tuple = ()
        for q in self._queues.values():
            if not q.reqs and not q.redo:
                continue
            d = self._deadline(q)
            # not yet due: sort by deadline after every overdue queue;
            # overdue: sort by last service time (then deadline)
            key = (
                (1, d, 0.0) if d > now else (0, q.last_pop, d)
            )
            if best is None or key < best_key:
                best, best_key = q, key
        return best

    def _pop_group(
        self, q: QueryQueue, due_only: bool = True
    ) -> tuple[list, int]:
        """Take the next unit of work off ``q``: a requeued group whose
        backoff has expired (served whole — retry never re-splits a
        coalesced group) ahead of fresh requests, else the head of the
        fresh queue up to its coalesce-width cap. Returns
        ``(group, attempt)``; fresh groups are attempt 0. ``due_only=False``
        (drain) ignores backoff expiry — a flush means "serve now"."""
        now = time.perf_counter()
        for i, (group, attempt, nb) in enumerate(q.redo):
            if due_only and nb > now:
                continue
            del q.redo[i]
            q.last_pop = now
            self._cv.notify_all()
            return group, attempt
        cap = (
            q.max_coalesce if q.max_coalesce is not None
            else self.default_coalesce
        )
        group = []
        rows = 0
        while q.reqs:
            req, n = q.reqs[0]
            if group and cap is not None and rows + n > cap:
                break
            q.reqs.popleft()
            group.append(req)
            rows += n
        q.last_pop = now
        self._cv.notify_all()  # wake backpressured submitters
        if asserts_enabled():
            runtime_assert(len(group) >= 1, "popped an empty group")
            rids = [id(r) for r in group]
            runtime_assert(
                len(rids) == len(set(rids)),
                f"popped group for '{q.name}' contains duplicate requests",
            )
        return group, 0

    def _loop(self) -> None:
        while True:
            with self._cv:
                q: Optional[QueryQueue] = None
                while not self._stopped:
                    q = self._earliest()
                    if q is None:
                        self._cv.wait()
                        continue
                    wait_s = self._deadline(q) - time.perf_counter()
                    if wait_s > 0:
                        # coalescing window still open: later submits ride
                        # along; an earlier deadline re-notifies the cv
                        self._cv.wait(wait_s)
                        continue
                    if self._inflight >= self.max_inflight:
                        self._cv.wait(0.05)
                        continue
                    break
                if self._stopped:
                    return
                group, attempt = self._pop_group(q)
                self._inflight += 1
                self._pump_started += 1
                self.flushes += 1
                name = q.name
            fut = self._dispatch_safe(name, group)
            fut.add_done_callback(
                lambda f, n=name, g=group, a=attempt: self._group_done(
                    f, n, g, a
                )
            )

    def _group_done(
        self, fut: "Future", name: str, group: list, attempt: int
    ) -> None:
        e = fut.exception()
        if e is not None:
            self._settle_failure(name, group, attempt, e)
        with self._cv:
            self._inflight -= 1
            self._pump_settled += 1
            if e is not None:
                self.last_error = e
            self._cv.notify_all()

    def _settle_failure(
        self, name: str, group: list, attempt: int, e: BaseException
    ) -> Optional[BaseException]:
        """Route one dispatched group's failure.

        Transient failures with retry budget left are requeued whole
        (returns None); everything else is terminal — the ``fail`` callback
        marks every request in the group so no waiter is ever orphaned, and
        the terminal error is returned for the synchronous path to raise.
        """
        attempts = attempt + 1
        if isinstance(e, TransientError):
            with self._cv:
                q = self._queues.get(name)
                policy = (
                    q.retry if q is not None and q.retry is not None
                    else self.default_retry
                )
                within_deadline = True
                if policy is not None and policy.deadline_ms is not None:
                    oldest = min(
                        (getattr(r, "t_submit", None) for r in group),
                        default=None,
                        key=lambda t: float("inf") if t is None else t,
                    )
                    if oldest is not None:
                        elapsed_ms = (time.perf_counter() - oldest) * 1e3
                        within_deadline = elapsed_ms < policy.deadline_ms
                if (
                    policy is not None
                    and q is not None
                    and attempts < policy.max_attempts
                    and within_deadline
                ):
                    nb = time.perf_counter() + policy.delay_s(attempts, name)
                    q.redo.append((group, attempts, nb))
                    self.retries += 1
                    self._cv.notify_all()
                    return None
                self.retries_exhausted += 1
            terminal: BaseException = RequestFailedError(
                f"group for '{name}' failed after {attempts} attempt(s): {e}",
                attempts=attempts,
            )
            terminal.__cause__ = e
        else:
            # deterministic failure: the dispatch callback already marked
            # the requests; fail() below is an idempotent safety net
            terminal = e
        self._fail(group, terminal)
        return terminal

    def _dispatch_safe(self, name: str, group: list) -> "Future":
        try:
            # "worker" fault site: the scheduler worker dies mid-dispatch —
            # the popped group must flow into the retry path, never be lost
            maybe_inject("worker", token=name)
            return self._dispatch(name, group)
        except BaseException as e:  # noqa: BLE001 — contain; requests carry it
            f: Future = Future()
            f.set_exception(e)
            return f

    # -- the synchronous path -------------------------------------------------

    def drain(self) -> list:
        """Snapshot and dispatch every *currently pending* request (EDF
        order), wait for completion, and return the drained requests.
        Re-raises the first *terminal* group failure after every group has
        settled — the old synchronous ``flush()`` contract. Transient
        failures are retried inline (backoff ignored — the caller is
        already blocked waiting) until they succeed or exhaust their
        policy, so a flush never returns with a request still pending.

        Bounded under sustained load: requests submitted after the snapshot
        ride the next flush, and the final wait covers only pump groups
        popped before this call — so "submit, flush, read the result" stays
        correct even when the pump raced this call to the queue, without
        flush() chasing global quiescence forever. Retry rounds are bounded
        by ``RetryPolicy.max_attempts``."""
        drained: list = []
        first: Optional[BaseException] = None
        with self._cv:
            pump_target = self._pump_started
        while True:
            todo: list[tuple[str, list, int]] = []
            with self._cv:
                while True:
                    q = self._earliest()
                    if q is None:
                        break
                    group, attempt = self._pop_group(q, due_only=False)
                    todo.append((q.name, group, attempt))
            if not todo:
                with self._cv:
                    if self._pump_settled < pump_target:
                        # pump groups popped before this call may still
                        # settle into a retry requeue we must then serve
                        self._cv.wait(1.0)
                        continue
                    if any(q.redo for q in self._queues.values()):
                        continue
                    if first is not None:
                        self.last_error = first
                break
            if asserts_enabled():
                ids = [id(r) for _name, g, _a in todo for r in g]
                runtime_assert(
                    len(ids) == len(set(ids)),
                    "drain snapshot contains duplicated requests",
                )
            dispatched = [
                (name, group, attempt, self._dispatch_safe(name, group))
                for name, group, attempt in todo
            ]
            drained.extend(
                r for _n, group, attempt, _f in dispatched
                if attempt == 0 for r in group
            )
            for name, group, attempt, fut in dispatched:
                e = fut.exception()  # blocks until the group settles
                if e is None:
                    continue
                terminal = self._settle_failure(name, group, attempt, e)
                if terminal is not None and first is None:
                    first = terminal
        if first is not None:
            raise first
        return drained


# ---------------------------------------------------------------------------
# The original single-deadline pump
# ---------------------------------------------------------------------------


class RequestPump:
    """Background thread driving one ``flush`` callable against a latency
    target — the minimal pump for embedders that don't need per-query queues.

    The :class:`Scheduler` above subsumes this for the serving layer (it is
    what :class:`~repro.serve.query_server.PredictionQueryServer` runs); the
    pump owns no queue state of its own: ``notify(t_submit)`` arms a deadline
    tracking the *oldest* pending request, the loop sleeps until it, and the
    flush callable does the actual draining. Explicit ``flush()`` calls
    remain safe at any time — flushing is idempotent on an empty queue.
    """

    def __init__(self, flush: Callable[[], list], max_latency_ms: float = 5.0):
        self._flush = flush
        self.max_latency_ms = float(max_latency_ms)
        self._cv = threading.Condition()
        self._deadline: float | None = None
        self._stopped = False
        self._thread: threading.Thread | None = None
        self.flushes = 0  # flushes this pump initiated
        self.last_error: BaseException | None = None  # most recent flush failure

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "RequestPump":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="raven-request-pump", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Stop the pump after draining anything already pending."""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._flush()  # drain stragglers deterministically

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- producer side -------------------------------------------------------

    def notify(self, t_submit: float | None = None) -> None:
        """Arm the flush deadline for a newly submitted request.

        The deadline tracks the oldest pending request: later submits never
        push it back, they just ride along in the same flush.
        """
        t = time.perf_counter() if t_submit is None else t_submit
        with self._cv:
            deadline = t + self.max_latency_ms / 1e3
            if self._deadline is None or deadline < self._deadline:
                self._deadline = deadline
            self._cv.notify_all()

    # -- the loop ------------------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._stopped and self._deadline is None:
                    self._cv.wait()
                if self._stopped:
                    return
                wait_s = self._deadline - time.perf_counter()
                if wait_s > 0:
                    self._cv.wait(wait_s)
                    continue  # re-check: stop/new earlier deadline may race
                self._deadline = None
            # count before running: waiters wake *inside* flush (their
            # request's event sets mid-drain), so counting after would let a
            # woken waiter observe flushes == 0 for the flush that served it
            self.flushes += 1
            try:
                self._flush()
            except BaseException as e:  # noqa: BLE001
                # the server already attached the error to the affected
                # requests (their wait() re-raises); the pump must survive a
                # bad batch or every later submit would hang forever
                self.last_error = e
