"""Persistent plan-artifact store: warm-start serving across processes.

Raven's premise is optimize once, serve many times — but before this module
"once" meant once *per process*: a fresh interpreter re-ran the optimizer and
re-traced/re-compiled every stage from scratch. The StageGraph's chained
per-stage content fingerprints (``repro.core.fingerprint.node_fingerprint``)
are stable across processes, so they can key durable artifacts. This module
is that disk tier, with two layers:

  * **plan layer** — the optimizer's output ``(PhysicalPlan,
    OptimizationReport)`` pickled per *query* fingerprint (IR plan + stats +
    optimizer configuration), so ``Query.prepare()`` in a fresh session skips
    re-optimization when nothing it depends on changed. Plans whose content
    is not cross-process stable (e.g. MLtoDNN ``TensorOp`` closures, which
    pickle refuses anyway) are skipped — the stage layer still covers them
    because ``TensorOp`` fns carry canonical ``__fingerprint_token__`` s.
  * **stage layer** — each pure stage's jitted executable AOT-exported via
    ``jax.export`` per (stage fingerprint, env shape/dtype digest):
    serialized on first compile, deserialized-and-called on later processes.
    A deserialized artifact replays StableHLO without ever running the
    Python stage function, so warm buckets cost **zero new XLA traces**.

Every entry is one directory written with the same atomic discipline as
``checkpoint/store.py`` (tmp dir + ``os.rename``; ``meta.json`` written
last marks the entry complete), so concurrent writers never clobber each
other and a crash mid-write never corrupts the store. Loads verify a
compatibility header (store version, jax version, backend) and fall back to
live compilation on any mismatch, truncation, or corruption — a bad cache
can cost time, never correctness. ``max_entries`` bounds the directory via
oldest-first eviction.
"""
from __future__ import annotations

import atexit
import hashlib
import json
import os
import pickle
import queue
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

STORE_VERSION = 1

_PLANS = "plans"
_STAGES = "stages"
_REGISTRY = "registry"
_META = "meta.json"
_PLAN_BLOB = "plan.pkl"
_STAGE_BLOB = "exported.bin"


def abstract_env(env: dict[str, Any]) -> dict[str, Any]:
    """Reduce an execution environment to its shape/dtype structure
    (``jax.ShapeDtypeStruct`` leaves; already-abstract leaves pass through).

    The single definition of the shapes-only snapshot used both by the
    engine (which must take it *before* a donating call invalidates the
    volatile buffers) and by the store's background writer (which must not
    pin device arrays in its queue).
    """
    return jax.tree_util.tree_map(
        lambda x: x if isinstance(x, jax.ShapeDtypeStruct)
        else jax.ShapeDtypeStruct(jax.numpy.shape(x),
                                  jax.numpy.result_type(x)),
        env,
    )


def env_digest(env: dict[str, Any]) -> str:
    """Canonical digest of an execution environment's *structure*.

    Hashes the pytree definition (table/column names, special keys) plus
    every leaf's shape and dtype — exactly the signature ``jax.jit``
    specializes on — so one digest names one compiled program variant.
    Values are deliberately excluded: the same bucket shape must map onto
    the same exported executable whatever rows arrive in it.
    """
    leaves, treedef = jax.tree_util.tree_flatten(env)
    h = hashlib.sha256(str(treedef).encode())
    for leaf in leaves:
        h.update(f"{jax.numpy.result_type(leaf)}{jax.numpy.shape(leaf)};".encode())
    return h.hexdigest()[:32]


def compat_header() -> dict[str, Any]:
    """The environment an artifact is only valid in."""
    return {
        "store_version": STORE_VERSION,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
    }


@dataclass
class StoreStats:
    """Disk-tier accounting (surfaced via ``db.cache_stats()``)."""

    plan_hits: int = 0
    plan_misses: int = 0
    plan_saves: int = 0
    stage_hits: int = 0
    stage_misses: int = 0
    stage_saves: int = 0
    incompatible: int = 0  # version/backend header rejected an entry
    corrupt: int = 0       # truncated/unreadable entry quarantined
    skipped: int = 0       # content not cross-process stable; not persisted
    save_errors: int = 0
    evictions: int = 0
    background_writes: int = 0  # stage exports handed to the writer thread
    fallbacks: int = 0     # loads that fell back to live compilation because
                           # the entry was corrupt/incompatible (not plain
                           # misses) — the serving-visible degradation count
    registry_saves: int = 0  # registry-journal writes (crash-safe recovery)
    registry_loads: int = 0
    registry_skipped: int = 0  # journal writes dropped (unpicklable state) —
                               # the on-disk journal is stale from here on

    def snapshot(self) -> dict[str, int]:
        return dict(self.__dict__)


class ArtifactStore:
    """Content-addressed disk cache for optimizer output and stage programs.

    Keys are caller-supplied canonical fingerprints (query fingerprint for
    the plan layer; chained stage fingerprint + env digest for the stage
    layer). All loads are fail-soft: any problem returns ``None`` and the
    caller compiles live.
    """

    def __init__(
        self,
        root: str,
        *,
        max_entries: int = 512,
        max_bytes: Optional[int] = None,
    ):
        self.root = os.path.abspath(os.path.expanduser(root))
        self.max_entries = int(max_entries)
        self.max_bytes = None if max_bytes is None else int(max_bytes)
        self.stats = StoreStats()
        self._write_queue: Optional[queue.Queue] = None
        self._writer: Optional[threading.Thread] = None
        self._writer_lock = threading.Lock()
        os.makedirs(os.path.join(self.root, _PLANS), exist_ok=True)
        os.makedirs(os.path.join(self.root, _STAGES), exist_ok=True)

    def __repr__(self) -> str:
        return f"ArtifactStore({self.root!r}, entries={len(self._entries())})"

    # -- plan layer ----------------------------------------------------------

    def save_plan(self, query_fp: str, plan: Any, report: Any) -> bool:
        """Persist one optimizer output under its query fingerprint.

        Returns False (without writing) when the plan's content is not
        stable across processes: identity-hashed components or closures the
        pickler refuses — a fingerprint built on ``id()`` must never be
        trusted from another process.
        """
        from repro.relational.engine import plan_fingerprint

        pins: list = []
        plan_fp = plan_fingerprint(plan, pins=pins)
        if pins:
            self.stats.skipped += 1
            return False
        try:
            blob = pickle.dumps((plan, report))
        except Exception:
            self.stats.skipped += 1
            return False
        meta = {**compat_header(), "plan_fingerprint": plan_fp}
        return self._write_entry(
            os.path.join(self.root, _PLANS, query_fp),
            {_PLAN_BLOB: blob}, meta,
        )

    def load_plan(self, query_fp: str) -> Optional[tuple[Any, Any]]:
        """Load ``(plan, report)`` for a query fingerprint, or None.

        The unpickled plan is re-fingerprinted and checked against the
        entry's recorded hash, so a corrupted blob that still unpickles is
        rejected rather than silently served.
        """
        from repro.relational.engine import plan_fingerprint

        d = os.path.join(self.root, _PLANS, query_fp)
        if self._injected_read_fault(d, token=query_fp):
            self.stats.plan_misses += 1
            return None
        meta = self._read_meta(d)
        if meta is None:
            self.stats.plan_misses += 1
            return None
        if not self._compatible(meta):
            self.stats.plan_misses += 1
            return None
        try:
            with open(os.path.join(d, _PLAN_BLOB), "rb") as f:
                plan, report = pickle.loads(f.read())
            pins: list = []
            if plan_fingerprint(plan, pins=pins) != meta["plan_fingerprint"] or pins:
                raise ValueError("plan fingerprint mismatch after load")
        except FileNotFoundError:
            self._quarantine(d)  # meta without blob: a truncated entry
            self.stats.plan_misses += 1
            return None
        except OSError:
            self.stats.plan_misses += 1  # transient: retry next time
            return None
        except Exception:
            self._quarantine(d)
            self.stats.plan_misses += 1
            return None
        self.stats.plan_hits += 1
        return plan, report

    # -- stage layer ---------------------------------------------------------

    def save_stage(
        self, stage_fp: str, digest: str, fn: Callable, env: dict[str, Any]
    ) -> bool:
        """AOT-export ``fn`` for ``env``'s exact shapes and persist it.

        ``fn`` must be the *raw* stage function (not the trace-accounting
        wrapper) so the export trace doesn't inflate retrace counters.
        ``env`` may carry real arrays or ``jax.ShapeDtypeStruct`` leaves —
        the export only needs the structure.
        """
        from jax import export

        try:
            blob = export.export(jax.jit(fn))(env).serialize()
        except Exception:
            self.stats.save_errors += 1
            return False
        meta = {**compat_header(), "stage_fingerprint": stage_fp,
                "env_digest": digest}
        return self._write_entry(
            os.path.join(self.root, _STAGES, stage_fp, digest),
            {_STAGE_BLOB: bytes(blob)}, meta,
        )

    def save_stage_async(
        self, stage_fp: str, digest: str, fn: Callable, env: dict[str, Any]
    ) -> None:
        """Queue one stage export for the background writer thread.

        The first compile of a new bucket used to pay ``jax.export``
        serialization + the disk write inline on the request path; this
        hands both to a daemon writer. ``env`` is reduced to shapes/dtypes
        immediately (:func:`abstract_env`), so the queue never pins device
        buffers (and a donated entry buffer can't be touched after
        invalidation). ``drain()`` blocks until queued writes land —
        registered via ``atexit`` too, so a short-lived process still
        persists what it compiled.
        """
        abstract = abstract_env(env)
        with self._writer_lock:
            if self._write_queue is None:
                self._write_queue = queue.Queue()
                self._writer = threading.Thread(
                    target=self._writer_loop, name="raven-artifact-writer",
                    daemon=True,
                )
                self._writer.start()
                atexit.register(self.drain)
            self.stats.background_writes += 1
            self._write_queue.put((stage_fp, digest, fn, abstract))

    def _writer_loop(self) -> None:
        q = self._write_queue
        while True:
            item = q.get()
            try:
                if item is not None:
                    self.save_stage(*item)
            except BaseException:  # noqa: BLE001 — the writer must survive
                self.stats.save_errors += 1
            finally:
                q.task_done()
            if item is None:
                return

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every queued background write has been attempted.

        ``timeout`` bounds the wait (None = until the queue empties); safe
        to call from any thread, any number of times.
        """
        with self._writer_lock:
            q = self._write_queue
        if q is None:
            return
        if timeout is None:
            q.join()
            return
        # poll with a deadline instead of spawning a joiner thread: a stuck
        # write must not leak one permanently-parked thread per timed call
        end = time.monotonic() + timeout
        while q.unfinished_tasks and time.monotonic() < end:
            time.sleep(0.01)

    def close(self) -> None:
        """Flush pending writes, stop the writer thread, and drop the
        ``atexit`` hook. Long-lived processes that open many stores
        (per-tenant sessions, reconnects) would otherwise accumulate one
        parked writer thread — and one atexit reference pinning the store —
        per store. A closed store stays usable: the next async save simply
        starts a fresh writer."""
        with self._writer_lock:
            q, writer = self._write_queue, self._writer
            self._write_queue = None
            self._writer = None
        if q is None:
            return
        q.put(None)  # writes ahead of the sentinel still land (FIFO)
        if writer is not None:
            writer.join(timeout=30.0)
        try:
            atexit.unregister(self.drain)
        except Exception:  # pragma: no cover - unregister is best-effort
            pass

    def pending_writes(self) -> int:
        with self._writer_lock:
            q = self._write_queue
        return 0 if q is None else q.unfinished_tasks

    def load_stage(self, stage_fp: str, digest: str) -> Optional[Callable]:
        """Deserialize one exported stage program, or None.

        The returned callable replays the serialized StableHLO — it never
        runs the stage's Python function, so calling it counts zero traces.
        """
        from jax import export

        d = os.path.join(self.root, _STAGES, stage_fp, digest)
        if self._injected_read_fault(d, token=stage_fp):
            self.stats.stage_misses += 1
            return None
        meta = self._read_meta(d)
        if meta is None:
            self.stats.stage_misses += 1
            return None
        if not self._compatible(meta) or meta.get("env_digest") != digest:
            self.stats.stage_misses += 1
            return None
        try:
            with open(os.path.join(d, _STAGE_BLOB), "rb") as f:
                exported = export.deserialize(bytearray(f.read()))
            call = exported.call
        except FileNotFoundError:
            self._quarantine(d)  # meta without blob: a truncated entry
            self.stats.stage_misses += 1
            return None
        except OSError:
            self.stats.stage_misses += 1  # transient: retry next time
            return None
        except Exception:
            self._quarantine(d)
            self.stats.stage_misses += 1
            return None
        self.stats.stage_hits += 1
        return call

    # -- registry-journal layer ----------------------------------------------
    # Unlike plans/stages, the journal is *mutable* state: one file per
    # registry fingerprint, rewritten whole on every lifecycle mutation.
    # ``tmp + os.replace`` keeps each rewrite atomic (a kill -9 mid-write
    # leaves the previous complete journal in place), which is what makes
    # ``Session.recover()`` crash-safe.

    def _registry_path(self, key: str) -> str:
        return os.path.join(self.root, _REGISTRY, f"{key}.pkl")

    def save_registry(self, key: str, state: Any) -> bool:
        """Atomically persist one registry journal under its fingerprint.

        Returns False without writing when the state does not pickle
        (e.g. a published pipeline closes over an unpicklable python UDF) —
        the in-process registry still works; only crash recovery is
        unavailable, and ``stats.skipped`` records it.
        """
        try:
            blob = pickle.dumps({"header": compat_header(), "state": state})
        except Exception:
            self.stats.skipped += 1
            self.stats.registry_skipped += 1
            return False
        d = os.path.join(self.root, _REGISTRY)
        try:
            os.makedirs(d, exist_ok=True)
            fd, tmp = tempfile.mkstemp(prefix=".journal_tmp_", dir=d)
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._registry_path(key))
        except OSError:
            self.stats.save_errors += 1
            return False
        self.stats.registry_saves += 1
        return True

    def load_registry(self, key: str) -> Optional[Any]:
        """Load the journal for one registry fingerprint, or None.

        Only the store version gates compatibility — the journal describes
        route/version *topology*, which is backend-independent; the plan
        and stage artifacts it points at check their own full headers."""
        path = self._registry_path(key)
        if self._injected_read_fault(path, token=key):
            return None
        try:
            with open(path, "rb") as f:
                payload = pickle.loads(f.read())
            header, state = payload["header"], payload["state"]
        except FileNotFoundError:
            return None
        except OSError:
            return None
        except Exception:
            self.stats.corrupt += 1
            self.stats.fallbacks += 1
            try:
                os.replace(path, path + ".quarantined")
            except OSError:
                pass
            return None
        if header.get("store_version") != STORE_VERSION:
            self.stats.incompatible += 1
            self.stats.fallbacks += 1
            return None
        self.stats.registry_loads += 1
        return state

    def stage_digests(self, stage_fp: str) -> list[str]:
        """Every complete on-disk env digest for one stage fingerprint
        (registration warm-start enumerates these)."""
        d = os.path.join(self.root, _STAGES, stage_fp)
        try:
            names = os.listdir(d)
        except OSError:
            return []
        return sorted(
            n for n in names
            if os.path.exists(os.path.join(d, n, _META))
        )

    # -- internals -----------------------------------------------------------

    def _write_entry(
        self, final_dir: str, files: dict[str, bytes], meta: dict[str, Any]
    ) -> bool:
        """Atomic entry write: tmp dir + rename; meta.json written last.

        Lost races are fine — content-addressed keys mean the winner wrote
        the same artifact, so the loser just discards its tmp dir.
        """
        if os.path.exists(os.path.join(final_dir, _META)):
            return True  # already present (same content by construction)
        os.makedirs(os.path.dirname(final_dir), exist_ok=True)
        tmp = tempfile.mkdtemp(prefix=".art_tmp_", dir=self.root)
        try:
            for name, data in files.items():
                with open(os.path.join(tmp, name), "wb") as f:
                    f.write(data)
            with open(os.path.join(tmp, _META), "w") as f:
                json.dump(meta, f)
            try:
                os.rename(tmp, final_dir)
            except OSError:
                shutil.rmtree(tmp, ignore_errors=True)  # concurrent writer won
                return True
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            self.stats.save_errors += 1
            return False
        if "plan_fingerprint" in meta:
            self.stats.plan_saves += 1
        else:
            self.stats.stage_saves += 1
        self._evict()
        return True

    def _read_meta(self, d: str) -> Optional[dict[str, Any]]:
        try:
            with open(os.path.join(d, _META)) as f:
                return json.load(f)
        except ValueError:
            # the header exists but is not valid json: the entry is truly
            # corrupt (entries are renamed into place whole, meta written
            # last), so drop it for rebuild
            self._quarantine(d)
            return None
        except OSError:
            # missing entry (a plain miss) or a transient error (EMFILE,
            # EACCES from a scanner holding the file): never delete a
            # possibly-healthy entry — just report a miss and move on
            return None

    def _injected_read_fault(self, d: str, token: str = "") -> bool:
        """The ``store-read`` fault site: when the installed
        :class:`~repro.exec.faults.FaultPlan` fires here, the entry is
        treated as torn on disk — quarantined through the real corruption
        path (so the counters the serving layer surfaces are the real
        ones) — and the load reports a miss. Store reads are fail-soft by
        contract, so an injected read fault degrades to live compilation
        and can never surface as a caller-visible error."""
        from repro.errors import FaultInjectedError
        from repro.exec.faults import maybe_inject

        try:
            maybe_inject("store-read", token=token)
        except FaultInjectedError:
            if os.path.exists(os.path.join(d, _META)):
                self._quarantine(d)
            else:
                self.stats.fallbacks += 1
            return True
        return False

    def _compatible(self, meta: dict[str, Any]) -> bool:
        header = compat_header()
        if all(meta.get(k) == v for k, v in header.items()):
            return True
        self.stats.incompatible += 1
        self.stats.fallbacks += 1
        return False

    def _quarantine(self, d: str) -> None:
        """Drop a corrupted/truncated entry so it is rebuilt, not retried."""
        self.stats.corrupt += 1
        self.stats.fallbacks += 1
        shutil.rmtree(d, ignore_errors=True)

    def _entries(self) -> list[str]:
        """Every complete entry directory (plans/* and stages/*/*)."""
        out: list[str] = []
        plans = os.path.join(self.root, _PLANS)
        stages = os.path.join(self.root, _STAGES)
        for base in ([plans] if os.path.isdir(plans) else []):
            out.extend(os.path.join(base, n) for n in os.listdir(base))
        if os.path.isdir(stages):
            for fp in os.listdir(stages):
                d = os.path.join(stages, fp)
                if os.path.isdir(d):
                    out.extend(os.path.join(d, n) for n in os.listdir(d))
        return [d for d in out if os.path.exists(os.path.join(d, _META))]

    @staticmethod
    def _entry_bytes(d: str) -> int:
        total = 0
        try:
            for name in os.listdir(d):
                try:
                    total += os.path.getsize(os.path.join(d, name))
                except OSError:
                    pass
        except OSError:
            pass
        return total

    def total_bytes(self) -> int:
        """Bytes held by complete entries (the ``max_bytes`` accounting)."""
        return sum(self._entry_bytes(d) for d in self._entries())

    def _evict(self) -> None:
        """Oldest-first eviction keeps the cache dir bounded — by entry
        count (``max_entries``) and, when configured, by total size
        (``max_bytes``): exported stage programs for wide buckets run to
        megabytes each, so a count cap alone can still blow a disk quota."""
        entries = self._entries()
        if len(entries) <= self.max_entries and self.max_bytes is None:
            return  # common case: one length check, no stat storm

        def mtime(d: str) -> float:
            try:
                return os.path.getmtime(os.path.join(d, _META))
            except OSError:
                return 0.0

        entries.sort(key=mtime)
        drop = max(0, len(entries) - self.max_entries)
        victims = entries[:drop]
        if self.max_bytes is not None:
            sizes = {d: self._entry_bytes(d) for d in entries}
            total = sum(sizes[d] for d in entries[drop:])
            # never evict the newest entry: a single artifact larger than
            # max_bytes would otherwise thrash the store forever
            for d in entries[drop:-1]:
                if total <= self.max_bytes:
                    break
                victims.append(d)
                total -= sizes[d]
        for d in victims:
            shutil.rmtree(d, ignore_errors=True)
            parent = os.path.dirname(d)
            if os.path.basename(os.path.dirname(parent)) == _STAGES:
                try:
                    os.rmdir(parent)  # drop a stage dir left empty
                except OSError:
                    pass
            self.stats.evictions += 1

    # -- operator surface ----------------------------------------------------

    def entries(self) -> list["StoreEntry"]:
        """Typed listing of every complete entry, newest first.

        The operator view behind ``python -m repro.exec.artifact_store
        inspect``: one :class:`StoreEntry` per on-disk artifact with its
        layer, key, size, age, and whether its compat header matches this
        process (stale jax/backend entries show up as ``compat=False``
        instead of silently wasting disk until eviction).
        """
        now = time.time()  # analysis: allow[wallclock-timing] — file mtimes
        out: list[StoreEntry] = []
        for d in self._entries():
            meta = self._read_meta(d)
            if meta is None:
                continue
            layer = "plan" if "plan_fingerprint" in meta else "stage"
            if layer == "plan":
                key = os.path.basename(d)
                digest = meta.get("plan_fingerprint", "")
            else:
                key = os.path.basename(os.path.dirname(d))
                digest = meta.get("env_digest", "")
            try:
                mtime = os.path.getmtime(os.path.join(d, _META))
            except OSError:
                mtime = now
            out.append(StoreEntry(
                layer=layer, key=key, digest=digest, path=d,
                size_bytes=self._entry_bytes(d),
                age_s=max(0.0, now - mtime),
                compat=all(
                    meta.get(k) == v for k, v in compat_header().items()
                ),
            ))
        out.sort(key=lambda e: e.age_s)
        return out

    def prune(
        self,
        *,
        max_age_s: Optional[float] = None,
        max_bytes: Optional[int] = None,
        keys: Optional[set] = None,
        dry_run: bool = False,
    ) -> list["StoreEntry"]:
        """Drop entries older than ``max_age_s``, whose fingerprint key is
        in ``keys`` (retired-version garbage collection), and/or evict
        oldest-first until the store fits in ``max_bytes``. Returns the
        victims (the would-be victims under ``dry_run``, with nothing
        deleted)."""
        entries = self.entries()  # newest first
        victims: list[StoreEntry] = []
        if max_age_s is not None:
            victims.extend(e for e in entries if e.age_s > max_age_s)
        if keys:
            doomed = {e.path for e in victims}
            victims.extend(
                e for e in entries
                if e.key in keys and e.path not in doomed
            )
        if max_bytes is not None:
            doomed = {e.path for e in victims}
            total = sum(e.size_bytes for e in entries if e.path not in doomed)
            # oldest first, but never the newest entry (mirrors _evict: one
            # oversized artifact must not thrash the store)
            for e in reversed(entries[1:]):
                if total <= max_bytes:
                    break
                if e.path in doomed:
                    continue
                victims.append(e)
                doomed.add(e.path)
                total -= e.size_bytes
        if not dry_run:
            for e in victims:
                shutil.rmtree(e.path, ignore_errors=True)
                parent = os.path.dirname(e.path)
                if os.path.basename(os.path.dirname(parent)) == _STAGES:
                    try:
                        os.rmdir(parent)
                    except OSError:
                        pass
                self.stats.evictions += 1
        return victims


@dataclass(frozen=True)
class StoreEntry:
    """One on-disk artifact as the operator CLI sees it."""

    layer: str       # "plan" | "stage"
    key: str         # query fingerprint (plan) / stage fingerprint (stage)
    digest: str      # plan fingerprint / env digest
    path: str
    size_bytes: int
    age_s: float
    compat: bool     # header matches this process's store/jax/backend


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"  # pragma: no cover - unreachable


def main(argv: Optional[list[str]] = None) -> int:
    """``python -m repro.exec.artifact_store {inspect,prune}`` — operator
    tooling for a store directory shared by serving processes."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.exec.artifact_store",
        description="Inspect or prune a Raven plan-artifact store.",
    )
    ap.add_argument("--root", required=True, help="store directory")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ins = sub.add_parser("inspect", help="list entries (newest first)")
    ins.add_argument("--layer", choices=["plan", "stage"], default=None)
    ins.add_argument("--fingerprint", default=None,
                     help="only entries whose key starts with this prefix")
    ins.add_argument("--min-bytes", type=int, default=0)
    ins.add_argument("--max-age-s", type=float, default=None,
                     help="only entries younger than this")
    ins.add_argument("--json", action="store_true", dest="as_json")

    pr = sub.add_parser("prune", help="delete old/oversized entries")
    pr.add_argument("--max-age-s", type=float, default=None,
                    help="drop entries older than this many seconds")
    pr.add_argument("--max-bytes", type=int, default=None,
                    help="evict oldest-first until the store fits")
    pr.add_argument("--key", action="append", default=None,
                    help="drop entries with this exact fingerprint key "
                         "(repeatable; retired-version GC)")
    pr.add_argument("--dry-run", action="store_true")

    args = ap.parse_args(argv)
    store = ArtifactStore(args.root)

    if args.cmd == "inspect":
        rows = store.entries()
        if args.layer:
            rows = [e for e in rows if e.layer == args.layer]
        if args.fingerprint:
            rows = [e for e in rows if e.key.startswith(args.fingerprint)]
        if args.min_bytes:
            rows = [e for e in rows if e.size_bytes >= args.min_bytes]
        if args.max_age_s is not None:
            rows = [e for e in rows if e.age_s <= args.max_age_s]
        if args.as_json:
            print(json.dumps([e.__dict__ for e in rows], indent=2))
        else:
            for e in rows:
                flag = "" if e.compat else "  [incompatible]"
                print(f"{e.layer:5s} {e.key[:16]:16s} {e.digest[:16]:16s} "
                      f"{_fmt_bytes(e.size_bytes):>10s} "
                      f"{e.age_s:8.0f}s{flag}")
            print(f"-- {len(rows)} entries, "
                  f"{_fmt_bytes(sum(e.size_bytes for e in rows))} total")
        return 0

    if args.max_age_s is None and args.max_bytes is None and not args.key:
        ap.error("prune needs --max-age-s, --max-bytes, and/or --key")
    victims = store.prune(
        max_age_s=args.max_age_s, max_bytes=args.max_bytes,
        keys=set(args.key) if args.key else None,
        dry_run=args.dry_run,
    )
    verb = "would delete" if args.dry_run else "deleted"
    for e in victims:
        print(f"{verb} {e.layer} {e.key[:16]} "
              f"({_fmt_bytes(e.size_bytes)}, {e.age_s:.0f}s old)")
    print(f"-- {verb} {len(victims)} entries, "
          f"{_fmt_bytes(sum(e.size_bytes for e in victims))}")
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    raise SystemExit(main())
