"""StageGraph: a first-class physical stage IR for the execution layer.

Lowering a physical plan used to produce an opaque list of Python closures;
every serving optimization (post-UDF bucketing, cross-request coalescing,
async flush, plan-cache persistence) dead-ended at that representation. This
module replaces it with a declarative graph of :class:`Stage` nodes, each
carrying:

  * its operator slice of the plan (maximal pure-jnp segment, or one MLUdf
    host boundary),
  * input/output column schema and the env tables it reads,
  * the ``:param`` slots its expressions consume,
  * a canonical per-stage content fingerprint (chained through upstream
    stages, so a stage's hash identifies *this stage of this plan*),
  * runtime accounting (XLA traces, calls, wall time).

Execution threads a three-part state ``(columns, valid, seg)`` through the
stages: ``valid`` is the row-validity mask that makes padded/bucketed serving
exact, and ``seg`` is an optional per-row request-segment id that lets
submits from different requests coalesce into one padded batch and be split
back apart after host boundaries compact rows (and lets aggregates fold
per-segment instead of per-batch).

The runner (:func:`run_graph`) accepts a ``bucketer`` so the serving layer
can re-pad rows to a power-of-two bucket at *every* host-boundary exit — not
just at query entry — which is what keeps post-UDF pure stages from
re-tracing on data-dependent shape churn.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.expr import eval_expr, params_of
from repro.relational.table import Table

# -- execution-environment keys ---------------------------------------------
# (canonical home; repro.relational.engine re-exports the first two for
# backward compatibility)

# initial fact-spine validity mask (padded serving)
ROW_VALID_KEY = "__row_valid__"
# bound :param values (0-d arrays): runtime inputs, so re-binding never
# re-traces
PARAMS_KEY = "__params__"
# per-row request-segment ids (int32), present only under coalesced serving
ROW_SEG_KEY = "__row_seg__"
# baked dim-table sort data, injected once per execution by the engine:
# {dim_table: {"keys": sorted_keys, "order": argsort_perm[, "unique": ...]}}.
# Dim tables are frozen at registration, so the engine computes (and caches)
# the sorted order on the host instead of re-deriving it inside the traced
# stage on every call; the Join step falls back to an in-trace argsort when
# the entry is absent (abstract execution, sharded path).
DIMSORT_KEY = "__dimsort__"
# arange(num_segment_slots): its *static length* tells segmented aggregates
# their output width at trace time (slot count is power-of-two bucketed)
SEG_SLOTS_KEY = "__seg_slots__"
# runtime scalar: how many of the segment slots are real requests
SEG_COUNT_KEY = "__seg_count__"

# pseudo-table carrying a host boundary's output into the next pure stage
MID_TABLE = "__mid__"
MID_VALID = "__valid__"
MID_SEG = "__seg__"

# state threaded through stages: (columns, valid-mask, segment-ids-or-None)
State = tuple[dict[str, jnp.ndarray], jnp.ndarray, Optional[jnp.ndarray]]


def donation_enabled() -> bool:
    """Whether pure stages donate their entry buffers to XLA.

    Donation lets the compiler reuse the (single-use) padded fact-spine
    buffers in place instead of allocating fresh outputs. XLA:CPU does not
    implement input-output aliasing, so by default donation is on only for
    accelerator backends; ``RAVEN_DONATE=1``/``0`` forces it either way
    (the forced-on CPU path still computes correctly — jax just warns that
    the donated buffers were not usable).
    """
    flag = os.environ.get("RAVEN_DONATE")
    if flag is not None:
        return flag not in ("0", "false", "")
    return jax.default_backend() != "cpu"


# env keys that are per-execution (single-use) rather than database-resident:
# eligible for donation alongside the donated fact tables
VOLATILE_KEYS = (ROW_VALID_KEY, ROW_SEG_KEY, MID_TABLE)


def seg_bucket(k: int, min_bucket: int = 4) -> int:
    """Power-of-two segment-slot bucket for ``k`` coalesced requests.

    Bucketing the slot count (like row counts) bounds the number of traced
    segmented-aggregate programs at log2 of the max coalesce width.
    """
    b = max(int(min_bucket), 1)
    while b < k:
        b <<= 1
    return b


# ---------------------------------------------------------------------------
# Pure-operator steps (env -> State composition)
# ---------------------------------------------------------------------------


def pure_step(plan, inner: Optional[Callable[[dict], State]]) -> Callable[[dict], State]:
    """Compose one pure operator on top of ``inner`` (env -> state)."""
    from repro.relational.engine import (
        Aggregate,
        Filter,
        Join,
        Project,
        Scan,
        TensorOp,
    )

    if isinstance(plan, Scan):
        def fn(env, _plan=plan):
            cols = {c: env[_plan.table][c] for c in _plan.columns}
            n = next(iter(cols.values())).shape[0]
            # the serving layer pads batches to a shape bucket and marks the
            # pad rows invalid up front via ROW_VALID_KEY
            rv = env.get(ROW_VALID_KEY)
            valid = jnp.ones((n,), dtype=bool) if rv is None else rv.astype(bool)
            return cols, valid, env.get(ROW_SEG_KEY)
        return fn

    if isinstance(plan, Join):
        # relational-kernel mode is a codegen decision: captured once at
        # stage-build time, and folded into the stage fingerprint by
        # build_stage_graph so the two modes never alias compiled artifacts
        from repro.kernels.ops import kernels_enabled

        use_kernels = kernels_enabled()

        def fn(env, _plan=plan, _kern=use_kernels):
            from repro.tensor.compile import (
                emit_join_kernel,
                join_kernel_qualifies,
            )

            cols, valid, seg = inner(env)
            dim = env[_plan.dim_table]
            keys = dim[_plan.dim_key]
            fk = cols[_plan.fact_key]
            ds = env.get(DIMSORT_KEY, {}).get(_plan.dim_table)
            if _kern and join_kernel_qualifies(_plan, dim, fk, ds):
                brought, hit = emit_join_kernel(_plan, dim, fk, ds)
                out = dict(cols)
                out.update(brought)
                return out, valid & hit, seg
            if ds is not None:  # baked at registration (satellite: no
                order = ds["order"]  # per-call argsort inside the trace)
                skeys = ds["keys"]
            else:
                order = jnp.argsort(keys)
                skeys = keys[order]
            pos = jnp.searchsorted(skeys, fk)
            pos = jnp.clip(pos, 0, skeys.shape[0] - 1)
            hit = skeys[pos] == fk
            gather = order[pos]
            out = dict(cols)
            for c in _plan.dim_columns:
                out[c] = dim[c][gather]
            return out, valid & hit, seg
        return fn

    if isinstance(plan, Filter):
        def fn(env, _plan=plan):
            cols, valid, seg = inner(env)
            keep = eval_expr(_plan.expr, cols, env.get(PARAMS_KEY))
            return cols, valid & keep.astype(bool), seg
        return fn

    if isinstance(plan, Project):
        def fn(env, _plan=plan):
            cols, valid, seg = inner(env)
            keep = _plan.keep if _plan.keep is not None else list(cols)
            out = {c: cols[c] for c in keep}
            for name, e in _plan.exprs.items():
                out[name] = eval_expr(e, cols, env.get(PARAMS_KEY))
            return out, valid, seg
        return fn

    if isinstance(plan, TensorOp):
        def fn(env, _plan=plan):
            cols, valid, seg = inner(env)
            out = dict(cols)
            out.update(_plan.fn(cols))
            for c in _plan.consumes:  # block columns ending here (split)
                out.pop(c, None)
            return out, valid, seg
        return fn

    if isinstance(plan, Aggregate):
        from repro.kernels.ops import kernels_enabled

        use_kernels = kernels_enabled()

        def fn(env, _plan=plan, _kern=use_kernels):
            from repro.tensor.compile import emit_aggregate_kernel

            cols, valid, seg = inner(env)
            w = valid.astype(jnp.float32)
            if seg is None:
                # global fold: a single output row; the upstream filter is
                # already folded in as the validity weight
                if _kern:
                    sid = jnp.zeros_like(valid, dtype=jnp.int32)
                    out = emit_aggregate_kernel(_plan.aggs, cols, w, sid, 1)
                    return out, jnp.ones((1,), dtype=bool), None
                out = {}
                sid0 = jnp.zeros_like(valid, dtype=jnp.int32)
                nvalid = jnp.sum(w)
                for name, op, col in _plan.aggs:
                    if op == "count":
                        out[name] = nvalid[None]
                    elif op == "sum":
                        out[name] = jnp.sum(cols[col] * w)[None]
                    elif op == "mean":
                        out[name] = (
                            jnp.sum(cols[col] * w) / jnp.maximum(nvalid, 1.0)
                        )[None]
                    elif op in ("min", "max"):
                        out[name] = _masked_extremum(
                            op, cols[col], valid, nvalid[None], sid0, 1
                        )
                    else:
                        raise ValueError(op)
                return out, jnp.ones((1,), dtype=bool), None
            # segmented fold: one output row per request slot. Invalid/pad
            # rows carry weight 0, so routing them to slot 0 is harmless;
            # slot count is static (len of SEG_SLOTS_KEY), the number of
            # *real* segments is a runtime scalar.
            slots = env[SEG_SLOTS_KEY]
            ns = slots.shape[0]
            k = env[SEG_COUNT_KEY]
            sid = jnp.where(valid, seg, 0)
            if _kern:
                out = emit_aggregate_kernel(_plan.aggs, cols, w, sid, ns)
                return out, slots < k, slots
            counts = jax.ops.segment_sum(w, sid, num_segments=ns)
            out = {}
            for name, op, col in _plan.aggs:
                if op == "count":
                    out[name] = counts
                elif op == "sum":
                    out[name] = jax.ops.segment_sum(
                        cols[col] * w, sid, num_segments=ns
                    )
                elif op == "mean":
                    s = jax.ops.segment_sum(cols[col] * w, sid, num_segments=ns)
                    out[name] = s / jnp.maximum(counts, 1.0)
                elif op in ("min", "max"):
                    out[name] = _masked_extremum(
                        op, cols[col], valid, counts, sid, ns
                    )
                else:
                    raise ValueError(op)
            return out, slots < k, slots
        return fn

    raise TypeError(type(plan))


def _masked_extremum(op, values, valid, counts, sid, ns):
    """Segment min/max over valid rows only; empty segments yield 0.0 (the
    same convention in the jnp fallback, the CPU oracle, and the Pallas
    kernel, so every dispatch path agrees)."""
    v = values.astype(jnp.float32)
    if op == "min":
        m = jax.ops.segment_min(
            jnp.where(valid, v, jnp.inf), sid, num_segments=ns
        )
    else:
        m = jax.ops.segment_max(
            jnp.where(valid, v, -jnp.inf), sid, num_segments=ns
        )
    return jnp.where(counts > 0, m, 0.0)


def _from_mid(env) -> State:
    """Stage entry for operators sitting on top of a host boundary: the
    boundary's output arrives re-wrapped as the ``__mid__`` pseudo-table."""
    cols = dict(env[MID_TABLE])
    valid = cols.pop(MID_VALID)
    seg = cols.pop(MID_SEG, None)
    return cols, valid, seg


# ---------------------------------------------------------------------------
# Stage / StageGraph
# ---------------------------------------------------------------------------


@dataclass
class Stage:
    """One node of the stage graph.

    ``kind == "pure"`` stages own a maximal pure-jnp operator segment and are
    jitted into a single XLA program (``runner``); ``kind == "host"`` stages
    own one MLUdf boundary and run interpreted on host. ``fingerprint`` is a
    canonical content hash of this stage's operators chained through every
    upstream stage's hash.
    """

    index: int
    kind: str  # "pure" | "host"
    ops: list  # plan-node slice, innermost first
    fingerprint: str
    reads: dict[str, tuple[str, ...]]  # env tables consumed -> columns
    in_columns: Optional[tuple[str, ...]]  # upstream-stage columns consumed
    out_columns: tuple[str, ...]
    params: frozenset[str] = frozenset()
    fn: Optional[Callable[[dict], State]] = None  # pure: raw env -> state
    runner: Optional[Callable[[dict], State]] = None  # pure: jitted fn
    udf: Any = None  # host: the MLUdf plan node
    # False when the chained fingerprint involves an identity-hashed (id())
    # component — valid only while those objects live in THIS process, so
    # the persistent artifact store must never key an entry on it
    content_stable: bool = True
    # runtime accounting (mutated by the jit trace hook and the runner)
    traces: int = 0
    calls: int = 0
    total_s: float = 0.0
    # pipelined-execution accounting: async_calls counts executions where a
    # pure stage was *dispatched* without waiting for the device (dispatch_s
    # is that enqueue cost; the device time overlaps other stages), and for
    # host stages the wall time spent off the dispatch thread on the
    # boundary pool
    async_calls: int = 0
    dispatch_s: float = 0.0
    # bucket programs served from the persistent artifact store instead of
    # being traced in this process (warm-start preloads + lazy disk hits)
    disk_loads: int = 0

    @property
    def label(self) -> str:
        """Compact operator chain, e.g. ``Scan[patients]→Project``."""
        return "→".join(_op_label(op) for op in self.ops)

    def describe(self) -> str:
        avg = f"{1e3 * self.total_s / self.calls:.2f}ms" if self.calls else "-"
        out = ", ".join(self.out_columns)
        pin = f" params=({', '.join(sorted(self.params))})" if self.params else ""
        disk = f" disk_loads={self.disk_loads}" if self.disk_loads else ""
        pipe = ""
        if self.async_calls:
            d = 1e3 * self.dispatch_s / self.async_calls
            word = "overlap" if self.kind == "host" else "dispatch"
            pipe = f" pipelined={self.async_calls} {word}={d:.2f}ms"
        return (
            f"[{self.index}] {self.kind:<4} {self.label}  "
            f"fp={self.fingerprint[:12]}…  out=({out}){pin}  "
            f"traces={self.traces} calls={self.calls} avg={avg}{pipe}{disk}"
        )


@dataclass
class StageGraph:
    """The lowered physical plan: a linear chain of stages."""

    plan: Any  # the PhysicalPlan this graph was lowered from
    stages: list[Stage]

    @property
    def is_pure(self) -> bool:
        """One jitted XLA program, no host boundary (MLtoSQL/MLtoDNN output)."""
        return all(s.kind == "pure" for s in self.stages)

    @property
    def n_host_boundaries(self) -> int:
        return sum(1 for s in self.stages if s.kind == "host")

    @property
    def has_aggregate(self) -> bool:
        from repro.relational.engine import Aggregate

        return any(
            isinstance(op, Aggregate) for s in self.stages for op in s.ops
        )

    @property
    def needs_segments(self) -> bool:
        """True when per-request splitting of a coalesced batch requires
        segment ids: row alignment with the input spine is lost at host
        boundaries (compaction) and at aggregates (folding)."""
        return not self.is_pure or self.has_aggregate

    @property
    def traces(self) -> int:
        return sum(s.traces for s in self.stages)

    def describe(self) -> str:
        head = (
            f"stage graph: {len(self.stages)} stage(s), "
            f"{self.n_host_boundaries} host boundary(ies)"
        )
        return "\n".join([head] + [s.describe() for s in self.stages])


# ---------------------------------------------------------------------------
# Plan segmentation + schema inference
# ---------------------------------------------------------------------------


def _linearize(plan) -> list:
    """Plan nodes innermost (Scan) first. Plans are linear chains."""
    from repro.relational.engine import walk_plan

    return list(walk_plan(plan))[::-1]


def plan_segments(plan) -> list[tuple[str, list]]:
    """Split a plan into maximal pure segments and host-boundary segments.

    Returns ``[(kind, ops), ...]`` with ops innermost-first — the shared
    segmentation logic used by lowering (fn building), the optimizer's
    stage-boundary annotation, and EXPLAIN.
    """
    from repro.relational.engine import MLUdf

    segments: list[tuple[str, list]] = []
    for op in _linearize(plan):
        if isinstance(op, MLUdf):
            segments.append(("host", [op]))
        elif segments and segments[-1][0] == "pure":
            segments[-1][1].append(op)
        else:
            segments.append(("pure", [op]))
    return segments


def _op_label(op) -> str:
    """One operator's display label (shared by Stage.label and the
    optimizer's stage-boundary annotation)."""
    name = type(op).__name__
    if name == "Scan":
        return f"Scan[{op.table}]"
    if name == "Join":
        return f"Join[{op.dim_table}]"
    if name == "MLUdf":
        return f"MLUdf[{op.pipeline.n_ops()}-op]"
    if name == "TensorOp":
        # the fused closure is opaque; the tensor compiler stamps the
        # columns it consumes (see TensorCompilation.input_names)
        ins = getattr(op.fn, "__input_names__", None)
        arity = f"{len(ins)}→{len(op.output_names)}" if ins is not None else (
            f"→{len(op.output_names)}"
        )
        return f"TensorOp[{arity}]"
    return name


def describe_segments(plan) -> list[str]:
    """Human-readable stage-boundary annotation (one line per stage), used by
    the optimizer's report at lowering time."""
    return [
        f"{kind}: " + "→".join(_op_label(op) for op in ops)
        for kind, ops in plan_segments(plan)
    ]


def _segment_out_cols(ops, in_cols: Optional[list[str]]) -> list[str]:
    """Fold output-column inference over one segment's operator slice."""
    from repro.relational.engine import (
        Aggregate,
        Filter,
        Join,
        MLUdf,
        Project,
        Scan,
        TensorOp,
    )

    cur = list(in_cols or [])
    for op in ops:
        if isinstance(op, Scan):
            cur = list(op.columns)
        elif isinstance(op, Join):
            cur = cur + list(op.dim_columns)
        elif isinstance(op, Filter):
            pass
        elif isinstance(op, Project):
            base = list(op.keep) if op.keep is not None else cur
            cur = base + [c for c in op.exprs if c not in base]
        elif isinstance(op, (MLUdf, TensorOp)):
            cur = [c for c in cur if c not in op.consumes]
            cur = cur + [c for c in op.output_names if c not in cur]
        elif isinstance(op, Aggregate):
            cur = [a[0] for a in op.aggs]
        else:
            raise TypeError(type(op))
    return cur


def _segment_reads(ops) -> dict[str, tuple[str, ...]]:
    """Env tables (and their columns) this segment reads directly."""
    from repro.relational.engine import Join, Scan

    reads: dict[str, list[str]] = {}
    for op in ops:
        if isinstance(op, Scan):
            reads.setdefault(op.table, []).extend(op.columns)
        elif isinstance(op, Join):
            cols = reads.setdefault(op.dim_table, [])
            for c in [op.dim_key, *op.dim_columns]:
                if c not in cols:
                    cols.append(c)
    return {t: tuple(cs) for t, cs in reads.items()}


def _segment_params(ops) -> frozenset[str]:
    from repro.relational.engine import Filter, Project

    names: set[str] = set()
    for op in ops:
        if isinstance(op, Filter):
            names |= params_of(op.expr)
        elif isinstance(op, Project):
            for e in op.exprs.values():
                names |= params_of(e)
    return frozenset(names)


def build_stage_graph(plan, pins: Optional[list] = None) -> StageGraph:
    """Lower a physical plan into its :class:`StageGraph`.

    Pure segments get an ``env -> state`` callable composed from
    :func:`pure_step` (jitted later by the engine, which installs ``runner``
    and the trace-accounting hook); host segments carry their MLUdf node.
    Per-stage fingerprints chain: ``fp[i] = H(fp[i-1], ops[i])`` with each
    operator hashed shallowly (child pointers excluded — the chain itself
    encodes upstream structure). A stage whose chain involved an
    identity-hashed component (anything landing in ``pins``) is marked
    ``content_stable=False`` — downstream stages inherit the mark, since
    their chained hash embeds the unstable prefix.
    """
    from repro.core.fingerprint import fingerprint, node_fingerprint
    from repro.kernels.ops import kernel_mode_token
    from repro.relational.engine import Aggregate, Join

    pins = pins if pins is not None else []
    stages: list[Stage] = []
    prev_fp = ""
    prev_out: Optional[list[str]] = None
    prev_stable = True
    for idx, (kind, ops) in enumerate(plan_segments(plan)):
        stage_pins: list = []
        tokens = [node_fingerprint(op, pins=stage_pins) for op in ops]
        # the RAVEN_KERNELS mode changes the program emitted for Join /
        # Aggregate stages, so it must fork their fingerprints (and only
        # theirs — other stages keep their historical hashes)
        extra = (
            [kernel_mode_token()]
            if any(isinstance(op, (Join, Aggregate)) for op in ops)
            else []
        )
        fp = fingerprint("stage", kind, prev_fp, tokens, *extra, pins=stage_pins)
        stable = prev_stable and not stage_pins
        pins.extend(stage_pins)
        out_cols = _segment_out_cols(ops, prev_out)
        if kind == "pure":
            fn: Optional[Callable] = None if idx == 0 else _from_mid
            for op in ops:
                fn = pure_step(op, fn)
            in_cols = tuple(prev_out) if prev_out is not None else None
            stage = Stage(
                index=idx, kind=kind, ops=ops, fingerprint=fp,
                content_stable=stable,
                reads=_segment_reads(ops), in_columns=in_cols,
                out_columns=tuple(out_cols), params=_segment_params(ops),
                fn=fn,
            )
        else:
            udf = ops[0]
            stage = Stage(
                index=idx, kind=kind, ops=ops, fingerprint=fp,
                content_stable=stable,
                reads={}, in_columns=tuple(udf.pipeline.input_names()),
                out_columns=tuple(out_cols), udf=udf,
            )
        stages.append(stage)
        prev_fp = fp
        prev_out = out_cols
        prev_stable = stable
    return StageGraph(plan=plan, stages=stages)


# ---------------------------------------------------------------------------
# Host-boundary (MLUdf) execution
# ---------------------------------------------------------------------------


def run_udf(udf, cols: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Batch-at-a-time interpreted pipeline execution (host)."""
    from repro.ml.pipeline import run_pipeline

    n = len(next(iter(cols.values())))
    in_names = udf.pipeline.input_names()
    outs: dict[str, list[np.ndarray]] = {o: [] for o in udf.pipeline.outputs}
    bs = udf.batch_size
    for s in range(0, max(n, 1), bs):
        batch = {k: cols[k][s : s + bs] for k in in_names}
        if len(next(iter(batch.values()))) == 0:
            continue
        res = run_pipeline(udf.pipeline, batch)
        for o in udf.pipeline.outputs:
            outs[o].append(np.asarray(res[o]))
    if n == 0:
        # run the pipeline over the zero-row slice anyway: outputs must keep
        # their true trailing shape (split-lowering block columns are 2-D),
        # or the downstream pure stage would trace against the wrong rank
        res = run_pipeline(udf.pipeline, {k: cols[k][:0] for k in in_names})
        for o in udf.pipeline.outputs:
            outs[o].append(np.asarray(res[o]))
    result = dict(cols)
    for o, name in zip(udf.pipeline.outputs, udf.output_names):
        result[name] = np.concatenate(outs[o])
    for c in udf.consumes:  # block columns ending at this boundary (split)
        if c not in udf.output_names:
            result.pop(c, None)
    return result


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------


@dataclass
class RunResult:
    """One graph execution: the result table, the per-row segment ids it
    carried (None outside coalesced serving), and per-stage wall times."""

    table: Table
    seg: Optional[jnp.ndarray]
    timings: list[float] = field(default_factory=list)


def call_pure(stage: Stage, env: dict[str, Any],
              donate: frozenset = frozenset()) -> State:
    """Invoke one pure stage — the jitted runner when the engine installed
    one (it understands the donation set), else the raw composed fn."""
    if stage.runner is not None:
        return stage.runner(env, donate=donate)
    return stage.fn(env)


def strip_consumed(env: dict[str, Any], donate: frozenset) -> dict[str, Any]:
    """Drop the entry stage's single-use inputs from the env once consumed.

    Under donation the entry stage aliased the padded fact spine (and the
    row-validity/segment vectors) into its outputs, so later stages must not
    see those now-invalid buffers; without donation this is a no-op so the
    env pytree structure — and therefore every warm jit specialization and
    on-disk artifact digest — is unchanged from the serial, non-donating
    layout.
    """
    if not donate or not donation_enabled():
        return env
    drop = set(donate) | {ROW_VALID_KEY, ROW_SEG_KEY}
    return {k: v for k, v in env.items() if k not in drop}


def host_step(
    stage: Stage,
    state: State,
    env: dict[str, Any],
    *,
    bucketer: Optional[Callable[[int], int]] = None,
    on_mid_bucket: Optional[Callable[[int, int], None]] = None,
) -> tuple[State, dict[str, Any]]:
    """Run one MLUdf host boundary: synchronize the upstream device state,
    compact to valid rows, run the interpreted pipeline, re-pad the output
    to a shape bucket, and re-wrap it as the ``__mid__`` pseudo-table.

    This is the graph's only synchronization point — ``np.asarray`` blocks
    on the device work the upstream pure stages dispatched — which is what
    lets the pipelined executor run it on a boundary worker thread while
    the dispatch thread keeps feeding the device. Returns the new state and
    the env (with ``__mid__`` installed) for the downstream stages.
    """
    from repro.exec.faults import maybe_inject

    # "udf" fault site: the interpreted ML runtime raises at the host
    # boundary (the Spark→Python-UDF failure mode), before any device sync
    maybe_inject("udf", token=stage.fingerprint)
    cols, valid, seg = state
    np_cols = {k: np.asarray(v) for k, v in cols.items()}
    mask = np.asarray(valid)
    np_cols = {k: v[mask] for k, v in np_cols.items()}  # compact
    np_seg = np.asarray(seg)[mask] if seg is not None else None
    out = run_udf(stage.udf, np_cols)
    n = len(next(iter(out.values()))) if out else 0
    b = bucketer(n) if bucketer is not None else n
    if b > n:
        out = {
            k: np.concatenate([v, np.zeros((b - n,) + v.shape[1:], dtype=v.dtype)])
            for k, v in out.items()
        }
        if np_seg is not None:
            np_seg = np.concatenate(
                [np_seg, np.zeros(b - n, dtype=np_seg.dtype)]
            )
    if on_mid_bucket is not None:
        on_mid_bucket(stage.index, b)
    mid = {k: jnp.asarray(v) for k, v in out.items()}
    mid[MID_VALID] = jnp.asarray(np.arange(b) < n)
    if np_seg is not None:
        mid[MID_SEG] = jnp.asarray(np_seg, dtype=jnp.int32)
    env = dict(env)
    env[MID_TABLE] = mid
    return _from_mid(env), env


def run_graph(
    graph: StageGraph,
    env: dict[str, Any],
    *,
    bucketer: Optional[Callable[[int], int]] = None,
    on_mid_bucket: Optional[Callable[[int, int], None]] = None,
    donate: frozenset = frozenset(),
) -> RunResult:
    """Execute a stage graph over an environment, one stage at a time.

    ``bucketer`` (serving layer) maps a host boundary's compacted row count
    to a padded bucket, so the *next* pure stage sees power-of-two shapes
    instead of data-dependent churn; ``on_mid_bucket(stage_index, bucket)``
    lets the caller account mid-graph bucket hits/misses. Without a
    ``bucketer`` the boundary output runs at its exact compacted shape (the
    one-shot ``execute_plan`` path). ``donate`` names env tables whose
    buffers are single-use (the serving layer's freshly padded fact spine)
    and may be aliased into stage outputs on accelerator backends.

    This serial runner blocks at every stage; the pipelined executor in
    :mod:`repro.exec.pipeline` runs the same stages — same jitted programs,
    same env structure — with device dispatch overlapped across request
    groups.
    """
    state: Optional[State] = None
    timings: list[float] = []
    for stage in graph.stages:
        t0 = time.perf_counter()
        if stage.kind == "pure":
            state = call_pure(stage, env, donate)
            jax.block_until_ready(state[:2])
            if stage.index == 0:
                env = strip_consumed(env, donate)
        else:
            state, env = host_step(
                stage, state, env,
                bucketer=bucketer, on_mid_bucket=on_mid_bucket,
            )
        dt = time.perf_counter() - t0
        stage.calls += 1
        stage.total_s += dt
        timings.append(dt)
    cols, valid, seg = state
    return RunResult(table=Table(columns=cols, valid=valid), seg=seg,
                     timings=timings)
