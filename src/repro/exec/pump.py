"""Latency-targeted background flushing for the serving layer.

The :class:`~repro.serve.query_server.PredictionQueryServer` is deliberately
synchronous — ``submit`` enqueues, ``flush`` drains — which makes tests
deterministic but forces every caller to drive ``db.flush()`` itself.
:class:`RequestPump` removes that requirement: a daemon thread watches the
pending queue and flushes when the *oldest* pending request has waited
``max_latency_ms``, so requests submitted close together coalesce into one
padded execution while no request waits longer than the latency target.

The pump owns no queue state of its own: ``notify(t_submit)`` arms a
deadline, the loop sleeps until it, and the flush callable (the server's
``flush``) does the actual draining. Explicit ``server.flush()`` calls remain
safe at any time — flushing is idempotent on an empty queue.

.. note:: The serving layer now schedules through
   :class:`repro.exec.scheduler.Scheduler` — per-query queues, deadlines,
   coalesce caps, and backpressure. ``RequestPump`` remains as the minimal
   single-deadline pump for embedders that drive one flush callable.
"""
from __future__ import annotations

import threading
import time
from typing import Callable


class RequestPump:
    """Background thread driving ``flush`` against a latency target."""

    def __init__(self, flush: Callable[[], list], max_latency_ms: float = 5.0):
        self._flush = flush
        self.max_latency_ms = float(max_latency_ms)
        self._cv = threading.Condition()
        self._deadline: float | None = None
        self._stopped = False
        self._thread: threading.Thread | None = None
        self.flushes = 0  # flushes this pump initiated
        self.last_error: BaseException | None = None  # most recent flush failure

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "RequestPump":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="raven-request-pump", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Stop the pump after draining anything already pending."""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._flush()  # drain stragglers deterministically

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- producer side -------------------------------------------------------

    def notify(self, t_submit: float | None = None) -> None:
        """Arm the flush deadline for a newly submitted request.

        The deadline tracks the oldest pending request: later submits never
        push it back, they just ride along in the same flush.
        """
        t = time.perf_counter() if t_submit is None else t_submit
        with self._cv:
            deadline = t + self.max_latency_ms / 1e3
            if self._deadline is None or deadline < self._deadline:
                self._deadline = deadline
            self._cv.notify_all()

    # -- the loop ------------------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._stopped and self._deadline is None:
                    self._cv.wait()
                if self._stopped:
                    return
                wait_s = self._deadline - time.perf_counter()
                if wait_s > 0:
                    self._cv.wait(wait_s)
                    continue  # re-check: stop/new earlier deadline may race
                self._deadline = None
            # count before running: waiters wake *inside* flush (their
            # request's event sets mid-drain), so counting after would let a
            # woken waiter observe flushes == 0 for the flush that served it
            self.flushes += 1
            try:
                self._flush()
            except BaseException as e:  # noqa: BLE001
                # the server already attached the error to the affected
                # requests (their wait() re-raises); the pump must survive a
                # bad batch or every later submit would hang forever
                self.last_error = e
