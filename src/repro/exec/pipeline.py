"""Pipelined StageGraph execution: overlap host and device work across groups.

The serial runner (:func:`repro.exec.stages.run_graph`) blocks at every
stage, so a plan with an MLUdf host boundary leaves the device idle while
numpy churns through the interpreted pipeline — and leaves the host idle
while XLA runs the pure stages. :class:`PipelineExecutor` runs the *same*
stages (same jitted programs, same env structure, so warm buckets stay warm)
as a pipeline over request groups:

  * **pure (device) stages dispatch asynchronously** on the calling thread —
    JAX's async dispatch enqueues the XLA computation and returns
    immediately, so the scheduler thread spends microseconds per stage and
    moves on to the next group;
  * **host boundaries run on a dedicated boundary pool**: the only point
    that must synchronize with the device (``np.asarray`` of the upstream
    state) happens on a worker thread, so group B's entry stages run on
    device while group A sits in its MLUdf boundary — and two UDF-heavy
    groups can occupy two workers at once (numpy releases the GIL in the
    kernels that matter);
  * a graph whose remaining stages are all pure completes inline on the
    dispatching thread — its future resolves immediately and the caller's
    result conversion provides the synchronization. This keeps small
    latency-sensitive pure queries out of the boundary pool's queue, so a
    large host-bound group can never sit in front of them.

The executor also owns the pipelining gauges (groups in flight, overlap
wall time, host-pool busy time) surfaced through ``db.cache_stats()``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Optional

from repro.exec.stages import (
    RunResult,
    StageGraph,
    State,
    call_pure,
    host_step,
    strip_consumed,
)
from repro.relational.table import Table


class PipelineExecutor:
    """Boundary thread pool + in-flight accounting for pipelined groups."""

    def __init__(self, workers: int = 2):
        self.workers = max(1, int(workers))
        self._pool: Optional[ThreadPoolExecutor] = None
        self._closed = False
        self._lock = threading.Lock()
        # gauges (all mutated under _lock)
        self.groups_in_flight = 0
        self.max_groups_in_flight = 0
        self.groups_started = 0
        self.overlapped_groups = 0  # groups that began while another ran
        self.overlap_s = 0.0        # wall time with >= 2 groups in flight
        self.host_busy_s = 0.0      # wall time spent inside host boundaries
        self._t_mark: float = 0.0

    @property
    def pool(self) -> ThreadPoolExecutor:
        """The boundary pool, created on first use.

        After :meth:`shutdown` the (shut-down) pool is returned as-is, so a
        straggling dispatch fails with the executor's RuntimeError instead
        of silently resurrecting a fresh pool nothing will ever shut down.
        """
        with self._lock:
            if self._pool is None and not self._closed:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix="raven-boundary",
                )
            if self._pool is None:
                raise RuntimeError("PipelineExecutor is shut down")
            return self._pool

    def shutdown(self) -> None:
        with self._lock:
            self._closed = True
            pool = self._pool
        if pool is not None:
            pool.shutdown(wait=False)

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return {
                "groups_in_flight": self.groups_in_flight,
                "max_groups_in_flight": self.max_groups_in_flight,
                "groups_started": self.groups_started,
                "overlapped_groups": self.overlapped_groups,
                "overlap_s": self.overlap_s,
                "host_busy_s": self.host_busy_s,
            }

    # -- in-flight / overlap accounting --------------------------------------

    def _accrue(self, now: float) -> None:
        # caller holds _lock; overlap accumulates only while >= 2 groups
        # were simultaneously in flight since the last transition
        if self.groups_in_flight >= 2:
            self.overlap_s += now - self._t_mark
        self._t_mark = now

    def _enter_group(self) -> None:
        with self._lock:
            now = time.perf_counter()
            self._accrue(now)
            if self.groups_in_flight >= 1:
                self.overlapped_groups += 1
            self.groups_in_flight += 1
            self.groups_started += 1
            self.max_groups_in_flight = max(
                self.max_groups_in_flight, self.groups_in_flight
            )

    def _exit_group(self) -> None:
        with self._lock:
            self._accrue(time.perf_counter())
            self.groups_in_flight -= 1

    # -- the pipelined walk ---------------------------------------------------

    def run_graph_async(
        self,
        graph: StageGraph,
        env: dict[str, Any],
        *,
        bucketer: Optional[Callable[[int], int]] = None,
        on_mid_bucket: Optional[Callable[[int, int], None]] = None,
        donate: frozenset = frozenset(),
    ) -> "Future[RunResult]":
        """Execute ``graph`` with host/device overlap; returns a future.

        Semantics are identical to :func:`repro.exec.stages.run_graph` — the
        same stage callables run over the same env structure — only the
        synchronization points move: pure stages are dispatched without
        waiting, and each host boundary (plus everything after it) runs on
        the boundary pool.
        """
        fut: Future = Future()
        self._enter_group()
        try:
            self._advance(graph, 0, None, env, bucketer, on_mid_bucket,
                          donate, [], fut)
        except BaseException as e:  # noqa: BLE001 — delivered via the future
            self._finish(fut, error=e)
        return fut

    def _advance(
        self,
        graph: StageGraph,
        start: int,
        state: Optional[State],
        env: dict[str, Any],
        bucketer,
        on_mid_bucket,
        donate: frozenset,
        timings: list[float],
        fut: Future,
    ) -> None:
        """Run stages from ``start`` on the current thread until the next
        host boundary (handed to the pool) or the end of the graph."""
        for i in range(start, len(graph.stages)):
            stage = graph.stages[i]
            t0 = time.perf_counter()
            if stage.kind == "pure":
                state = call_pure(stage, env, donate)
                dt = time.perf_counter() - t0
                if stage.index == 0:
                    env = strip_consumed(env, donate)
                with self._lock:
                    # async dispatch has no meaningful per-stage wall time
                    # (the device work overlaps other groups), so only the
                    # dispatch-side accounting moves — calls/total_s stay
                    # the serial runner's blocking-wall measure
                    stage.async_calls += 1
                    stage.dispatch_s += dt
                timings.append(dt)
                continue

            # host boundary: everything from here on runs on the pool, and
            # the dispatching thread returns to its scheduler loop
            def boundary(
                _stage=stage, _state=state, _env=env, _i=i,
            ) -> None:
                t1 = time.perf_counter()
                try:
                    new_state, new_env = host_step(
                        _stage, _state, _env,
                        bucketer=bucketer, on_mid_bucket=on_mid_bucket,
                    )
                except BaseException as e:  # noqa: BLE001
                    self._finish(fut, error=e)
                    return
                dt1 = time.perf_counter() - t1
                with self._lock:
                    _stage.calls += 1
                    _stage.total_s += dt1
                    _stage.async_calls += 1
                    _stage.dispatch_s += dt1
                    self.host_busy_s += dt1
                timings.append(dt1)
                try:
                    self._advance(graph, _i + 1, new_state, new_env,
                                  bucketer, on_mid_bucket, donate,
                                  timings, fut)
                except BaseException as e:  # noqa: BLE001
                    self._finish(fut, error=e)

            self.pool.submit(boundary)
            return

        cols, valid, seg = state
        self._finish(fut, result=RunResult(
            table=Table(columns=cols, valid=valid), seg=seg, timings=timings,
        ))

    def _finish(self, fut: Future, *, result=None, error=None) -> None:
        self._exit_group()
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
