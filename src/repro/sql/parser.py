"""PREDICT-statement SQL frontend (paper §6 syntax, TVF form).

Supported grammar (enough for the paper's query shapes — scan or multi-way
FK join, a PREDICT TVF, conjunctive predicates over inputs and outputs,
aggregates or column select):

    SELECT <item [, item ...]>
    FROM PREDICT(model = '<path-or-name>',
                 data = <table> [JOIN <table> ON <col> = <col>]*) AS <alias>
    [WHERE <col|alias.col> <op> <literal|:param> [AND ...]]

    item := COUNT(*) | SUM(col) | AVG(col) | MIN(col) | MAX(col)
          | col | alias.col | *
    op   := = | <> | != | < | <= | > | >=

``:name`` placeholders become :class:`~repro.relational.expr.Param` slots in
the IR: they hash by name (not value), so a prepared plan re-binds thresholds
without re-optimizing or changing its fingerprint.

Parsing is split in two stages shared with the session API's fluent builder:
``parse_spec`` produces a neutral :class:`QuerySpec`, and
``build_prediction_query`` lowers a spec to the unified IR — the builder
assembles the same spec, so both front doors yield fingerprint-identical
:class:`repro.core.ir.PredictionQuery` instances.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.core.ir import (
    LAggregate,
    LFilter,
    LJoin,
    LPredict,
    LScan,
    PredictionQuery,
    TableStats,
)
from repro.errors import (
    SQLSyntaxError,
    UnknownColumnError,
    UnknownModelError,
    UnknownTableError,
)
from repro.relational.expr import Bin, Col, Const, Expr, Param

_TOKEN = re.compile(
    r"\s*(?:(?P<str>'[^']*')|(?P<num>-?\d+\.?\d*(?:[eE][-+]?\d+)?)"
    r"|(?P<param>:[A-Za-z_][A-Za-z_0-9]*)"
    r"|(?P<op><=|>=|<>|!=|=|<|>|\(|\)|,|\*|\.)"
    r"|(?P<word>[A-Za-z_][A-Za-z_0-9]*))"
)

_OPMAP = {
    "=": "eq", "<": "lt", "<=": "le", ">": "gt", ">=": "ge",
    "<>": "ne", "!=": "ne",
}

_AGGMAP = {
    "COUNT": "count", "SUM": "sum", "AVG": "mean",
    "MIN": "min", "MAX": "max",
}


def canonical_op(op: str) -> str:
    """Normalize a comparison operator (symbol or canonical name)."""
    if op in _OPMAP:
        return _OPMAP[op]
    if op in _OPMAP.values():
        return op
    raise SQLSyntaxError(
        f"unknown comparison operator {op!r} — expected one of "
        f"{sorted(_OPMAP)} or {sorted(set(_OPMAP.values()))}"
    )


def _tokenize(sql: str) -> list[str]:
    tokens, pos = [], 0
    while pos < len(sql):
        m = _TOKEN.match(sql, pos)
        if not m:
            if sql[pos:].strip() == "":
                break
            raise SQLSyntaxError(f"bad token at: {sql[pos:pos+20]!r}")
        tokens.append(m.group(0).strip())
        pos = m.end()
    return tokens


class _Parser:
    def __init__(self, tokens: list[str]):
        self.toks = tokens
        self.i = 0

    def peek(self) -> str:
        return self.toks[self.i] if self.i < len(self.toks) else ""

    def next(self) -> str:
        t = self.peek()
        self.i += 1
        return t

    def expect(self, word: str) -> str:
        t = self.next()
        if t.upper() != word.upper():
            raise SQLSyntaxError(
                f"expected {word}, got {t!r}" if t else f"expected {word}, "
                "got end of query"
            )
        return t


# ---------------------------------------------------------------------------
# Stage 1: text -> QuerySpec (shared target with the fluent builder)
# ---------------------------------------------------------------------------


@dataclass
class QuerySpec:
    """Neutral description of one prediction query.

    Both front doors (SQL text and the fluent builder) lower to this, and
    :func:`build_prediction_query` is the single spec -> IR path — which is
    what makes their IR (and hence plan fingerprints) identical.
    """

    items: list[tuple[str, str]] = field(default_factory=list)  # (kind, arg)
    model: str | None = None
    base: str | None = None
    joins: list[tuple[str, str, str]] = field(default_factory=list)
    preds: list[tuple[str, str, Expr]] = field(default_factory=list)


def parse_select_items(text_or_parser) -> list[tuple[str, str]]:
    """Parse a SELECT item list: ``COUNT(*), AVG(score), col, t.col, *``."""
    p = (
        text_or_parser
        if isinstance(text_or_parser, _Parser)
        else _Parser(_tokenize(text_or_parser))
    )
    items: list[tuple[str, str]] = []
    while True:
        t = p.next()
        if not t:
            raise SQLSyntaxError("expected a select item, got end of input")
        u = t.upper()
        if u in _AGGMAP:
            p.expect("(")
            arg = p.next()
            p.expect(")")
            items.append((_AGGMAP[u], arg))
        elif t == "*":
            items.append(("star", "*"))
        else:
            # col or alias.col
            if p.peek() == ".":
                p.next()
                col = p.next()
                items.append(("col", col))
            else:
                items.append(("col", t))
        if p.peek() == ",":
            p.next()
            continue
        break
    return items


def parse_condition(text_or_parser, alias: str | None = None) -> tuple[str, str, Expr]:
    """Parse one ``col <op> literal|:param`` comparison."""
    p = (
        text_or_parser
        if isinstance(text_or_parser, _Parser)
        else _Parser(_tokenize(text_or_parser))
    )
    col = _qualcol(p, alias)
    op = p.next()
    if op not in _OPMAP:
        raise SQLSyntaxError(f"expected a comparison operator, got {op!r}")
    lit = p.next()
    if not lit:
        raise SQLSyntaxError(f"expected a literal or :param after {op!r}")
    return col, _OPMAP[op], _value_expr(lit)


def _value_expr(lit: str) -> Expr:
    if lit.startswith(":"):
        return Param(lit[1:])
    if lit.startswith("'"):
        return Const(lit.strip("'"))
    try:
        return Const(float(lit))
    except ValueError:
        raise SQLSyntaxError(
            f"expected a literal or :param, got {lit!r}"
        ) from None


def parse_spec(sql: str) -> QuerySpec:
    """Parse PREDICT-statement SQL text into a :class:`QuerySpec`."""
    p = _Parser(_tokenize(sql))
    p.expect("SELECT")
    spec = QuerySpec(items=parse_select_items(p))

    p.expect("FROM")
    p.expect("PREDICT")
    p.expect("(")
    p.expect("model")
    p.expect("=")
    spec.model = p.next().strip("'")
    p.expect(",")
    p.expect("data")
    p.expect("=")
    spec.base = p.next()
    if not spec.base:
        raise SQLSyntaxError("PREDICT clause is missing the data= table")
    while p.peek().upper() == "JOIN":
        p.next()
        dim = p.next()
        p.expect("ON")
        a = _qualcol(p)
        p.expect("=")
        b = _qualcol(p)
        spec.joins.append((dim, a, b))
    p.expect(")")
    alias = None
    if p.peek().upper() == "AS":
        p.next()
        alias = p.next()

    if p.peek().upper() == "WHERE":
        p.next()
        while True:
            spec.preds.append(parse_condition(p, alias))
            if p.peek().upper() == "AND":
                p.next()
                continue
            break
    if p.peek():
        raise SQLSyntaxError(f"unexpected trailing token {p.peek()!r}")
    return spec


def _qualcol(p: _Parser, alias: str | None = None) -> str:
    a = p.next()
    if p.peek() == ".":
        p.next()
        return p.next()
    return a


# ---------------------------------------------------------------------------
# Stage 2: QuerySpec -> unified IR
# ---------------------------------------------------------------------------


def build_prediction_query(
    spec: QuerySpec,
    models: dict,
    database: dict,
    stats: dict[str, TableStats] | None = None,
) -> PredictionQuery:
    """Lower a :class:`QuerySpec` to a :class:`PredictionQuery` (unified IR)."""
    if spec.model is None:
        raise SQLSyntaxError("query has no PREDICT(model=..., data=...) clause")
    if spec.base is None:
        raise SQLSyntaxError("PREDICT clause names no data= table")
    if spec.model not in models:
        raise UnknownModelError(
            f"unknown model '{spec.model}' — registered models: "
            f"{sorted(map(str, models)) or '(none)'}"
        )
    if spec.base not in database:
        raise UnknownTableError(
            f"unknown table '{spec.base}' — known tables: {sorted(database)}"
        )

    pipeline = models[spec.model]
    if isinstance(pipeline, str):
        from repro.ml.pipeline import load_pipeline

        pipeline = load_pipeline(pipeline)
    out_names = ["score", "pred"][: len(pipeline.outputs)]

    known_cols = set(database[spec.base])
    plan = LScan(spec.base, list(database[spec.base].keys()))
    for dim, a, b in spec.joins:
        if dim not in database:
            raise UnknownTableError(
                f"unknown join table '{dim}' — known tables: {sorted(database)}"
            )
        if b in database[dim]:
            fact_key, dim_key = a, b
        elif a in database[dim]:
            fact_key, dim_key = b, a
        else:
            raise UnknownColumnError(
                f"join key {a!r}={b!r}: neither side is a column of '{dim}'"
            )
        dim_cols = [c for c in database[dim] if c != dim_key]
        known_cols |= set(database[dim])
        plan = LJoin(plan, dim, fact_key, dim_key, dim_cols)

    for col, _op, _v in spec.preds:
        if col not in known_cols and col not in out_names:
            raise UnknownColumnError(
                f"predicate column '{col}' is neither a table column nor a "
                f"model output {out_names}"
            )

    input_preds = [x for x in spec.preds if x[0] not in out_names]
    output_preds = [x for x in spec.preds if x[0] in out_names]
    for col, op, v in input_preds:
        plan = LFilter(plan, Bin(op, Col(col), v))
    plan = LPredict(plan, pipeline.copy(), out_names)
    for col, op, v in output_preds:
        plan = LFilter(plan, Bin(op, Col(col), v))

    aggs = [
        (f"{kind}_{arg if arg != '*' else 'rows'}", kind, arg)
        for kind, arg in spec.items
        if kind in ("count", "sum", "mean", "min", "max")
    ]
    if aggs:
        # COUNT(*) needs a concrete column: use the first predict output
        aggs = [
            (name, kind, out_names[-1] if arg == "*" else arg)
            for (name, kind, arg) in aggs
        ]
        plan = LAggregate(plan, aggs)

    return PredictionQuery(plan=plan, stats=stats or {})


def parse_prediction_query(
    sql: str,
    models: dict,
    database: dict,
    stats: dict[str, TableStats] | None = None,
    fact: str | None = None,
) -> PredictionQuery:
    """One-call convenience: SQL text -> unified IR."""
    return build_prediction_query(parse_spec(sql), models, database, stats)
