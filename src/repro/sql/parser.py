"""PREDICT-statement SQL frontend (paper §6 syntax, TVF form).

Supported grammar (enough for the paper's query shapes — scan or multi-way
FK join, a PREDICT TVF, conjunctive predicates over inputs and outputs,
aggregates or column select):

    SELECT <item [, item ...]>
    FROM PREDICT(model = '<path-or-name>',
                 data = <table> [JOIN <table> ON <col> = <col>]*) AS <alias>
    [WHERE <col|alias.col> <op> <literal> [AND ...]]

    item := COUNT(*) | SUM(col) | AVG(col) | col | alias.col | *

Produces a :class:`repro.core.ir.PredictionQuery` over a model registry
(name -> TrainedPipeline) and a database (name -> columns).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.core.ir import (
    LAggregate,
    LFilter,
    LJoin,
    LPredict,
    LScan,
    PredictionQuery,
    TableStats,
)
from repro.relational.expr import Bin, Col, Const

_TOKEN = re.compile(
    r"\s*(?:(?P<str>'[^']*')|(?P<num>-?\d+\.?\d*(?:[eE][-+]?\d+)?)"
    r"|(?P<op><=|>=|<>|!=|=|<|>|\(|\)|,|\*|\.)"
    r"|(?P<word>[A-Za-z_][A-Za-z_0-9]*))"
)

_OPMAP = {"=": "eq", "<": "lt", "<=": "le", ">": "gt", ">=": "ge"}


def _tokenize(sql: str) -> list[str]:
    tokens, pos = [], 0
    while pos < len(sql):
        m = _TOKEN.match(sql, pos)
        if not m:
            if sql[pos:].strip() == "":
                break
            raise SyntaxError(f"bad token at: {sql[pos:pos+20]!r}")
        tokens.append(m.group(0).strip())
        pos = m.end()
    return tokens


class _Parser:
    def __init__(self, tokens: list[str]):
        self.toks = tokens
        self.i = 0

    def peek(self) -> str:
        return self.toks[self.i] if self.i < len(self.toks) else ""

    def next(self) -> str:
        t = self.peek()
        self.i += 1
        return t

    def expect(self, word: str) -> str:
        t = self.next()
        if t.upper() != word.upper():
            raise SyntaxError(f"expected {word}, got {t!r}")
        return t


def parse_prediction_query(
    sql: str,
    models: dict,
    database: dict,
    stats: dict[str, TableStats] | None = None,
    fact: str | None = None,
) -> PredictionQuery:
    p = _Parser(_tokenize(sql))
    p.expect("SELECT")

    items: list[tuple[str, str]] = []  # (kind, arg)
    while True:
        t = p.next()
        u = t.upper()
        if u in ("COUNT", "SUM", "AVG"):
            p.expect("(")
            arg = p.next()
            p.expect(")")
            items.append(({"COUNT": "count", "SUM": "sum", "AVG": "mean"}[u], arg))
        elif t == "*":
            items.append(("star", "*"))
        else:
            # col or alias.col
            if p.peek() == ".":
                p.next()
                col = p.next()
                items.append(("col", col))
            else:
                items.append(("col", t))
        if p.peek() == ",":
            p.next()
            continue
        break

    p.expect("FROM")
    p.expect("PREDICT")
    p.expect("(")
    p.expect("model")
    p.expect("=")
    model_name = p.next().strip("'")
    p.expect(",")
    p.expect("data")
    p.expect("=")
    base_table = p.next()
    joins: list[tuple[str, str, str]] = []
    while p.peek().upper() == "JOIN":
        p.next()
        dim = p.next()
        p.expect("ON")
        a = _qualcol(p)
        p.expect("=")
        b = _qualcol(p)
        joins.append((dim, a, b))
    p.expect(")")
    alias = None
    if p.peek().upper() == "AS":
        p.next()
        alias = p.next()

    preds: list[tuple[str, str, float]] = []
    if p.peek().upper() == "WHERE":
        p.next()
        while True:
            col = _qualcol(p, alias)
            op = p.next()
            lit = p.next()
            value = float(lit.strip("'")) if not lit.startswith("'") else lit.strip("'")
            preds.append((col, _OPMAP[op], value))
            if p.peek().upper() == "AND":
                p.next()
                continue
            break

    # ---- build the unified IR ----------------------------------------------
    pipeline = models[model_name]
    if isinstance(pipeline, str):
        from repro.ml.pipeline import load_pipeline

        pipeline = load_pipeline(pipeline)
    out_names = ["score", "pred"][: len(pipeline.outputs)]

    plan = LScan(base_table, list(database[base_table].keys()))
    for dim, a, b in joins:
        fact_key, dim_key = (a, b) if b in database[dim] else (b, a)
        dim_cols = [c for c in database[dim] if c != dim_key]
        plan = LJoin(plan, dim, fact_key, dim_key, dim_cols)

    input_preds = [x for x in preds if x[0] not in out_names]
    output_preds = [x for x in preds if x[0] in out_names]
    for col, op, v in input_preds:
        plan = LFilter(plan, Bin(op, Col(col), Const(v)))
    plan = LPredict(plan, pipeline.copy(), out_names)
    for col, op, v in output_preds:
        plan = LFilter(plan, Bin(op, Col(col), Const(v)))

    aggs = [
        (f"{kind}_{arg if arg != '*' else 'rows'}", kind, arg)
        for kind, arg in items
        if kind in ("count", "sum", "mean")
    ]
    if aggs:
        # COUNT(*) needs a concrete column: use the first predict output
        aggs = [
            (name, kind, out_names[-1] if arg == "*" else arg)
            for (name, kind, arg) in aggs
        ]
        plan = LAggregate(plan, aggs)

    return PredictionQuery(plan=plan, stats=stats or {})


def _qualcol(p: _Parser, alias: str | None = None) -> str:
    a = p.next()
    if p.peek() == ".":
        p.next()
        return p.next()
    return a
