from repro.sql.parser import parse_prediction_query
