"""Columnar table with validity mask.

Filters never compact (mask-only, branch-free — the vectorized-engine idiom);
compaction happens only at host boundaries or final output.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass
class Table:
    columns: dict[str, jnp.ndarray]
    valid: jnp.ndarray  # bool (n,)

    @staticmethod
    def from_numpy(cols: dict[str, np.ndarray]) -> "Table":
        n = len(next(iter(cols.values())))
        return Table(
            columns={k: jnp.asarray(v) for k, v in cols.items()},
            valid=jnp.ones(n, dtype=bool),
        )

    @property
    def n_rows(self) -> int:
        return int(self.valid.shape[0])

    def to_numpy(self, compact: bool = True) -> dict[str, np.ndarray]:
        mask = np.asarray(self.valid)
        out = {}
        for k, v in self.columns.items():
            a = np.asarray(v)
            out[k] = a[mask] if compact else a
        return out
