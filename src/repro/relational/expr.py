"""Scalar expression trees over columns — the engine's "SQL expressions".

MLtoSQL compiles models into these (trees → nested ``Case``; linear models →
mul/add chains), so expression evaluation must scale to tens of thousands of
nodes without hitting Python recursion limits: evaluation is an explicit-stack
post-order walk producing pure jnp ops (trace-once under jit).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Union

import jax.numpy as jnp

Num = Union[int, float, bool]


class Expr:
    __slots__ = ()

    # sugar for rule-writers / tests
    def __add__(self, o): return Bin("add", self, _wrap(o))
    def __sub__(self, o): return Bin("sub", self, _wrap(o))
    def __mul__(self, o): return Bin("mul", self, _wrap(o))
    def __le__(self, o): return Bin("le", self, _wrap(o))
    def __lt__(self, o): return Bin("lt", self, _wrap(o))
    def __ge__(self, o): return Bin("ge", self, _wrap(o))
    def __gt__(self, o): return Bin("gt", self, _wrap(o))

    def eq(self, o): return Bin("eq", self, _wrap(o))
    def and_(self, o): return Bin("and", self, _wrap(o))
    def or_(self, o): return Bin("or", self, _wrap(o))


def _wrap(v) -> "Expr":
    return v if isinstance(v, Expr) else Const(v)


@dataclass(frozen=True)
class Col(Expr):
    name: str


@dataclass(frozen=True)
class Const(Expr):
    value: Any


@dataclass(frozen=True)
class Param(Expr):
    """Named query parameter (a ``:name`` placeholder).

    Hashes by *name*, not value: the bound value rides into the compiled
    program through the execution environment (a 0-d array), so re-binding a
    parameter changes neither the plan fingerprint nor the traced XLA program.
    """

    name: str


@dataclass(frozen=True)
class Bin(Expr):
    op: str  # add sub mul div le lt ge gt eq ne and or min max
    a: Expr
    b: Expr


@dataclass(frozen=True)
class Un(Expr):
    """Unary scalar function (SQL's EXP/SQRT/... family)."""

    op: str  # neg abs exp log sqrt sigmoid
    a: Expr


@dataclass(frozen=True)
class Case(Expr):
    """CASE WHEN cond THEN then ELSE orelse END."""

    cond: Expr
    then: Expr
    orelse: Expr


_UN = {
    "neg": jnp.negative,
    "abs": jnp.abs,
    "exp": jnp.exp,
    "log": jnp.log,
    "sqrt": jnp.sqrt,
    "sigmoid": lambda x: 1.0 / (1.0 + jnp.exp(-x)),
    # inverse sigmoid, clipped like the optimizer's static threshold rewrite
    # so prob-space parameters survive the logit-space filter rewrite
    "logit": lambda x: (lambda p: jnp.log(p / (1.0 - p)))(
        jnp.clip(x, 1e-9, 1.0 - 1e-9)
    ),
}

_BIN = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "le": jnp.less_equal,
    "lt": jnp.less,
    "ge": jnp.greater_equal,
    "gt": jnp.greater,
    "eq": jnp.equal,
    "ne": jnp.not_equal,
    "and": jnp.logical_and,
    "or": jnp.logical_or,
    "min": jnp.minimum,
    "max": jnp.maximum,
}


def eval_expr(
    expr: Expr,
    env: dict[str, jnp.ndarray],
    params: dict[str, jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """Iterative post-order evaluation (no recursion limit)."""
    out: dict[int, jnp.ndarray] = {}
    stack: list[tuple[Expr, bool]] = [(expr, False)]
    while stack:
        node, visited = stack.pop()
        nid = id(node)
        if nid in out:
            continue
        if isinstance(node, Col):
            out[nid] = env[node.name]
        elif isinstance(node, Param):
            if params is None or node.name not in params:
                from repro.errors import UnboundParameterError

                raise UnboundParameterError(
                    f"parameter :{node.name} is unbound — pass it via "
                    f"params={{'{node.name}': value}}"
                )
            out[nid] = jnp.asarray(params[node.name])
        elif isinstance(node, Const):
            out[nid] = jnp.asarray(node.value)
        elif visited:
            if isinstance(node, Bin):
                out[nid] = _BIN[node.op](out[id(node.a)], out[id(node.b)])
            elif isinstance(node, Un):
                out[nid] = _UN[node.op](out[id(node.a)])
            else:  # Case
                out[nid] = jnp.where(
                    out[id(node.cond)], out[id(node.then)], out[id(node.orelse)]
                )
        else:
            stack.append((node, True))
            if isinstance(node, Bin):
                stack.append((node.a, False))
                stack.append((node.b, False))
            elif isinstance(node, Un):
                stack.append((node.a, False))
            elif isinstance(node, Case):
                stack.append((node.cond, False))
                stack.append((node.then, False))
                stack.append((node.orelse, False))
            else:
                raise TypeError(type(node))
    return out[id(expr)]


def expr_size(expr: Expr) -> int:
    """Node count (shared subtrees counted once) — drives the strategy stats."""
    seen: set[int] = set()
    stack = [expr]
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        if isinstance(node, Bin):
            stack.extend([node.a, node.b])
        elif isinstance(node, Un):
            stack.append(node.a)
        elif isinstance(node, Case):
            stack.extend([node.cond, node.then, node.orelse])
    return len(seen)


def columns_of(expr: Expr) -> set[str]:
    cols: set[str] = set()
    seen: set[int] = set()
    stack = [expr]
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        if isinstance(node, Col):
            cols.add(node.name)
        elif isinstance(node, Bin):
            stack.extend([node.a, node.b])
        elif isinstance(node, Un):
            stack.append(node.a)
        elif isinstance(node, Case):
            stack.extend([node.cond, node.then, node.orelse])
    return cols


def params_of(expr: Expr) -> set[str]:
    """Names of all :class:`Param` placeholders reachable from ``expr``."""
    names: set[str] = set()
    seen: set[int] = set()
    stack = [expr]
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        if isinstance(node, Param):
            names.add(node.name)
        elif isinstance(node, Bin):
            stack.extend([node.a, node.b])
        elif isinstance(node, Un):
            stack.append(node.a)
        elif isinstance(node, Case):
            stack.extend([node.cond, node.then, node.orelse])
    return names


_OP_SYMBOL = {
    "add": "+", "sub": "-", "mul": "*", "div": "/",
    "le": "<=", "lt": "<", "ge": ">=", "gt": ">",
    "eq": "=", "ne": "<>", "and": "AND", "or": "OR",
    "min": "MIN", "max": "MAX",
}


def format_expr(expr: Expr, max_nodes: int = 24) -> str:
    """Compact SQL-ish rendering for EXPLAIN output.

    MLtoSQL emits expressions with tens of thousands of nodes; those are
    summarized as ``<N-node expr over (cols)>`` instead of being printed
    (also keeps the recursive pretty-printer off the deep trees).
    """
    n = expr_size(expr)
    if n > max_nodes:
        cols = sorted(columns_of(expr))
        more = "" if len(cols) <= 6 else ", …"
        return f"<{n}-node expr over ({', '.join(cols[:6])}{more})>"

    def fmt(e: Expr) -> str:
        if isinstance(e, Col):
            return e.name
        if isinstance(e, Param):
            return f":{e.name}"
        if isinstance(e, Const):
            v = e.value
            return f"{v:g}" if isinstance(v, float) else repr(v)
        if isinstance(e, Bin):
            sym = _OP_SYMBOL.get(e.op, e.op)
            if sym in ("MIN", "MAX"):
                return f"{sym}({fmt(e.a)}, {fmt(e.b)})"
            return f"({fmt(e.a)} {sym} {fmt(e.b)})"
        if isinstance(e, Un):
            return f"{e.op}({fmt(e.a)})"
        if isinstance(e, Case):
            return (
                f"CASE WHEN {fmt(e.cond)} THEN {fmt(e.then)} "
                f"ELSE {fmt(e.orelse)} END"
            )
        raise TypeError(type(e))

    return fmt(expr)
