"""Physical plans + execution for the columnar JAX data engine.

A plan is a tree of operators over a database (dict of named column-dicts).
Lowering splits the plan at host boundaries (``MLUdf``) into a
:class:`~repro.exec.stages.StageGraph`: maximal pure-jnp segments are jitted
as single XLA programs (so an MLtoSQL-compiled model fuses with the
scans/joins/filters around it — the whole point of the optimization), while
MLUdf stages run interpreted numpy on host with batch-at-a-time dispatch (the
Spark→Python-UDF→ML-runtime boundary, including its conversion and per-batch
overheads). The stage graph is a first-class IR — declarative,
schema-carrying, per-stage fingerprinted — built by :mod:`repro.exec.stages`;
this module owns the plan-node definitions, the jit/trace accounting, and the
fingerprint-keyed compiled-plan cache on top of it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.exec.faults import maybe_inject
from repro.relational.expr import Expr
from repro.relational.table import Table

# ---------------------------------------------------------------------------
# Plan nodes
# ---------------------------------------------------------------------------


@dataclass
class Scan:
    table: str
    columns: list[str]  # columns actually read (projection pushdown target)


@dataclass
class Join:
    """Foreign-key join: gather dim columns onto the fact spine."""

    child: "PhysicalPlan"
    dim_table: str
    fact_key: str
    dim_key: str
    dim_columns: list[str]  # dim columns to bring in (pushdown target)


@dataclass
class Filter:
    child: "PhysicalPlan"
    expr: Expr


@dataclass
class Project:
    child: "PhysicalPlan"
    keep: Optional[list[str]]  # None -> pass all child columns through
    exprs: dict[str, Expr] = field(default_factory=dict)


@dataclass
class MLUdf:
    """Host-boundary pipeline invocation (interpreted 'ML runtime')."""

    child: "PhysicalPlan"
    pipeline: Any  # TrainedPipeline
    output_names: list[str]  # graph outputs -> column names
    batch_size: int = 10_000
    # upstream block columns (split-lowering cut values) this node is the
    # last consumer of — dropped from its output schema
    consumes: tuple[str, ...] = ()


@dataclass
class TensorOp:
    """Fused tensor program (MLtoDNN output). ``fn(cols)->cols`` is jittable."""

    child: "PhysicalPlan"
    fn: Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]
    output_names: list[str]
    # upstream block columns this node is the last consumer of (see MLUdf)
    consumes: tuple[str, ...] = ()


@dataclass
class Aggregate:
    child: "PhysicalPlan"
    aggs: list[tuple[str, str, str]]  # (out_name, op{sum,count,mean,min,max}, col)


PhysicalPlan = Union[Scan, Join, Filter, Project, MLUdf, TensorOp, Aggregate]


def plan_children(p: PhysicalPlan) -> list[PhysicalPlan]:
    return [] if isinstance(p, Scan) else [p.child]


def walk_plan(p: PhysicalPlan):
    yield p
    for c in plan_children(p):
        yield from walk_plan(c)


# ---------------------------------------------------------------------------
# Lowering: plan -> StageGraph (repro.exec.stages)
# ---------------------------------------------------------------------------

from repro.exec.stages import (  # noqa: E402  (plan nodes must exist first)
    DIMSORT_KEY,
    PARAMS_KEY,
    ROW_SEG_KEY,
    ROW_VALID_KEY,
    SEG_COUNT_KEY,
    SEG_SLOTS_KEY,
    VOLATILE_KEYS,
    RunResult,
    StageGraph,
    build_stage_graph,
    donation_enabled,
    run_graph,
    seg_bucket,
)


def plan_fingerprint(plan: PhysicalPlan, pins: Optional[list] = None) -> str:
    """Canonical content hash of a physical plan.

    Structurally identical plans (same operators, expressions, pipeline
    weights) hash equal, so compiled artifacts are reusable across plan
    objects. Opaque callables (``TensorOp.fn``) hash by identity and are
    reported via ``pins``; the compiled-plan cache keeps those alive so a
    fingerprint can never alias a dead closure's recycled id.

    Plans containing Join/Aggregate ops additionally fold in the
    ``RAVEN_KERNELS`` mode token: the mode changes the stage programs those
    plans lower to, so a CompiledPlan cached under one mode must never be
    served under the other.
    """
    from repro.core.fingerprint import fingerprint
    from repro.kernels.ops import kernel_mode_token

    extra = (
        [kernel_mode_token()]
        if any(isinstance(p, (Join, Aggregate)) for p in walk_plan(plan))
        else []
    )
    return fingerprint(plan, *extra, pins=pins)


@dataclass
class CacheStats:
    """Module-level compiled-plan cache accounting.

    ``traces`` counts XLA stage tracings across all entries; ``stage_traces``
    breaks the same count down per stage fingerprint, so callers (and
    ``db.cache_stats()`` on the session) can assert zero-retrace warm paths
    for a *specific* stage — e.g. the post-UDF pure stage of a host-boundary
    plan — without reaching into compiled-plan internals.
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    traces: int = 0  # XLA (re)compiles: stage tracings across all entries
    stage_traces: dict[str, int] = field(default_factory=dict)
    disk_hits: int = 0    # artifact-store loads that skipped work: a persisted
    disk_misses: int = 0  # plan or an AOT-exported stage program (vs not found)

    def snapshot(self) -> dict[str, Any]:
        return {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "traces": self.traces,
            "stage_traces": dict(self.stage_traces),
            "disk_hits": self.disk_hits, "disk_misses": self.disk_misses,
        }


PLAN_CACHE_STATS = CacheStats()
_PLAN_CACHE: "dict[str, CompiledPlan]" = {}  # insertion-ordered: LRU via re-insert
PLAN_CACHE_CAPACITY = 64


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _DIMSORT_CACHE.clear()
    PLAN_CACHE_STATS.hits = PLAN_CACHE_STATS.misses = 0
    PLAN_CACHE_STATS.evictions = PLAN_CACHE_STATS.traces = 0
    PLAN_CACHE_STATS.disk_hits = PLAN_CACHE_STATS.disk_misses = 0
    PLAN_CACHE_STATS.stage_traces.clear()


# -- baked dim-table sort orders ---------------------------------------------
# Dim tables are frozen at registration, so the Join stage's sorted key
# order is a pure function of the key column's *content*. Baking it here (on
# the host, once per distinct key column) removes the per-call argsort from
# the traced stage; the cache is content-keyed — array identity is useless
# because callers re-wrap numpy tables into fresh jnp arrays per call — and
# bounded. Entries carry a zero-length "unique" marker array when the keys
# are duplicate-free: its *presence in the pytree structure* is what lets
# the traced Join step decide at trace time that the one-hot-matmul kernel
# gather is exact (see tensor.compile.join_kernel_qualifies).

_DIMSORT_CACHE: dict[tuple, dict[str, jnp.ndarray]] = {}
_DIMSORT_CAPACITY = 128


def dimsort_entry(keys) -> dict[str, jnp.ndarray]:
    """Baked sort data for one dim-key column: ``keys`` sorted, the stable
    argsort permutation (matching ``jnp.argsort``'s stable order, so the
    baked and in-trace fallback paths gather identical rows even with
    duplicate keys), and the uniqueness marker."""
    import hashlib

    nk = np.ascontiguousarray(np.asarray(keys))
    key = (str(nk.dtype), nk.shape, hashlib.sha1(nk.tobytes()).hexdigest())
    hit = _DIMSORT_CACHE.get(key)
    if hit is not None:
        return hit
    order = np.argsort(nk, kind="stable")
    sk = nk[order]
    entry = {
        "keys": jnp.asarray(sk),
        "order": jnp.asarray(order.astype(np.int32)),
    }
    if sk.size == 0 or not np.any(sk[1:] == sk[:-1]):
        entry["unique"] = jnp.zeros((0,), jnp.int32)
    if len(_DIMSORT_CACHE) >= _DIMSORT_CAPACITY:
        _DIMSORT_CACHE.pop(next(iter(_DIMSORT_CACHE)))
    _DIMSORT_CACHE[key] = entry
    return entry


# The process-wide artifact store (disk tier under the in-memory LRU above).
# ``raven.connect(cache_dir=...)`` installs one; stage runners consult it at
# bucket-compile time, so even CompiledPlans already resident in the LRU pick
# up (or populate) the disk tier of whichever store is active.
_ARTIFACT_STORE: Optional[Any] = None


def set_artifact_store(store: Optional[Any]) -> Optional[Any]:
    """Install (or clear, with None) the process-wide artifact store;
    returns the previous one."""
    global _ARTIFACT_STORE
    prev, _ARTIFACT_STORE = _ARTIFACT_STORE, store
    return prev


def get_artifact_store() -> Optional[Any]:
    return _ARTIFACT_STORE


@dataclass
class CompiledPlan:
    """Reusable compiled artifact for one physical plan.

    Wraps the lowered :class:`~repro.exec.stages.StageGraph`: pure stages
    carry jitted executables (jit specializes per input shape bucket
    internally; ``traces`` counts those specializations — i.e. actual XLA
    compiles). ``pins`` keeps identity-hashed plan components alive while
    this entry can be looked up.
    """

    fingerprint: str
    graph: StageGraph
    pins: list = field(default_factory=list)

    @property
    def stages(self) -> list:
        return self.graph.stages

    @property
    def n_stages(self) -> int:
        return len(self.graph.stages)

    @property
    def is_pure(self) -> bool:
        """One jitted XLA program, no host boundary (MLtoSQL/MLtoDNN output)."""
        return self.graph.is_pure

    @property
    def traces(self) -> int:
        """XLA stage tracings attributable to this compiled plan."""
        return self.graph.traces

    @property
    def specializations(self) -> int:
        """Distinct per-stage bucket programs this plan holds, however they
        arrived (fresh XLA traces *or* AOT disk loads). ``traces`` alone
        undercounts warm coverage when the artifact store preloaded shapes;
        the registry's warm gate compares this before/after a cutover."""
        return sum(
            st.traces + st.disk_loads for st in self.graph.stages
            if st.kind == "pure"
        )

    def warm_start(self, store: Optional[Any] = None) -> int:
        """Preload every on-disk exported program for this plan's stages.

        Enumerates the active artifact store's entries under each pure
        stage's chained fingerprint and deserializes them eagerly, so the
        first request landing on a previously-served bucket shape runs the
        AOT artifact instead of tracing. Returns the number of bucket
        programs loaded.
        """
        store = store if store is not None else get_artifact_store()
        if store is None:
            return 0
        n = 0
        for stage in self.graph.stages:
            if isinstance(stage.runner, _StageRunner):
                n += stage.runner.preload(store)
        return n

    def _env(
        self,
        database: dict[str, dict[str, jnp.ndarray]],
        row_valid: Optional[jnp.ndarray],
        params: Optional[dict[str, Any]],
        segments: Optional[tuple[np.ndarray, int]],
    ) -> dict[str, Any]:
        """Build the execution environment shared by the serial runner and
        the pipelined executor — one construction path, so both execute the
        exact same jit specializations."""
        env: dict[str, Any] = dict(database)
        if row_valid is not None:
            env[ROW_VALID_KEY] = jnp.asarray(row_valid, dtype=bool)
        if params:
            # float32 0-d arrays: a fresh bound value is a same-shape input
            # to the jitted stages, so re-binding never re-traces
            env[PARAMS_KEY] = {
                k: jnp.asarray(v, dtype=jnp.float32) for k, v in params.items()
            }
        if segments is not None:
            seg_ids, count = segments
            # slot count is power-of-two bucketed so segmented aggregates
            # trace per bucket, not per coalesce width; the real request
            # count rides in as a runtime scalar
            ns = seg_bucket(count)
            env[ROW_SEG_KEY] = jnp.asarray(seg_ids, dtype=jnp.int32)
            env[SEG_SLOTS_KEY] = jnp.arange(ns, dtype=jnp.int32)
            env[SEG_COUNT_KEY] = jnp.asarray(count, dtype=jnp.int32)
        ds: dict[str, dict[str, jnp.ndarray]] = {}
        for p in walk_plan(self.graph.plan):
            if isinstance(p, Join):
                tab = database.get(p.dim_table)
                if tab is not None and p.dim_key in tab:
                    ds[p.dim_table] = dimsort_entry(tab[p.dim_key])
        if ds:
            env[DIMSORT_KEY] = ds
        return env

    def run(
        self,
        database: dict[str, dict[str, jnp.ndarray]],
        row_valid: Optional[jnp.ndarray] = None,
        params: Optional[dict[str, Any]] = None,
        segments: Optional[tuple[np.ndarray, int]] = None,
        bucketer: Optional[Callable[[int], int]] = None,
        on_mid_bucket: Optional[Callable[[int, int], None]] = None,
        donate: frozenset = frozenset(),
    ) -> RunResult:
        """Execute the stage graph; the full-fidelity serving entry point.

        ``segments=(seg_ids, n_requests)`` threads per-row request-segment
        ids through the graph (coalesced serving); ``bucketer`` re-pads host
        boundary outputs to shape buckets so post-UDF stages stay warm;
        ``donate`` names fact tables whose (single-use, freshly padded)
        buffers the entry stage may alias into its outputs on accelerator
        backends.
        """
        env = self._env(database, row_valid, params, segments)
        return run_graph(
            self.graph, env, bucketer=bucketer, on_mid_bucket=on_mid_bucket,
            donate=frozenset(donate),
        )

    def run_async(
        self,
        database: dict[str, dict[str, jnp.ndarray]],
        *,
        executor: Any,
        row_valid: Optional[jnp.ndarray] = None,
        params: Optional[dict[str, Any]] = None,
        segments: Optional[tuple[np.ndarray, int]] = None,
        bucketer: Optional[Callable[[int], int]] = None,
        on_mid_bucket: Optional[Callable[[int, int], None]] = None,
        donate: frozenset = frozenset(),
    ):
        """Pipelined execution: returns a ``Future[RunResult]``.

        Pure stages dispatch asynchronously on the calling thread and host
        boundaries run on ``executor``'s boundary pool (see
        :class:`repro.exec.pipeline.PipelineExecutor`), so one request
        group's host work overlaps another's device work. Runs the same
        stage programs over the same env structure as :meth:`run` — a
        bucket warmed by either path stays warm for both.
        """
        env = self._env(database, row_valid, params, segments)
        return executor.run_graph_async(
            self.graph, env, bucketer=bucketer, on_mid_bucket=on_mid_bucket,
            donate=frozenset(donate),
        )

    def __call__(
        self,
        database: dict[str, dict[str, jnp.ndarray]],
        row_valid: Optional[jnp.ndarray] = None,
        params: Optional[dict[str, Any]] = None,
    ) -> Table:
        return self.run(database, row_valid=row_valid, params=params).table


class _StageRunner:
    """Per-stage executable: disk tier under jit's in-process specialization.

    Without an active artifact store this is exactly ``jax.jit(traced)``.
    With one, each new env shape/dtype structure (= one jit specialization =
    one bucket variant) first consults the store under the stage's chained
    content fingerprint: a hit deserializes the AOT-exported program and
    runs it (zero traces, ever); a miss traces live and then hands the
    freshly-specialized program to the store's background writer so the
    *next* process warm-starts without this request paying the export cost.
    The per-digest outcome is memoized, so steady-state calls never touch
    disk.

    On accelerator backends (or under ``RAVEN_DONATE=1``) a call carrying a
    non-empty ``donate`` set runs through a second jit specialization whose
    first argument — the single-use serving inputs: donated fact tables,
    the row-validity/segment vectors, the ``__mid__`` pseudo-table — is
    donated to XLA, letting the compiler alias the padded entry buffers
    into stage outputs instead of allocating fresh ones.
    """

    def __init__(self, stage):
        self.stage = stage

        def traced(env, _fn=stage.fn, _stage=stage):
            # python side effects run at trace time only: this counts
            # actual XLA compiles (one per new env shape/dtype structure),
            # attributed both globally and to this specific stage — and is
            # exactly where a "compile" fault fires (a failure that only
            # occurs when specializing, never on a warm call)
            maybe_inject("compile", token=_stage.fingerprint)
            _stage.traces += 1
            PLAN_CACHE_STATS.traces += 1
            PLAN_CACHE_STATS.stage_traces[_stage.fingerprint] = (
                PLAN_CACHE_STATS.stage_traces.get(_stage.fingerprint, 0) + 1
            )
            return _fn(env)

        self.jitted = jax.jit(traced)
        self._jitted_donating: Optional[Callable] = None  # built on demand
        # env digest -> deserialized exported call, or None (= run live)
        self._known: dict[str, Optional[Callable]] = {}

    def _run_live(self, env, donate: frozenset):
        if not donate or not donation_enabled():
            return self.jitted(env)
        if self._jitted_donating is None:
            def traced2(volatile, resident, _fn=self.stage.fn,
                        _stage=self.stage):
                maybe_inject("compile", token=_stage.fingerprint)
                _stage.traces += 1
                PLAN_CACHE_STATS.traces += 1
                PLAN_CACHE_STATS.stage_traces[_stage.fingerprint] = (
                    PLAN_CACHE_STATS.stage_traces.get(_stage.fingerprint, 0)
                    + 1
                )
                return _fn({**resident, **volatile})

            self._jitted_donating = jax.jit(traced2, donate_argnums=(0,))
        volatile = {
            k: v for k, v in env.items()
            if k in donate or k in VOLATILE_KEYS
        }
        resident = {k: v for k, v in env.items() if k not in volatile}
        return self._jitted_donating(volatile, resident)

    def __call__(self, env, donate: frozenset = frozenset()):
        # fault sites: "latency" stalls the stage (slow-stage spike),
        # "stage" raises at call time; tokens carry the stage fingerprint
        # so a plan can target one stage (e.g. only the kernel-mode fork)
        maybe_inject("latency", token=self.stage.fingerprint)
        maybe_inject("stage", token=self.stage.fingerprint)
        store = get_artifact_store()
        if store is None or not self.stage.content_stable:
            # identity-hashed fingerprint components are meaningless in any
            # other process (and a recycled id could alias a different
            # stage), so an unstable stage never touches the disk tier
            return self._run_live(env, donate)
        from repro.exec.artifact_store import env_digest

        digest = env_digest(env)
        if digest in self._known:
            fn = self._known[digest]
            return self._run_live(env, donate) if fn is None else fn(env)
        fn = store.load_stage(self.stage.fingerprint, digest)
        if fn is not None:
            PLAN_CACHE_STATS.disk_hits += 1
            self.stage.disk_loads += 1
            self._known[digest] = fn
            return fn(env)
        PLAN_CACHE_STATS.disk_misses += 1
        self._known[digest] = None
        # snapshot the env's structure (shapes/dtypes only) *before* running:
        # under donation the live call invalidates the volatile buffers, and
        # the background writer must not pin real device arrays anyway
        from repro.exec.artifact_store import abstract_env

        abstract = abstract_env(env)
        out = self._run_live(env, donate)  # live trace for this structure
        # export the raw stage fn (not ``traced``: the export's own trace
        # must not inflate retrace accounting); the store's writer thread
        # serializes off the request path
        store.save_stage_async(
            self.stage.fingerprint, digest, self.stage.fn, abstract
        )
        return out

    def preload(self, store) -> int:
        """Deserialize every on-disk bucket program for this stage."""
        if not self.stage.content_stable:
            return 0
        n = 0
        for digest in store.stage_digests(self.stage.fingerprint):
            if digest in self._known:
                # already resolved in this process — including digests this
                # process traced live and then saved itself: re-loading
                # those would fabricate "disk warm start" stats for work
                # that never crossed a process boundary
                continue
            fn = store.load_stage(self.stage.fingerprint, digest)
            if fn is not None:
                PLAN_CACHE_STATS.disk_hits += 1
                self.stage.disk_loads += 1
                self._known[digest] = fn
                n += 1
        return n


def _build_compiled(plan: PhysicalPlan, fingerprint: str, pins: list) -> CompiledPlan:
    graph = build_stage_graph(plan, pins=pins)
    for stage in graph.stages:
        if stage.kind == "pure":
            stage.runner = _StageRunner(stage)
    return CompiledPlan(fingerprint=fingerprint, graph=graph, pins=pins)


def compile_plan(plan: PhysicalPlan, cache: bool = True) -> CompiledPlan:
    """Compile a plan into a reusable executable over a database dict.

    Pure stages are jitted (one XLA program each — a fully-MLtoSQL'd query is
    exactly ONE program); UDF stages run on host between them. Compiled
    artifacts are cached in a module-level LRU keyed by plan fingerprint, so
    repeated compile/execute of an identical plan reuses both the lowered
    stages and jit's shape-specialized XLA programs instead of re-jitting
    per call. ``cache=False`` forces a fresh compile (the pre-cache,
    compile-per-call behavior — kept for benchmarks and tests).
    """
    if not cache:
        pins: list = []
        return _build_compiled(plan, plan_fingerprint(plan, pins=pins), pins)
    pins = []
    fp = plan_fingerprint(plan, pins=pins)
    entry = _PLAN_CACHE.get(fp)
    if entry is not None:
        PLAN_CACHE_STATS.hits += 1
        _PLAN_CACHE.pop(fp)  # LRU: re-insert as most recent
        _PLAN_CACHE[fp] = entry
        return entry
    PLAN_CACHE_STATS.misses += 1
    entry = _build_compiled(plan, fp, pins)
    _PLAN_CACHE[fp] = entry
    while len(_PLAN_CACHE) > PLAN_CACHE_CAPACITY:
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
        PLAN_CACHE_STATS.evictions += 1
    return entry


def execute_plan(
    plan: PhysicalPlan,
    database: dict[str, dict[str, np.ndarray]],
    row_valid: Optional[np.ndarray] = None,
    params: Optional[dict[str, Any]] = None,
) -> Table:
    db = {
        t: {c: jnp.asarray(v) for c, v in cols.items()}
        for t, cols in database.items()
    }
    return compile_plan(plan)(db, row_valid=row_valid, params=params)


def plan_params(plan: PhysicalPlan) -> set[str]:
    """Names of every :class:`~repro.relational.expr.Param` the plan reads."""
    from repro.relational.expr import params_of

    names: set[str] = set()
    for p in walk_plan(plan):
        if isinstance(p, Filter):
            names |= params_of(p.expr)
        elif isinstance(p, Project):
            for e in p.exprs.values():
                names |= params_of(e)
    return names


# ---------------------------------------------------------------------------
# Data-parallel execution (shard_map over the 'data' mesh axis)
# ---------------------------------------------------------------------------


def compile_plan_sharded(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    fact_table: str,
    axis: str = "data",
) -> Callable[[dict], Table]:
    """Shard the fact table's rows over ``axis``; replicate dimension tables.

    Only valid for fully-pure plans (MLtoSQL / MLtoDNN output). Aggregates
    become partial-per-shard + psum.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    graph = build_stage_graph(plan)
    assert len(graph.stages) == 1 and graph.is_pure, (
        "sharded execution requires a host-boundary-free plan"
    )
    fn = graph.stages[0].fn
    has_agg = any(isinstance(p, Aggregate) for p in walk_plan(plan))

    def body(env):
        cols, valid, _seg = fn(env)
        if has_agg:
            cols = {k: jax.lax.psum(v, axis) for k, v in cols.items()}
            # counts/sums compose additively; mean needs sum/count form —
            # callers use sum+count and divide outside.
        return cols, valid

    def specs_for(env):
        in_specs = {}
        for t, cols in env.items():
            spec = P(axis) if t == fact_table else P()
            in_specs[t] = {c: spec for c in cols}
        return in_specs

    def run(database):
        env = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in database.items()
        }
        in_specs = (specs_for(env),)
        out_specs = (
            ({k: P() for k in _out_cols(plan)}, P())
            if has_agg
            else ({k: P(axis) for k in _out_cols(plan)}, P(axis))
        )
        sharded = shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
        cols, valid = jax.jit(sharded)(env)
        return Table(columns=cols, valid=valid)

    return run


def _out_cols(plan: PhysicalPlan) -> list[str]:
    """Static output-column inference for out_specs."""
    if isinstance(plan, Scan):
        return list(plan.columns)
    if isinstance(plan, Join):
        return _out_cols(plan.child) + list(plan.dim_columns)
    if isinstance(plan, Filter):
        return _out_cols(plan.child)
    if isinstance(plan, Project):
        base = _out_cols(plan.child) if plan.keep is None else list(plan.keep)
        return base + list(plan.exprs)
    if isinstance(plan, (MLUdf, TensorOp)):
        base = [c for c in _out_cols(plan.child) if c not in plan.consumes]
        return base + [c for c in plan.output_names if c not in base]
    if isinstance(plan, Aggregate):
        return [a[0] for a in plan.aggs]
    raise TypeError(type(plan))
