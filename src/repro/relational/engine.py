"""Physical plans + execution for the columnar JAX data engine.

A plan is a tree of operators over a database (dict of named column-dicts).
Lowering splits the plan at host boundaries (``MLUdf``) into *stages*: maximal
pure-jnp segments are jitted as single XLA programs (so an MLtoSQL-compiled
model fuses with the scans/joins/filters around it — the whole point of the
optimization), while MLUdf stages run interpreted numpy on host with
batch-at-a-time dispatch (the Spark→Python-UDF→ML-runtime boundary, including
its conversion and per-batch overheads).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.expr import Expr, eval_expr
from repro.relational.table import Table

# ---------------------------------------------------------------------------
# Plan nodes
# ---------------------------------------------------------------------------


@dataclass
class Scan:
    table: str
    columns: list[str]  # columns actually read (projection pushdown target)


@dataclass
class Join:
    """Foreign-key join: gather dim columns onto the fact spine."""

    child: "PhysicalPlan"
    dim_table: str
    fact_key: str
    dim_key: str
    dim_columns: list[str]  # dim columns to bring in (pushdown target)


@dataclass
class Filter:
    child: "PhysicalPlan"
    expr: Expr


@dataclass
class Project:
    child: "PhysicalPlan"
    keep: Optional[list[str]]  # None -> pass all child columns through
    exprs: dict[str, Expr] = field(default_factory=dict)


@dataclass
class MLUdf:
    """Host-boundary pipeline invocation (interpreted 'ML runtime')."""

    child: "PhysicalPlan"
    pipeline: Any  # TrainedPipeline
    output_names: list[str]  # graph outputs -> column names
    batch_size: int = 10_000


@dataclass
class TensorOp:
    """Fused tensor program (MLtoDNN output). ``fn(cols)->cols`` is jittable."""

    child: "PhysicalPlan"
    fn: Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]
    output_names: list[str]


@dataclass
class Aggregate:
    child: "PhysicalPlan"
    aggs: list[tuple[str, str, str]]  # (out_name, op{sum,count,mean}, col)


PhysicalPlan = Union[Scan, Join, Filter, Project, MLUdf, TensorOp, Aggregate]


def plan_children(p: PhysicalPlan) -> list[PhysicalPlan]:
    return [] if isinstance(p, Scan) else [p.child]


def walk_plan(p: PhysicalPlan):
    yield p
    for c in plan_children(p):
        yield from walk_plan(c)


# ---------------------------------------------------------------------------
# Lowering: plan -> stages
# ---------------------------------------------------------------------------

State = tuple[dict[str, jnp.ndarray], jnp.ndarray]  # (columns, valid)

# env key carrying the initial fact-spine validity mask (padded serving)
ROW_VALID_KEY = "__row_valid__"

# env key carrying bound :param values (0-d arrays). Params enter the jitted
# stages as runtime inputs, so re-binding a value reuses the traced program.
PARAMS_KEY = "__params__"


def _pure_step(plan: PhysicalPlan, inner: Callable[[dict], State]) -> Callable[[dict], State]:
    """Compose one pure operator on top of ``inner`` (env -> state)."""

    if isinstance(plan, Scan):
        def fn(env, _plan=plan):
            cols = {c: env[_plan.table][c] for c in _plan.columns}
            n = next(iter(cols.values())).shape[0]
            # the serving layer pads batches to a shape bucket and marks the
            # pad rows invalid up front via ROW_VALID_KEY
            rv = env.get(ROW_VALID_KEY)
            valid = jnp.ones((n,), dtype=bool) if rv is None else rv.astype(bool)
            return cols, valid
        return fn

    if isinstance(plan, Join):
        def fn(env, _plan=plan):
            cols, valid = inner(env)
            dim = env[_plan.dim_table]
            keys = dim[_plan.dim_key]
            order = jnp.argsort(keys)
            skeys = keys[order]
            pos = jnp.searchsorted(skeys, cols[_plan.fact_key])
            pos = jnp.clip(pos, 0, skeys.shape[0] - 1)
            hit = skeys[pos] == cols[_plan.fact_key]
            gather = order[pos]
            out = dict(cols)
            for c in _plan.dim_columns:
                out[c] = dim[c][gather]
            return out, valid & hit
        return fn

    if isinstance(plan, Filter):
        def fn(env, _plan=plan):
            cols, valid = inner(env)
            keep = eval_expr(_plan.expr, cols, env.get(PARAMS_KEY))
            return cols, valid & keep.astype(bool)
        return fn

    if isinstance(plan, Project):
        def fn(env, _plan=plan):
            cols, valid = inner(env)
            keep = _plan.keep if _plan.keep is not None else list(cols)
            out = {c: cols[c] for c in keep}
            for name, e in _plan.exprs.items():
                out[name] = eval_expr(e, cols, env.get(PARAMS_KEY))
            return out, valid
        return fn

    if isinstance(plan, TensorOp):
        def fn(env, _plan=plan):
            cols, valid = inner(env)
            out = dict(cols)
            out.update(_plan.fn(cols))
            return out, valid
        return fn

    if isinstance(plan, Aggregate):
        def fn(env, _plan=plan):
            cols, valid = inner(env)
            w = valid.astype(jnp.float32)
            out = {}
            for name, op, col in _plan.aggs:
                if op == "count":
                    out[name] = jnp.sum(w)[None]
                elif op == "sum":
                    out[name] = jnp.sum(cols[col] * w)[None]
                elif op == "mean":
                    out[name] = (jnp.sum(cols[col] * w) / jnp.maximum(jnp.sum(w), 1.0))[None]
                else:
                    raise ValueError(op)
            return out, jnp.ones((1,), dtype=bool)
        return fn

    raise TypeError(type(plan))


@dataclass
class _PureStage:
    fn: Callable[[dict], State]  # env -> state  (jitted at compile)


@dataclass
class _UdfStage:
    udf: MLUdf


def _lower(plan: PhysicalPlan) -> list[Union[_PureStage, _UdfStage]]:
    if isinstance(plan, Scan):
        return [_PureStage(_pure_step(plan, None))]
    if isinstance(plan, MLUdf):
        return _lower(plan.child) + [_UdfStage(plan)]
    stages = _lower(plan.child)
    last = stages[-1]
    if isinstance(last, _PureStage):
        stages[-1] = _PureStage(_pure_step(plan, last.fn))
    else:
        # operator sits on top of a host boundary: its "env" is the boundary
        # output re-wrapped as a pseudo-table named "__mid__"
        def from_mid(env):
            cols = dict(env["__mid__"])
            valid = cols.pop("__valid__")
            return cols, valid

        stages.append(_PureStage(_pure_step(plan, from_mid)))
    return stages


def _run_udf(udf: MLUdf, cols: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Batch-at-a-time interpreted pipeline execution (host)."""
    from repro.ml.pipeline import run_pipeline

    n = len(next(iter(cols.values())))
    in_names = udf.pipeline.input_names()
    outs: dict[str, list[np.ndarray]] = {o: [] for o in udf.pipeline.outputs}
    bs = udf.batch_size
    for s in range(0, max(n, 1), bs):
        batch = {k: cols[k][s : s + bs] for k in in_names}
        if len(next(iter(batch.values()))) == 0:
            continue
        res = run_pipeline(udf.pipeline, batch)
        for o in udf.pipeline.outputs:
            outs[o].append(np.asarray(res[o]))
    result = dict(cols)
    for o, name in zip(udf.pipeline.outputs, udf.output_names):
        result[name] = (
            np.concatenate(outs[o]) if outs[o] else np.empty((0,))
        )
    return result


def plan_fingerprint(plan: PhysicalPlan, pins: Optional[list] = None) -> str:
    """Canonical content hash of a physical plan.

    Structurally identical plans (same operators, expressions, pipeline
    weights) hash equal, so compiled artifacts are reusable across plan
    objects. Opaque callables (``TensorOp.fn``) hash by identity and are
    reported via ``pins``; the compiled-plan cache keeps those alive so a
    fingerprint can never alias a dead closure's recycled id.
    """
    from repro.core.fingerprint import fingerprint

    return fingerprint(plan, pins=pins)


@dataclass
class CacheStats:
    """Module-level compiled-plan cache accounting."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    traces: int = 0  # XLA (re)compiles: stage tracings across all entries

    def snapshot(self) -> dict[str, int]:
        return {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "traces": self.traces,
        }


PLAN_CACHE_STATS = CacheStats()
_PLAN_CACHE: "dict[str, CompiledPlan]" = {}  # insertion-ordered: LRU via re-insert
PLAN_CACHE_CAPACITY = 64


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    PLAN_CACHE_STATS.hits = PLAN_CACHE_STATS.misses = 0
    PLAN_CACHE_STATS.evictions = PLAN_CACHE_STATS.traces = 0


@dataclass
class CompiledPlan:
    """Reusable compiled artifact for one physical plan.

    ``stages`` holds the jitted pure-stage executables (jit specializes per
    input shape bucket internally; ``traces`` counts those specializations —
    i.e. actual XLA compiles). ``pins`` keeps identity-hashed plan components
    alive while this entry can be looked up.
    """

    fingerprint: str
    stages: list
    pins: list = field(default_factory=list)
    traces: int = 0

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def is_pure(self) -> bool:
        """One jitted XLA program, no host boundary (MLtoSQL/MLtoDNN output)."""
        return all(isinstance(s, _PureStage) for s in self.stages)

    def __call__(
        self,
        database: dict[str, dict[str, jnp.ndarray]],
        row_valid: Optional[jnp.ndarray] = None,
        params: Optional[dict[str, Any]] = None,
    ) -> Table:
        env: dict[str, Any] = dict(database)
        if row_valid is not None:
            env[ROW_VALID_KEY] = jnp.asarray(row_valid, dtype=bool)
        if params:
            # float32 0-d arrays: a fresh bound value is a same-shape input
            # to the jitted stages, so re-binding never re-traces
            env[PARAMS_KEY] = {
                k: jnp.asarray(v, dtype=jnp.float32) for k, v in params.items()
            }
        state: Optional[State] = None
        for st in self.stages:
            if isinstance(st, _PureStage):
                state = st.fn(env)
            else:
                cols, valid = state
                np_cols = {k: np.asarray(v) for k, v in cols.items()}
                mask = np.asarray(valid)
                np_cols = {k: v[mask] for k, v in np_cols.items()}  # compact
                out = _run_udf(st.udf, np_cols)
                mid = {k: jnp.asarray(v) for k, v in out.items()}
                mid["__valid__"] = jnp.ones(
                    (len(next(iter(out.values()))),), dtype=bool
                ) if out else jnp.ones((0,), dtype=bool)
                env = dict(env)
                env["__mid__"] = mid
                state = (dict(mid), mid["__valid__"])
                state[0].pop("__valid__")
        cols, valid = state
        return Table(columns=cols, valid=valid)


def _build_compiled(plan: PhysicalPlan, fingerprint: str, pins: list) -> CompiledPlan:
    compiled = CompiledPlan(fingerprint=fingerprint, stages=[], pins=pins)
    for s in _lower(plan):
        if isinstance(s, _PureStage):
            def traced(env, _fn=s.fn):
                # python side effects run at trace time only: this counts
                # actual XLA compiles (one per new env shape/dtype structure)
                compiled.traces += 1
                PLAN_CACHE_STATS.traces += 1
                return _fn(env)

            compiled.stages.append(_PureStage(jax.jit(traced)))
        else:
            compiled.stages.append(s)
    return compiled


def compile_plan(plan: PhysicalPlan, cache: bool = True) -> CompiledPlan:
    """Compile a plan into a reusable executable over a database dict.

    Pure stages are jitted (one XLA program each — a fully-MLtoSQL'd query is
    exactly ONE program); UDF stages run on host between them. Compiled
    artifacts are cached in a module-level LRU keyed by plan fingerprint, so
    repeated compile/execute of an identical plan reuses both the lowered
    stages and jit's shape-specialized XLA programs instead of re-jitting
    per call. ``cache=False`` forces a fresh compile (the pre-cache,
    compile-per-call behavior — kept for benchmarks and tests).
    """
    if not cache:
        pins: list = []
        return _build_compiled(plan, plan_fingerprint(plan, pins=pins), pins)
    pins = []
    fp = plan_fingerprint(plan, pins=pins)
    entry = _PLAN_CACHE.get(fp)
    if entry is not None:
        PLAN_CACHE_STATS.hits += 1
        _PLAN_CACHE.pop(fp)  # LRU: re-insert as most recent
        _PLAN_CACHE[fp] = entry
        return entry
    PLAN_CACHE_STATS.misses += 1
    entry = _build_compiled(plan, fp, pins)
    _PLAN_CACHE[fp] = entry
    while len(_PLAN_CACHE) > PLAN_CACHE_CAPACITY:
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
        PLAN_CACHE_STATS.evictions += 1
    return entry


def execute_plan(
    plan: PhysicalPlan,
    database: dict[str, dict[str, np.ndarray]],
    row_valid: Optional[np.ndarray] = None,
    params: Optional[dict[str, Any]] = None,
) -> Table:
    db = {
        t: {c: jnp.asarray(v) for c, v in cols.items()}
        for t, cols in database.items()
    }
    return compile_plan(plan)(db, row_valid=row_valid, params=params)


def plan_params(plan: PhysicalPlan) -> set[str]:
    """Names of every :class:`~repro.relational.expr.Param` the plan reads."""
    from repro.relational.expr import params_of

    names: set[str] = set()
    for p in walk_plan(plan):
        if isinstance(p, Filter):
            names |= params_of(p.expr)
        elif isinstance(p, Project):
            for e in p.exprs.values():
                names |= params_of(e)
    return names


# ---------------------------------------------------------------------------
# Data-parallel execution (shard_map over the 'data' mesh axis)
# ---------------------------------------------------------------------------


def compile_plan_sharded(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    fact_table: str,
    axis: str = "data",
) -> Callable[[dict], Table]:
    """Shard the fact table's rows over ``axis``; replicate dimension tables.

    Only valid for fully-pure plans (MLtoSQL / MLtoDNN output). Aggregates
    become partial-per-shard + psum.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    stages = _lower(plan)
    assert len(stages) == 1 and isinstance(stages[0], _PureStage), (
        "sharded execution requires a host-boundary-free plan"
    )
    fn = stages[0].fn
    has_agg = any(isinstance(p, Aggregate) for p in walk_plan(plan))

    def body(env):
        cols, valid = fn(env)
        if has_agg:
            cols = {k: jax.lax.psum(v, axis) for k, v in cols.items()}
            # counts/sums compose additively; mean needs sum/count form —
            # callers use sum+count and divide outside.
        return cols, valid

    def specs_for(env):
        in_specs = {}
        for t, cols in env.items():
            spec = P(axis) if t == fact_table else P()
            in_specs[t] = {c: spec for c in cols}
        return in_specs

    def run(database):
        env = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in database.items()
        }
        in_specs = (specs_for(env),)
        out_specs = (
            ({k: P() for k in _out_cols(plan)}, P())
            if has_agg
            else ({k: P(axis) for k in _out_cols(plan)}, P(axis))
        )
        sharded = shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
        cols, valid = jax.jit(sharded)(env)
        return Table(columns=cols, valid=valid)

    return run


def _out_cols(plan: PhysicalPlan) -> list[str]:
    """Static output-column inference for out_specs."""
    if isinstance(plan, Scan):
        return list(plan.columns)
    if isinstance(plan, Join):
        return _out_cols(plan.child) + list(plan.dim_columns)
    if isinstance(plan, Filter):
        return _out_cols(plan.child)
    if isinstance(plan, Project):
        base = _out_cols(plan.child) if plan.keep is None else list(plan.keep)
        return base + list(plan.exprs)
    if isinstance(plan, (MLUdf, TensorOp)):
        return _out_cols(plan.child) + list(plan.output_names)
    if isinstance(plan, Aggregate):
        return [a[0] for a in plan.aggs]
    raise TypeError(type(plan))
