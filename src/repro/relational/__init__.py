"""Columnar JAX data engine — the Spark / SQL Server analog.

Tables are dicts of device-resident columns plus a validity mask; plans are
trees of physical operators compiled into (a pipeline of) jitted XLA programs.
ML pipelines enter the plan in one of three physical forms (paper §5):

  * ``MLUdf``     — host boundary + interpreted numpy execution (the
                    Spark→Python-UDF→ONNX-Runtime path),
  * ``TensorOp``  — a fused jitted tensor program (the MLtoDNN path),
  * plain ``Project`` expressions — the MLtoSQL path (model compiled *into*
                    the relational program; everything fuses into one XLA
                    computation).
"""
from repro.relational.expr import (
    Bin,
    Case,
    Col,
    Const,
    Expr,
    eval_expr,
    expr_size,
)
from repro.relational.table import Table
from repro.relational.engine import (
    Aggregate,
    CompiledPlan,
    Filter,
    Join,
    MLUdf,
    PhysicalPlan,
    PLAN_CACHE_STATS,
    Project,
    Scan,
    TensorOp,
    clear_plan_cache,
    execute_plan,
    compile_plan,
    plan_fingerprint,
)
