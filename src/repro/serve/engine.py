"""Continuous-batching serve engine over the zoo's prefill/decode steps.

vLLM-style slot model adapted to JAX/TPU constraints: the decode step is ONE
fixed-shape jitted program over a (B_slots, S_cache) KV cache; requests map
onto free slots, finished slots are recycled mid-flight, and prefill runs as
a separate (also fixed-shape) program whose emitted KV rows are scattered
into the slot cache. Fixed shapes mean exactly two compiled programs serve
any request mix — no shape-churn recompiles (the TPU analog of CUDA-graph
serving).

Greedy decoding; per-request max_new_tokens and eos termination. The engine
is deliberately synchronous (step() advances one decode tick) so tests and
examples can drive it deterministically; a production loop would wrap it in
an async request pump.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    rid: int = 0
    # filled by the engine
    output: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int = 4, cache_len: int = 256):
        fam = model.cfg.family
        if fam not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "ServeEngine currently drives KV-cache decoder LMs"
            )
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        cfg = model.cfg
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        Ld, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        self.k_cache = jnp.zeros((Ld, n_slots, cache_len, KH, hd), dt)
        self.v_cache = jnp.zeros((Ld, n_slots, cache_len, KH, hd), dt)
        self.lengths = np.zeros((n_slots,), np.int32)
        self.last_token = np.zeros((n_slots,), np.int32)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self._rid = itertools.count()
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        # two fixed-shape compiled programs: prefill(prompt block), decode tick
        def _decode(params, tokens, lengths, kc, vc):
            logits, (kc, vc) = model.decode(
                params, {"tokens": tokens, "lengths": lengths}, (kc, vc)
            )
            return jnp.argmax(logits, -1).astype(jnp.int32), kc, vc

        self._decode = jax.jit(_decode, donate_argnums=(3, 4))
        self._prefill = jax.jit(
            lambda params, batch: model.prefill(params, batch, cache_len=cache_len)
        )
        self.prefill_len = 32  # fixed prompt block (pad/truncate to this)

    # -- request lifecycle --------------------------------------------------

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        r = Request(prompt=list(prompt), max_new_tokens=max_new_tokens,
                    eos_id=eos_id, rid=next(self._rid))
        self.queue.append(r)
        return r

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        """Prefill queued requests into free slots (batched to n_slots)."""
        free = self._free_slots()
        take = min(len(free), len(self.queue))
        if take == 0:
            return
        reqs = [self.queue.pop(0) for _ in range(take)]
        P = self.prefill_len
        toks = np.zeros((take, P), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-P:]
            toks[i, P - len(p):] = p  # left-pad (positions still 0..P-1)
        logits, (kcs, vcs) = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        first = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        for i, r in enumerate(reqs):
            s = free[i]
            self.slot_req[s] = r
            self.k_cache = self.k_cache.at[:, s].set(kcs[:, i])
            self.v_cache = self.v_cache.at[:, s].set(vcs[:, i])
            self.lengths[s] = P
            tok = int(first[i])
            r.output.append(tok)
            self.last_token[s] = tok
            self._maybe_finish(s)

    def _maybe_finish(self, slot: int) -> None:
        r = self.slot_req[slot]
        if r is None:
            return
        if (
            len(r.output) >= r.max_new_tokens
            or (r.eos_id is not None and r.output and r.output[-1] == r.eos_id)
            or self.lengths[slot] + 1 >= self.cache_len
        ):
            r.done = True
            self.finished.append(r)
            self.slot_req[slot] = None
            self.lengths[slot] = 0

    # -- main loop -----------------------------------------------------------

    def step(self) -> int:
        """Admit + one decode tick. Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tok, self.k_cache, self.v_cache = self._decode(
            self.params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.lengths),
            self.k_cache,
            self.v_cache,
        )
        tok = np.asarray(tok)
        for s in active:
            self.lengths[s] += 1
            t = int(tok[s])
            self.slot_req[s].output.append(t)
            self.last_token[s] = t
            self._maybe_finish(s)
        return len(active)

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        for _ in range(max_ticks):
            active = self.step()
            if active == 0 and not self.queue:
                break
        return self.finished
