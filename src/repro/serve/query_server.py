"""Serving layer for prediction queries: optimize once, execute hot.

Raven's premise is that a prediction query is optimized *once* and then served
at high request rates, yet ``execute_plan`` alone re-derives everything per
call. ``PredictionQueryServer`` closes that gap on top of the StageGraph IR:

  * ``register`` runs the :class:`RavenOptimizer` once per (query, stats)
    — structurally identical registrations share the optimized physical plan
    via the canonical query fingerprint — and compiles the plan into a
    reusable stage graph through the engine's fingerprint-keyed plan cache.
  * Incoming batches are padded to a power-of-two row bucket with a validity
    mask at **every pure-stage boundary**: query entry *and* each MLUdf host
    boundary's exit, so post-UDF stages stop re-tracing on data-dependent
    shape churn.
  * ``submit``/``flush`` micro-batch: pending requests against the same query
    coalesce into one padded execution. Pure row-aligned plans are sliced
    back by position; host-boundary and aggregate plans thread per-request
    *segment ids* through the graph (compaction-proof) and split on them.
  * An optional :class:`~repro.exec.pump.RequestPump` drives flushing against
    a latency target, so callers need never invoke ``flush`` themselves
    (``prep.serve(max_latency_ms=...)`` on the session front door).

Without a pump the server stays synchronous — ``submit`` enqueues, ``flush``
drains — so tests and examples can drive it deterministically.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import fingerprint
from repro.core.ir import PredictionQuery
from repro.core.optimizer import OptimizationReport, OptimizerOptions, RavenOptimizer
from repro.errors import (
    RavenError,
    StaleQueryError,
    UnknownQueryError,
    check_params,
)
from repro.exec.pump import RequestPump
from repro.relational.engine import (
    Aggregate,
    CompiledPlan,
    PhysicalPlan,
    Scan,
    compile_plan,
    plan_params,
    walk_plan,
)


def row_bucket(n: int, min_bucket: int = 64) -> int:
    """Smallest power-of-two bucket holding ``n`` rows (≥ ``min_bucket``)."""
    b = max(int(min_bucket), 1)
    while b < n:
        b <<= 1
    return b


@dataclass
class QueryRequest:
    """One submitted batch; ``result`` is filled by ``flush`` (or the pump)."""

    rid: int
    query: str
    columns: dict[str, np.ndarray]
    n_rows: int
    result: Optional[dict[str, np.ndarray]] = None
    done: bool = False
    error: Optional[BaseException] = None  # execution failure, re-raised by wait()
    t_submit: float = 0.0
    t_done: float = 0.0
    _event: threading.Event = field(
        default_factory=threading.Event, repr=False, compare=False
    )

    def wait(self, timeout: Optional[float] = None) -> dict[str, np.ndarray]:
        """Block until this request's result is ready (pump-driven serving)
        and return it; re-raises the execution error if its batch failed."""
        if not self._event.wait(timeout):
            raise RavenError(
                f"request {self.rid} for query '{self.query}' not served "
                f"within {timeout}s — is a pump running / was flush() called?"
            )
        if self.error is not None:
            raise RavenError(
                f"request {self.rid} for query '{self.query}' failed during "
                f"execution: {self.error}"
            ) from self.error
        return self.result

    @property
    def latency_s(self) -> float:
        """Submit-to-result wall time (0.0 until served)."""
        return (self.t_done - self.t_submit) if self.done else 0.0


@dataclass
class ServerStats:
    queries_registered: int = 0
    plan_cache_hits: int = 0    # optimizer runs avoided via query fingerprint
    plan_cache_misses: int = 0
    bucket_hits: int = 0        # executions landing on an already-seen
    bucket_misses: int = 0      # (query, schema, bucket) combination
    mid_bucket_hits: int = 0    # host-boundary exits landing on an already-
    mid_bucket_misses: int = 0  # seen (query, stage, bucket) combination
    warm_started_buckets: int = 0  # bucket programs preloaded from the
    #                                artifact store at registration time
    batches_executed: int = 0
    requests_served: int = 0
    coalesced_requests: int = 0  # requests that shared a batch with others
    segmented_batches: int = 0   # coalesced executions split by segment ids
    flushes: int = 0
    rows_in: int = 0
    rows_padded: int = 0

    def snapshot(self) -> dict[str, int]:
        return dict(self.__dict__)


@dataclass
class RegisteredQuery:
    name: str
    token: str  # unique per registration: the stale-handle guard key
    query_fingerprint: str
    plan: PhysicalPlan
    report: OptimizationReport
    compiled: CompiledPlan
    database: dict[str, dict[str, jnp.ndarray]]  # dims resident on device
    fact_table: str
    scan_columns: list[str]
    fact_dtypes: dict[str, np.dtype]
    has_aggregate: bool
    param_names: frozenset[str] = frozenset()
    params: dict[str, Any] = field(default_factory=dict)

    @property
    def recompiles(self) -> int:
        """XLA stage tracings attributable to this query's compiled plan."""
        return self.compiled.traces

    @property
    def sliceable(self) -> bool:
        """Coalesced output rows stay 1:1 aligned with the input spine, so
        per-request results fall out of positional slicing — no segment ids
        needed. False once a host boundary (compaction) or an aggregate
        (folding) breaks the alignment."""
        return self.compiled.is_pure and not self.has_aggregate


class PredictionQueryServer:
    def __init__(
        self,
        strategy=None,
        options: Optional[OptimizerOptions] = None,
        *,
        min_bucket: int = 64,
        max_bucket: int = 1 << 20,
        mid_bucketing: bool = True,
    ):
        self.optimizer = RavenOptimizer(strategy=strategy, options=options)
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        # pad host-boundary outputs to power-of-two buckets before the next
        # pure stage (False reproduces the old exact-shape post-UDF path —
        # kept for A/B benchmarks)
        self.mid_bucketing = mid_bucketing
        self.stats = ServerStats()
        self.queries: dict[str, RegisteredQuery] = {}
        self._optimized: dict[str, tuple[PhysicalPlan, OptimizationReport]] = {}
        self._pins: list[Any] = []  # keeps identity-hashed objects alive
        self._seen_buckets: set[tuple[str, tuple, int]] = set()
        self._seen_mid_buckets: set[tuple[str, int, int]] = set()
        self._rid = itertools.count()
        self._reg_serial = itertools.count()
        self._pending: list[QueryRequest] = []
        self._lock = threading.Lock()        # guards the pending queue
        self._flush_lock = threading.Lock()  # serializes flush bodies
        self._pump: Optional[RequestPump] = None

    # -- registration --------------------------------------------------------

    def register(
        self,
        name: str,
        query: PredictionQuery,
        database: dict[str, dict[str, np.ndarray]],
        fact_table: Optional[str] = None,
        *,
        optimized: Optional[tuple[PhysicalPlan, OptimizationReport]] = None,
        params: Optional[dict[str, Any]] = None,
    ) -> RegisteredQuery:
        """Optimize + compile ``query`` and make it servable under ``name``.

        ``database`` supplies the dimension tables (kept device-resident) and
        the fact table's schema; serve-time batches replace the fact rows.
        ``optimized`` seeds the (plan, report) for a query the caller already
        optimized (the session front door's PreparedQuery path), keyed under
        the same fingerprint the server would compute itself. ``params``
        binds the query's ``:param`` placeholders; re-bind via :meth:`rebind`
        without touching the compiled plan.
        """
        if optimized is not None:
            # externally optimized (the session's PreparedQuery path): the
            # caller's optimizer options may differ from this server's, so
            # key on the supplied physical plan rather than seeding the
            # (query, server-options) cache with a foreign plan. Neither a
            # cache hit nor a miss — no optimizer run happened here.
            plan, report = optimized
            qfp = fingerprint(
                query.plan, query.stats, "external", pins=self._pins,
            )
        else:
            qfp = fingerprint(
                query.plan, query.stats, self.optimizer.options,
                self.optimizer.strategy, pins=self._pins,
            )
            cached = self._optimized.get(qfp)
            if cached is not None:
                self.stats.plan_cache_hits += 1
                plan, report = cached
            else:
                self.stats.plan_cache_misses += 1
                plan, report = self.optimizer.optimize(query)
                self._optimized[qfp] = (plan, report)
        compiled = compile_plan(plan)
        # warm start: deserialize every AOT-exported bucket program the
        # artifact store holds for this plan's stages, so previously-served
        # shapes run with zero new XLA traces from the very first submit
        from repro.relational.engine import get_artifact_store

        if get_artifact_store() is not None:
            self.stats.warm_started_buckets += compiled.warm_start()
        param_names = frozenset(plan_params(plan))
        bound = dict(params or {})
        check_params(param_names, bound, context=f"query '{name}'")

        scans = [p for p in walk_plan(plan) if isinstance(p, Scan)]
        if fact_table is None:
            fact_table = scans[0].table
        if fact_table not in database:
            raise KeyError(f"fact table '{fact_table}' missing from database")
        scan_columns = [c for s in scans if s.table == fact_table for c in s.columns]
        db = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in database.items()
            if t != fact_table
        }
        reg = RegisteredQuery(
            name=name,
            # plan fingerprints are deliberately invariant under :param
            # values (rebinding must not recompile), so a handle guard keyed
            # on them alone would miss a re-registration that only changed
            # bound params; the per-registration serial closes that hole
            token=f"{compiled.fingerprint[:16]}#{next(self._reg_serial)}",
            query_fingerprint=qfp,
            plan=plan,
            report=report,
            compiled=compiled,
            database=db,
            fact_table=fact_table,
            scan_columns=scan_columns,
            fact_dtypes={
                c: np.asarray(database[fact_table][c]).dtype
                for c in scan_columns
            },
            has_aggregate=any(isinstance(p, Aggregate) for p in walk_plan(plan)),
            param_names=param_names,
            params={k: jnp.asarray(v, jnp.float32) for k, v in bound.items()},
        )
        self.queries[name] = reg
        self.stats.queries_registered += 1
        return reg

    def rebind(self, name: str, params: dict[str, Any]) -> RegisteredQuery:
        """Re-bind ``:param`` values for a registered query.

        Fingerprint-stable: the optimized plan, compiled stages, and shape
        buckets are untouched — the new values simply flow into the next
        execution as runtime inputs (zero new XLA traces).
        """
        reg = self._registered(name)
        check_params(
            reg.param_names, params, require_all=False, context=f"query '{name}'"
        )
        reg.params.update(
            {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
        )
        return reg

    def _registered(self, name: str) -> RegisteredQuery:
        reg = self.queries.get(name)
        if reg is None:
            raise UnknownQueryError(
                f"no query registered under '{name}' — registered: "
                f"{sorted(self.queries) or '(none)'}"
            )
        return reg

    # -- the pump ------------------------------------------------------------

    def start_pump(self, max_latency_ms: float = 5.0) -> RequestPump:
        """Start (or retune) the background pump: submitted requests flush
        automatically once the oldest has waited ``max_latency_ms``."""
        with self._lock:
            if self._pump is None:
                self._pump = RequestPump(
                    self.flush, max_latency_ms=max_latency_ms
                )
                self._pump.start()
            else:
                # served queries share one pump: the tightest target wins
                self._pump.max_latency_ms = min(
                    self._pump.max_latency_ms, float(max_latency_ms)
                )
            return self._pump

    def stop_pump(self) -> None:
        with self._lock:
            pump, self._pump = self._pump, None
        if pump is not None:
            pump.stop()  # outside the lock: stop() drains via flush()

    @property
    def pump(self) -> Optional[RequestPump]:
        return self._pump

    # -- request lifecycle ---------------------------------------------------

    def submit(
        self,
        name: str,
        columns: dict[str, np.ndarray],
        *,
        expect_token: Optional[str] = None,
    ) -> QueryRequest:
        """Enqueue one batch of fact rows for ``name``; run via ``flush`` (or
        the pump). ``expect_token`` guards against serving through a stale
        handle: if ``name`` has been re-registered since the caller's
        ``serve()`` — different plan *or* different bound params — the
        submit is rejected instead of silently answering the wrong query."""
        reg = self._registered(name)
        if expect_token is not None and expect_token != reg.token:
            raise StaleQueryError(
                f"query '{name}' was re-registered since this handle served "
                f"it (registration {reg.token} != handle's "
                f"{expect_token}) — re-serve the prepared query to refresh "
                f"the handle"
            )
        missing = [c for c in reg.scan_columns if c not in columns]
        if missing:
            raise KeyError(f"batch for '{name}' missing columns {missing}")
        # normalize dtypes to the registered schema so every bucket-sized
        # batch maps onto the same compiled program
        cols = {
            c: np.asarray(columns[c]).astype(reg.fact_dtypes[c], copy=False)
            for c in reg.scan_columns
        }
        lengths = {len(v) for v in cols.values()}
        if len(lengths) > 1:
            raise ValueError(
                f"batch for '{name}' has ragged columns: "
                f"{ {c: len(v) for c, v in cols.items()} }"
            )
        n = lengths.pop() if lengths else 0
        req = QueryRequest(
            rid=next(self._rid), query=name, columns=cols, n_rows=n,
            t_submit=time.perf_counter(),
        )
        with self._lock:
            self._pending.append(req)
            self.stats.rows_in += n
            pump = self._pump  # racing stop_pump(): read once, under the lock
        if pump is not None:
            pump.notify(req.t_submit)
        return req

    def flush(self) -> list[QueryRequest]:
        """Execute all pending requests (coalescing per query) and return
        them with results filled. Safe to call from any thread; concurrent
        flushes serialize, and an empty queue is a no-op."""
        with self._flush_lock:
            with self._lock:
                pending, self._pending = self._pending, []
            if not pending:
                return []
            # account before running: waiters wake the instant their request
            # finishes, and must observe consistent flush counters
            self.stats.requests_served += len(pending)
            self.stats.flushes += 1
            by_query: dict[str, list[QueryRequest]] = {}
            for r in pending:
                by_query.setdefault(r.query, []).append(r)
            first_error: Optional[BaseException] = None
            for name, reqs in by_query.items():
                reg = self.queries[name]
                for group in self._coalesce(reqs):
                    try:
                        self._run_group(reg, group)
                    except BaseException as e:
                        # contain the blast radius: fail this group's
                        # requests (waiters re-raise from wait()) but keep
                        # serving the other groups in this flush
                        for r in group:
                            if not r.done:
                                r.error = e
                                r._event.set()
                        if first_error is None:
                            first_error = e
            if first_error is not None:
                raise first_error
        return pending

    def execute(
        self, name: str, columns: dict[str, np.ndarray]
    ) -> dict[str, np.ndarray]:
        """One-shot convenience: submit + flush + return the result."""
        req = self.submit(name, columns)
        self.flush()
        # under a pump another thread's flush may have raced ours and taken
        # this request; either way the result is ready once both finish
        return req.wait(timeout=60.0)

    # -- internals -----------------------------------------------------------

    def _coalesce(self, reqs: list[QueryRequest]) -> list[list[QueryRequest]]:
        """Pack pending requests into shared executions ≤ ``max_bucket``."""
        groups: list[list[QueryRequest]] = []
        cur: list[QueryRequest] = []
        cur_rows = 0
        for r in reqs:
            if cur and cur_rows + r.n_rows > self.max_bucket:
                groups.append(cur)
                cur, cur_rows = [], 0
            cur.append(r)
            cur_rows += r.n_rows
        if cur:
            groups.append(cur)
        return groups

    def _execute_padded(
        self,
        reg: RegisteredQuery,
        fact_np: dict[str, np.ndarray],
        n: int,
        segments: Optional[tuple[np.ndarray, int]] = None,
    ):
        """Pad ``n`` fact rows to their bucket and run the stage graph."""
        bucket = row_bucket(n, self.min_bucket)
        fact: dict[str, jnp.ndarray] = {}
        for c in reg.scan_columns:
            col = fact_np[c]
            if len(col) < bucket:
                pad = np.zeros(bucket - len(col), dtype=col.dtype)
                col = np.concatenate([col, pad])
            fact[c] = jnp.asarray(col)
        row_valid = np.arange(bucket) < n
        if segments is not None:
            ids, k = segments
            if len(ids) < bucket:
                ids = np.concatenate(
                    [ids, np.zeros(bucket - len(ids), dtype=np.int32)]
                )
            segments = (ids, k)

        schema = tuple((c, str(reg.fact_dtypes[c])) for c in reg.scan_columns)
        key = (reg.compiled.fingerprint, schema, bucket)
        if key in self._seen_buckets:
            self.stats.bucket_hits += 1
        else:
            self.stats.bucket_misses += 1
            self._seen_buckets.add(key)

        def track_mid(stage_index: int, b: int) -> None:
            mid_key = (reg.compiled.fingerprint, stage_index, b)
            if mid_key in self._seen_mid_buckets:
                self.stats.mid_bucket_hits += 1
            else:
                self.stats.mid_bucket_misses += 1
                self._seen_mid_buckets.add(mid_key)

        db = dict(reg.database)
        db[reg.fact_table] = fact
        res = reg.compiled.run(
            db,
            row_valid=jnp.asarray(row_valid),
            params=reg.params if reg.param_names else None,
            segments=segments,
            bucketer=(
                (lambda m: row_bucket(m, self.min_bucket))
                if self.mid_bucketing else None
            ),
            on_mid_bucket=track_mid,
        )
        self.stats.batches_executed += 1
        self.stats.rows_padded += bucket - n
        return res

    def _finish(self, req: QueryRequest) -> None:
        req.done = True
        req.t_done = time.perf_counter()
        req._event.set()

    def _run_group(self, reg: RegisteredQuery, group: list[QueryRequest]) -> None:
        n = sum(r.n_rows for r in group)
        if reg.sliceable:
            cat = {
                c: np.concatenate([r.columns[c] for r in group])
                if len(group) > 1 else group[0].columns[c]
                for c in reg.scan_columns
            }
            # row-aligned output lets a spine wider than max_bucket run as
            # max_bucket-sized chunks, keeping the compiled-program count
            # bounded by log2(max_bucket / min_bucket) + 1 per query
            out_cols: dict[str, list[np.ndarray]] = {}
            out_valid: list[np.ndarray] = []
            for off in range(0, max(n, 1), self.max_bucket):
                span = min(self.max_bucket, n - off) if n else 0
                chunk = {c: v[off:off + span] for c, v in cat.items()}
                table = self._execute_padded(reg, chunk, span).table
                valid = np.asarray(table.valid)[:span]
                out_valid.append(valid)
                for k, v in table.columns.items():
                    out_cols.setdefault(k, []).append(np.asarray(v)[:span])
            cols = {k: np.concatenate(v) for k, v in out_cols.items()}
            valid = np.concatenate(out_valid)
            if len(group) > 1:
                self.stats.coalesced_requests += len(group)
            # output rows align 1:1 with the fact spine: slice each request's
            # span, then compact by its validity slice
            off = 0
            for r in group:
                sl = slice(off, off + r.n_rows)
                m = valid[sl]
                r.result = {k: v[sl][m] for k, v in cols.items()}
                self._finish(r)
                off += r.n_rows
        elif len(group) == 1:
            # a lone host-boundary/aggregate request: no splitting needed
            req = group[0]
            res = self._execute_padded(reg, req.columns, req.n_rows)
            req.result = res.table.to_numpy(compact=True)
            self._finish(req)
        else:
            # host boundaries compact data-dependently and aggregates fold
            # the spine, so positional slicing is impossible: thread
            # per-request segment ids through the stage graph instead
            cat = {
                c: np.concatenate([r.columns[c] for r in group])
                for c in reg.scan_columns
            }
            seg_ids = np.repeat(
                np.arange(len(group), dtype=np.int32),
                [r.n_rows for r in group],
            )
            res = self._execute_padded(
                reg, cat, n, segments=(seg_ids, len(group))
            )
            self.stats.coalesced_requests += len(group)
            self.stats.segmented_batches += 1
            cols = {k: np.asarray(v) for k, v in res.table.columns.items()}
            valid = np.asarray(res.table.valid)
            if reg.has_aggregate:
                # segmented fold: output row i belongs to request i
                for i, r in enumerate(group):
                    r.result = {k: v[i:i + 1] for k, v in cols.items()}
                    self._finish(r)
            else:
                seg = np.asarray(res.seg)
                for i, r in enumerate(group):
                    m = valid & (seg == i)
                    r.result = {k: v[m] for k, v in cols.items()}
                    self._finish(r)

    def recompiles(self) -> int:
        """Total XLA stage compiles across all registered queries."""
        return sum(r.compiled.traces for r in self.queries.values())
