"""Serving layer for prediction queries: optimize once, execute hot.

Raven's premise is that a prediction query is optimized *once* and then served
at high request rates, yet ``execute_plan`` alone re-derives everything per
call. ``PredictionQueryServer`` closes that gap:

  * ``register`` runs the :class:`RavenOptimizer` once per (query, stats)
    — structurally identical registrations share the optimized physical plan
    via the canonical query fingerprint — and compiles the plan into reusable
    stage executables through the engine's fingerprint-keyed plan cache.
  * Incoming batches are padded to a power-of-two row bucket with a validity
    mask (the engine's filters, joins, and aggregates are mask-aware), so any
    mix of request sizes hits at most ``log2(max_rows)`` compiled XLA
    programs per query instead of recompiling per shape.
  * ``submit``/``flush`` micro-batch: pending requests against the same query
    coalesce into one padded execution, with per-request result slicing off
    the shared fact spine.

The server is deliberately synchronous (like :class:`ServeEngine`): ``submit``
enqueues, ``flush`` drains, so tests and examples drive it deterministically;
a production loop would wrap it in an async request pump.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import fingerprint
from repro.core.ir import PredictionQuery
from repro.core.optimizer import OptimizationReport, OptimizerOptions, RavenOptimizer
from repro.errors import check_params
from repro.relational.engine import (
    Aggregate,
    CompiledPlan,
    PhysicalPlan,
    Scan,
    compile_plan,
    plan_params,
    walk_plan,
)
from repro.relational.table import Table


def row_bucket(n: int, min_bucket: int = 64) -> int:
    """Smallest power-of-two bucket holding ``n`` rows (≥ ``min_bucket``)."""
    b = max(int(min_bucket), 1)
    while b < n:
        b <<= 1
    return b


@dataclass
class QueryRequest:
    """One submitted batch; ``result`` is filled by ``flush``."""

    rid: int
    query: str
    columns: dict[str, np.ndarray]
    n_rows: int
    result: Optional[dict[str, np.ndarray]] = None
    done: bool = False


@dataclass
class ServerStats:
    queries_registered: int = 0
    plan_cache_hits: int = 0    # optimizer runs avoided via query fingerprint
    plan_cache_misses: int = 0
    bucket_hits: int = 0        # executions landing on an already-seen
    bucket_misses: int = 0      # (query, schema, bucket) combination
    batches_executed: int = 0
    requests_served: int = 0
    coalesced_requests: int = 0  # requests that shared a batch with others
    rows_in: int = 0
    rows_padded: int = 0

    def snapshot(self) -> dict[str, int]:
        return dict(self.__dict__)


@dataclass
class RegisteredQuery:
    name: str
    query_fingerprint: str
    plan: PhysicalPlan
    report: OptimizationReport
    compiled: CompiledPlan
    database: dict[str, dict[str, jnp.ndarray]]  # dims resident on device
    fact_table: str
    scan_columns: list[str]
    fact_dtypes: dict[str, np.dtype]
    has_aggregate: bool
    param_names: frozenset[str] = frozenset()
    params: dict[str, Any] = field(default_factory=dict)

    @property
    def recompiles(self) -> int:
        """XLA stage tracings attributable to this query's compiled plan."""
        return self.compiled.traces


class PredictionQueryServer:
    def __init__(
        self,
        strategy=None,
        options: Optional[OptimizerOptions] = None,
        *,
        min_bucket: int = 64,
        max_bucket: int = 1 << 20,
    ):
        self.optimizer = RavenOptimizer(strategy=strategy, options=options)
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.stats = ServerStats()
        self.queries: dict[str, RegisteredQuery] = {}
        self._optimized: dict[str, tuple[PhysicalPlan, OptimizationReport]] = {}
        self._pins: list[Any] = []  # keeps identity-hashed objects alive
        self._seen_buckets: set[tuple[str, tuple, int]] = set()
        self._rid = itertools.count()
        self._pending: list[QueryRequest] = []

    # -- registration --------------------------------------------------------

    def register(
        self,
        name: str,
        query: PredictionQuery,
        database: dict[str, dict[str, np.ndarray]],
        fact_table: Optional[str] = None,
        *,
        optimized: Optional[tuple[PhysicalPlan, OptimizationReport]] = None,
        params: Optional[dict[str, Any]] = None,
    ) -> RegisteredQuery:
        """Optimize + compile ``query`` and make it servable under ``name``.

        ``database`` supplies the dimension tables (kept device-resident) and
        the fact table's schema; serve-time batches replace the fact rows.
        ``optimized`` seeds the (plan, report) for a query the caller already
        optimized (the session front door's PreparedQuery path), keyed under
        the same fingerprint the server would compute itself. ``params``
        binds the query's ``:param`` placeholders; re-bind via :meth:`rebind`
        without touching the compiled plan.
        """
        if optimized is not None:
            # externally optimized (the session's PreparedQuery path): the
            # caller's optimizer options may differ from this server's, so
            # key on the supplied physical plan rather than seeding the
            # (query, server-options) cache with a foreign plan. Neither a
            # cache hit nor a miss — no optimizer run happened here.
            plan, report = optimized
            qfp = fingerprint(
                query.plan, query.stats, "external", pins=self._pins,
            )
        else:
            qfp = fingerprint(
                query.plan, query.stats, self.optimizer.options,
                self.optimizer.strategy, pins=self._pins,
            )
            cached = self._optimized.get(qfp)
            if cached is not None:
                self.stats.plan_cache_hits += 1
                plan, report = cached
            else:
                self.stats.plan_cache_misses += 1
                plan, report = self.optimizer.optimize(query)
                self._optimized[qfp] = (plan, report)
        compiled = compile_plan(plan)
        param_names = frozenset(plan_params(plan))
        bound = dict(params or {})
        check_params(param_names, bound, context=f"query '{name}'")

        scans = [p for p in walk_plan(plan) if isinstance(p, Scan)]
        if fact_table is None:
            fact_table = scans[0].table
        if fact_table not in database:
            raise KeyError(f"fact table '{fact_table}' missing from database")
        scan_columns = [c for s in scans if s.table == fact_table for c in s.columns]
        db = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in database.items()
            if t != fact_table
        }
        reg = RegisteredQuery(
            name=name,
            query_fingerprint=qfp,
            plan=plan,
            report=report,
            compiled=compiled,
            database=db,
            fact_table=fact_table,
            scan_columns=scan_columns,
            fact_dtypes={
                c: np.asarray(database[fact_table][c]).dtype
                for c in scan_columns
            },
            has_aggregate=any(isinstance(p, Aggregate) for p in walk_plan(plan)),
            param_names=param_names,
            params={k: jnp.asarray(v, jnp.float32) for k, v in bound.items()},
        )
        self.queries[name] = reg
        self.stats.queries_registered += 1
        return reg

    def rebind(self, name: str, params: dict[str, Any]) -> RegisteredQuery:
        """Re-bind ``:param`` values for a registered query.

        Fingerprint-stable: the optimized plan, compiled stages, and shape
        buckets are untouched — the new values simply flow into the next
        execution as runtime inputs (zero new XLA traces).
        """
        if name not in self.queries:
            raise KeyError(f"no registered query named '{name}'")
        reg = self.queries[name]
        check_params(
            reg.param_names, params, require_all=False, context=f"query '{name}'"
        )
        reg.params.update(
            {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
        )
        return reg

    # -- request lifecycle ---------------------------------------------------

    def submit(self, name: str, columns: dict[str, np.ndarray]) -> QueryRequest:
        """Enqueue one batch of fact rows for ``name``; run via ``flush``."""
        reg = self.queries[name]
        missing = [c for c in reg.scan_columns if c not in columns]
        if missing:
            raise KeyError(f"batch for '{name}' missing columns {missing}")
        # normalize dtypes to the registered schema so every bucket-sized
        # batch maps onto the same compiled program
        cols = {
            c: np.asarray(columns[c]).astype(reg.fact_dtypes[c], copy=False)
            for c in reg.scan_columns
        }
        lengths = {len(v) for v in cols.values()}
        if len(lengths) > 1:
            raise ValueError(
                f"batch for '{name}' has ragged columns: "
                f"{ {c: len(v) for c, v in cols.items()} }"
            )
        n = lengths.pop() if lengths else 0
        req = QueryRequest(
            rid=next(self._rid), query=name, columns=cols, n_rows=n,
        )
        self._pending.append(req)
        self.stats.rows_in += n
        return req

    def flush(self) -> list[QueryRequest]:
        """Execute all pending requests (coalescing per query) and return
        them with results filled."""
        pending, self._pending = self._pending, []
        by_query: dict[str, list[QueryRequest]] = {}
        for r in pending:
            by_query.setdefault(r.query, []).append(r)
        for name, reqs in by_query.items():
            reg = self.queries[name]
            if reg.compiled.is_pure and not reg.has_aggregate:
                for group in self._coalesce(reqs):
                    self._run_group(reg, group)
            else:
                # aggregates fold the whole spine into one row, and host
                # (UDF) boundaries compact data-dependently: neither can be
                # sliced back per request, so these run one batch at a time
                for r in reqs:
                    self._run_group(reg, [r])
        self.stats.requests_served += len(pending)
        return pending

    def execute(
        self, name: str, columns: dict[str, np.ndarray]
    ) -> dict[str, np.ndarray]:
        """One-shot convenience: submit + flush + return the result."""
        req = self.submit(name, columns)
        self.flush()
        return req.result

    # -- internals -----------------------------------------------------------

    def _coalesce(self, reqs: list[QueryRequest]) -> list[list[QueryRequest]]:
        """Pack pending requests into shared executions ≤ ``max_bucket``."""
        groups: list[list[QueryRequest]] = []
        cur: list[QueryRequest] = []
        cur_rows = 0
        for r in reqs:
            if cur and cur_rows + r.n_rows > self.max_bucket:
                groups.append(cur)
                cur, cur_rows = [], 0
            cur.append(r)
            cur_rows += r.n_rows
        if cur:
            groups.append(cur)
        return groups

    def _execute_padded(
        self, reg: RegisteredQuery, fact_np: dict[str, np.ndarray], n: int
    ) -> "Table":
        """Pad ``n`` fact rows to their bucket and run the compiled plan."""
        bucket = row_bucket(n, self.min_bucket)
        fact: dict[str, jnp.ndarray] = {}
        for c in reg.scan_columns:
            col = fact_np[c]
            if len(col) < bucket:
                pad = np.zeros(bucket - len(col), dtype=col.dtype)
                col = np.concatenate([col, pad])
            fact[c] = jnp.asarray(col)
        row_valid = np.arange(bucket) < n

        schema = tuple((c, str(reg.fact_dtypes[c])) for c in reg.scan_columns)
        key = (reg.compiled.fingerprint, schema, bucket)
        if key in self._seen_buckets:
            self.stats.bucket_hits += 1
        else:
            self.stats.bucket_misses += 1
            self._seen_buckets.add(key)

        db = dict(reg.database)
        db[reg.fact_table] = fact
        table = reg.compiled(
            db, row_valid=jnp.asarray(row_valid),
            params=reg.params if reg.param_names else None,
        )
        self.stats.batches_executed += 1
        self.stats.rows_padded += bucket - n
        return table

    def _run_group(self, reg: RegisteredQuery, group: list[QueryRequest]) -> None:
        n = sum(r.n_rows for r in group)
        if reg.compiled.is_pure and not reg.has_aggregate:
            cat = {
                c: np.concatenate([r.columns[c] for r in group])
                if len(group) > 1 else group[0].columns[c]
                for c in reg.scan_columns
            }
            # row-aligned output lets a spine wider than max_bucket run as
            # max_bucket-sized chunks, keeping the compiled-program count
            # bounded by log2(max_bucket / min_bucket) + 1 per query
            out_cols: dict[str, list[np.ndarray]] = {}
            out_valid: list[np.ndarray] = []
            for off in range(0, max(n, 1), self.max_bucket):
                span = min(self.max_bucket, n - off) if n else 0
                chunk = {c: v[off:off + span] for c, v in cat.items()}
                table = self._execute_padded(reg, chunk, span)
                valid = np.asarray(table.valid)[:span]
                out_valid.append(valid)
                for k, v in table.columns.items():
                    out_cols.setdefault(k, []).append(np.asarray(v)[:span])
            cols = {k: np.concatenate(v) for k, v in out_cols.items()}
            valid = np.concatenate(out_valid)
            if len(group) > 1:
                self.stats.coalesced_requests += len(group)
            # output rows align 1:1 with the fact spine: slice each request's
            # span, then compact by its validity slice
            off = 0
            for r in group:
                sl = slice(off, off + r.n_rows)
                m = valid[sl]
                r.result = {k: v[sl][m] for k, v in cols.items()}
                r.done = True
                off += r.n_rows
        else:
            # aggregates fold the spine into one row and UDF boundaries
            # compact data-dependently: no chunking, whole batch at once
            assert len(group) == 1
            req = group[0]
            table = self._execute_padded(reg, req.columns, req.n_rows)
            req.result = table.to_numpy(compact=True)
            req.done = True

    def recompiles(self) -> int:
        """Total XLA stage compiles across all registered queries."""
        return sum(r.compiled.traces for r in self.queries.values())
