"""Serving layer for prediction queries: optimize once, execute hot.

Raven's premise is that a prediction query is optimized *once* and then served
at high request rates, yet ``execute_plan`` alone re-derives everything per
call. ``PredictionQueryServer`` closes that gap on top of the StageGraph IR:

  * ``register`` runs the :class:`RavenOptimizer` once per (query, stats)
    — structurally identical registrations share the optimized physical plan
    via the canonical query fingerprint — and compiles the plan into a
    reusable stage graph through the engine's fingerprint-keyed plan cache.
  * Incoming batches are padded to a power-of-two row bucket with a validity
    mask at **every pure-stage boundary**: query entry *and* each MLUdf host
    boundary's exit, so post-UDF stages stop re-tracing on data-dependent
    shape churn.
  * ``submit``/``flush`` micro-batch: pending requests against the same query
    coalesce into one padded execution. Pure row-aligned plans are sliced
    back by position; host-boundary and aggregate plans thread per-request
    *segment ids* through the graph (compaction-proof) and split on them.
  * Request scheduling is a :class:`~repro.exec.scheduler.Scheduler`: every
    query gets its own bounded queue (``max_pending`` backpressure raising
    :class:`~repro.errors.ServerOverloadedError`), its own latency target,
    and a coalesce-width cap; the background pump flushes queues
    earliest-deadline-first so a small latency-sensitive query is never
    starved behind a bulk one.
  * Dispatched groups execute through the **pipelined**
    :class:`~repro.exec.pipeline.PipelineExecutor`: pure stages dispatch to
    the device asynchronously and MLUdf boundaries run on a boundary thread
    pool, so one group's host work overlaps another group's device work
    (``pipelined=False`` restores the serial stage-at-a-time runner for
    A/B measurement).

Without a pump the server stays synchronous — ``submit`` enqueues, ``flush``
drains — so tests and examples can drive it deterministically.

**Versioned routing** (the model-lifecycle layer): every registration owns a
:class:`QueryRoute` that can hold *several* :class:`RegisteredQuery`
versions of the same serve name — one live, others staged. ``stage_version``
compiles an incoming version without touching routing, ``warm_version``
replays the route's observed bucket ladder through it (so its programs are
compiled *before* any traffic reaches them), and ``cutover`` atomically
swaps the routed version under the scheduler lock: groups already dispatched
hold their version's registration and complete on it, groups popped after
the swap run the new one — zero dropped requests, zero re-traces when the
incoming version is warm. ``set_shadow`` mirrors every coalesced group
through a staged version whose results are diffed and counted but never
returned; ``set_split`` routes a deterministic percentage of groups to
staged versions (smooth weighted round-robin, per-version stats). The
route-level token keeps submit handles valid across cutovers — only a true
re-``register`` (new plan under the same name) invalidates them.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import asserts_enabled, runtime_assert
from repro.analysis.verifier import (
    check_exec,
    check_graph,
    enforce,
    resolve_verify_mode,
)
from repro.core.fingerprint import fingerprint
from repro.core.ir import PredictionQuery
from repro.core.optimizer import OptimizationReport, OptimizerOptions, RavenOptimizer
from repro.errors import (
    RavenError,
    RegistryStateError,
    RequestTimeoutError,
    StaleQueryError,
    TransientError,
    UnknownModelVersionError,
    UnknownQueryError,
    check_params,
)
from repro.exec.faults import RetryPolicy, get_fault_plan, maybe_inject
from repro.exec.pipeline import PipelineExecutor
from repro.exec.scheduler import Scheduler
from repro.exec.stages import seg_bucket
from repro.relational.engine import (
    Aggregate,
    CompiledPlan,
    PhysicalPlan,
    Scan,
    compile_plan,
    plan_params,
    walk_plan,
)


def row_bucket(n: int, min_bucket: int = 64) -> int:
    """Smallest power-of-two bucket holding ``n`` rows (≥ ``min_bucket``)."""
    b = max(int(min_bucket), 1)
    while b < n:
        b <<= 1
    return b


def canonical_dtype(dt: np.dtype) -> np.dtype:
    """The dtype a column actually runs under on device (x64 disabled).

    Registered schemas and submitted batches are normalized to this *at
    submit time*, on the submitter's thread: converting float64 → float32
    during ``jnp.asarray`` is an element-wise cast, and paying it per group
    on the scheduler thread serializes the whole server behind it. After
    normalization the serving path's host→device transfers are plain
    memcpys.
    """
    dt = np.dtype(dt)
    if dt.kind == "f" and dt.itemsize > 4:
        return np.dtype(np.float32)
    if dt.kind in "iu" and dt.itemsize > 4:
        return np.dtype(np.int32)
    return dt


@dataclass
class QueryRequest:
    """One submitted batch; ``result`` is filled by ``flush`` (or the pump)."""

    rid: int
    query: str
    columns: dict[str, np.ndarray]
    n_rows: int
    served_by: str = ""  # version label of the registration that served it
    result: Optional[dict[str, np.ndarray]] = None
    done: bool = False
    error: Optional[BaseException] = None  # execution failure, re-raised by wait()
    t_submit: float = 0.0
    t_done: float = 0.0
    _event: threading.Event = field(
        default_factory=threading.Event, repr=False, compare=False
    )

    def wait(self, timeout: Optional[float] = None) -> dict[str, np.ndarray]:
        """Block until this request's result is ready (pump-driven serving)
        and return it; re-raises the execution error if its batch failed.

        An expired ``timeout`` raises the typed
        :class:`~repro.errors.RequestTimeoutError` — the caller can tell "the
        server never answered" apart from "the server answered with a
        failure" (typed Raven errors re-raise as themselves; foreign
        exceptions are wrapped so the waiter always sees a
        :class:`~repro.errors.RavenError`)."""
        if not self._event.wait(timeout):
            raise RequestTimeoutError(
                f"request {self.rid} for query '{self.query}' not served "
                f"within {timeout}s — is a pump running / was flush() called?"
            )
        if self.error is not None:
            if isinstance(self.error, RavenError):
                raise self.error
            raise RavenError(
                f"request {self.rid} for query '{self.query}' failed during "
                f"execution: {self.error}"
            ) from self.error
        return self.result

    @property
    def latency_s(self) -> float:
        """Submit-to-result wall time (0.0 until served)."""
        return (self.t_done - self.t_submit) if self.done else 0.0


@dataclass
class ServerStats:
    queries_registered: int = 0
    plan_cache_hits: int = 0    # optimizer runs avoided via query fingerprint
    plan_cache_misses: int = 0
    bucket_hits: int = 0        # executions landing on an already-seen
    bucket_misses: int = 0      # (query, schema, bucket) combination
    mid_bucket_hits: int = 0    # host-boundary exits landing on an already-
    mid_bucket_misses: int = 0  # seen (query, stage, bucket) combination
    warm_started_buckets: int = 0  # bucket programs preloaded from the
    #                                artifact store at registration time
    batches_executed: int = 0
    requests_served: int = 0
    coalesced_requests: int = 0  # requests that shared a batch with others
    segmented_batches: int = 0   # coalesced executions split by segment ids
    pipelined_groups: int = 0    # groups dispatched through the async path
    flushes: int = 0             # dispatched request groups
    rows_in: int = 0
    rows_padded: int = 0
    cutovers: int = 0            # atomic version swaps completed
    shadow_mirrored_groups: int = 0  # groups mirrored to a shadow version
    warm_replayed_buckets: int = 0   # ladder entries replayed by warm_version
    breaker_trips: int = 0       # registrations degraded to the fallback plan

    def snapshot(self) -> dict[str, int]:
        return dict(self.__dict__)


@dataclass
class VersionStats:
    """Per-version serving counters, kept on the :class:`QueryRoute`."""

    groups: int = 0              # dispatched groups this version executed
    requests: int = 0
    rows: int = 0
    errors: int = 0              # dispatched groups that failed on this
    #                              version — counted even when the scheduler
    #                              retried the group to success, so a rollback
    #                              guard sees trouble before users do
    shadow_groups: int = 0       # mirrored groups this version scored
    shadow_rows: int = 0         # mirrored rows compared against the primary
    shadow_diff_rows: int = 0    # compared rows that were not bitwise equal
    shadow_max_abs_diff: float = 0.0  # largest numeric divergence observed
    shadow_errors: int = 0       # mirrored executions that raised (contained)

    def snapshot(self) -> dict[str, Any]:
        return dict(self.__dict__)


@dataclass
class RegisteredQuery:
    name: str
    token: str  # unique per registration: the stale-handle guard key
    query_fingerprint: str
    plan: PhysicalPlan
    report: OptimizationReport
    compiled: CompiledPlan
    database: dict[str, dict[str, jnp.ndarray]]  # dims resident on device
    fact_table: str
    scan_columns: list[str]
    fact_dtypes: dict[str, np.dtype]
    has_aggregate: bool
    param_names: frozenset[str] = frozenset()
    params: dict[str, Any] = field(default_factory=dict)
    version_label: str = "v1"     # which model version this registration runs
    donate: bool = True           # donate padded entry buffers to XLA
    warmed: bool = False          # warm_version covered the route ladder
    # (bucket, seg_slots) entries this registration has executed or replayed
    # — the per-version warm coverage the cutover gate checks
    warmed_ladder: set = field(default_factory=set)
    # circuit breaker: `breaker_threshold` consecutive dispatch failures trip
    # this registration onto a fallback plan compiled with the relational
    # kernels disabled (fingerprint-forked; bitwise-identical results per the
    # kernel parity contract) — a persistent kernel/compile fault degrades
    # the query instead of failing every request forever
    breaker_threshold: int = 3
    breaker_failures: int = 0     # consecutive failures; reset on success
    breaker_trips: int = 0
    degraded: bool = False
    fallback: Optional[CompiledPlan] = None

    @property
    def active(self) -> CompiledPlan:
        """The plan serving this registration's traffic right now: the
        kernel-free fallback once the breaker tripped (and its compile
        landed), the primary compiled plan otherwise."""
        fb = self.fallback
        return fb if (self.degraded and fb is not None) else self.compiled

    @property
    def recompiles(self) -> int:
        """XLA stage tracings attributable to this query's compiled plan
        (fallback included once the breaker tripped)."""
        fb = self.fallback
        return self.compiled.traces + (fb.traces if fb is not None else 0)

    @property
    def sliceable(self) -> bool:
        """Coalesced output rows stay 1:1 aligned with the input spine, so
        per-request results fall out of positional slicing — no segment ids
        needed. False once a host boundary (compaction) or an aggregate
        (folding) breaks the alignment."""
        return self.compiled.is_pure and not self.has_aggregate


@dataclass
class QueryRoute:
    """Versioned routing state for one serve name.

    The ``token`` lives here, not on any one registration: submit handles
    stay valid across cutovers (the whole point of a hot swap) and only a
    fresh ``register`` under the same name — a genuinely different query —
    mints a new token and stales old handles. ``ladder`` records every
    (row bucket, segment-slot bucket) combination this route has executed;
    it is exactly what ``warm_version`` must replay through an incoming
    version for a zero-retrace cutover.
    """

    name: str
    token: str
    live: str                                     # live version label
    versions: dict[str, RegisteredQuery] = field(default_factory=dict)
    shadow: Optional[str] = None                  # mirrored version label
    split: dict[str, float] = field(default_factory=dict)  # label -> fraction
    stats: dict[str, VersionStats] = field(default_factory=dict)
    ladder: set = field(default_factory=set)      # (bucket, seg_slots) seen
    # columns a submitted batch must carry: the union of scan columns over
    # every version that can currently receive traffic (live, shadow, split)
    required: set = field(default_factory=set)
    cutovers: int = 0
    # entries the last cutover's incoming version had NOT warmed (nonzero
    # only when forced with require_warm=False); the registry-warm analysis
    # rule asserts this stayed zero
    last_cutover_deficit: int = 0
    _wrr: dict[str, float] = field(default_factory=dict)  # smooth-WRR credit
    # per-version rolling request latencies (ms, bounded window) — the p99
    # signal the registry's rollback guard compares against its baseline
    latencies: dict[str, deque] = field(default_factory=dict, repr=False)

    def version_stats(self, label: str) -> VersionStats:
        st = self.stats.get(label)
        if st is None:
            st = self.stats[label] = VersionStats()
        return st

    def record_latency(self, label: str, ms: float) -> None:
        dq = self.latencies.get(label)
        if dq is None:
            dq = self.latencies[label] = deque(maxlen=256)
        dq.append(float(ms))

    def p99_ms(self, label: str) -> float:
        """p99 over the version's rolling latency window (0.0 when empty)."""
        xs = sorted(self.latencies.get(label) or ())
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(len(xs) * 0.99))]

    def snapshot(self) -> dict[str, Any]:
        return {
            "live": self.live,
            "shadow": self.shadow,
            "split": dict(self.split),
            "cutovers": self.cutovers,
            "last_cutover_deficit": self.last_cutover_deficit,
            "ladder": sorted(self.ladder),
            "versions": {
                label: {
                    "plan_fingerprint": reg.compiled.fingerprint,
                    "warmed": reg.warmed,
                    "traces": reg.compiled.traces,
                    "degraded": reg.degraded,
                    "breaker_failures": reg.breaker_failures,
                    "breaker_trips": reg.breaker_trips,
                    "fallback_traces": (
                        reg.fallback.traces if reg.fallback is not None else 0
                    ),
                    "p99_ms": self.p99_ms(label),
                    **self.version_stats(label).snapshot(),
                }
                for label, reg in self.versions.items()
            },
        }


class PredictionQueryServer:
    def __init__(
        self,
        strategy=None,
        options: Optional[OptimizerOptions] = None,
        *,
        min_bucket: int = 64,
        max_bucket: int = 1 << 20,
        mid_bucketing: bool = True,
        pipelined: bool = True,
        boundary_workers: int = 2,
        max_inflight: int = 4,
    ):
        self.optimizer = RavenOptimizer(strategy=strategy, options=options)
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        # pad host-boundary outputs to power-of-two buckets before the next
        # pure stage (False reproduces the old exact-shape post-UDF path —
        # kept for A/B benchmarks)
        self.mid_bucketing = mid_bucketing
        # pipelined=False restores the serial stage-at-a-time group runner
        # (the baseline the mixed-workload benchmark measures against)
        self.pipelined = pipelined
        self.stats = ServerStats()
        self.queries: dict[str, RegisteredQuery] = {}  # live registrations
        self.routes: dict[str, QueryRoute] = {}        # versioned routing
        self.executor = PipelineExecutor(workers=boundary_workers)
        self.scheduler = Scheduler(
            self._dispatch_group,
            default_coalesce=max_bucket,
            max_inflight=max_inflight,
            # terminal-failure delivery: when a group exhausts its retries
            # (or fails deterministically) every waiter gets the typed error
            fail=self._fail_group,
        )
        self._optimized: dict[str, tuple[PhysicalPlan, OptimizationReport]] = {}
        self._pins: list[Any] = []  # keeps identity-hashed objects alive
        self._seen_buckets: set[tuple[str, tuple, int]] = set()
        self._seen_mid_buckets: set[tuple[str, int, int]] = set()
        self._rid = itertools.count()
        self._reg_serial = itertools.count()
        self._lock = threading.Lock()  # guards stats/seen-bucket mutation

    # -- registration --------------------------------------------------------

    def register(
        self,
        name: str,
        query: PredictionQuery,
        database: dict[str, dict[str, np.ndarray]],
        fact_table: Optional[str] = None,
        *,
        optimized: Optional[tuple[PhysicalPlan, OptimizationReport]] = None,
        params: Optional[dict[str, Any]] = None,
        max_latency_ms: Optional[float] = None,
        max_pending: Optional[int] = None,
        max_coalesce: Optional[int] = None,
        version_label: str = "v1",
        donate: bool = True,
        retry: Optional[RetryPolicy] = None,
        breaker_threshold: Optional[int] = None,
    ) -> RegisteredQuery:
        """Optimize + compile ``query`` and make it servable under ``name``.

        ``database`` supplies the dimension tables (kept device-resident) and
        the fact table's schema; serve-time batches replace the fact rows.
        ``optimized`` seeds the (plan, report) for a query the caller already
        optimized (the session front door's PreparedQuery path), keyed under
        the same fingerprint the server would compute itself. ``params``
        binds the query's ``:param`` placeholders; re-bind via :meth:`rebind`
        without touching the compiled plan.

        The scheduling knobs configure this query's scheduler queue:
        ``max_latency_ms`` its flush deadline (earliest-deadline-first across
        queries), ``max_pending`` its backpressure bound (a submit against a
        full queue blocks or raises
        :class:`~repro.errors.ServerOverloadedError`), ``max_coalesce`` the
        most rows one dispatched group may take (so a bulk backlog cannot
        monopolize a flush).

        ``version_label`` names this registration in the versioned route
        created for ``name`` (further versions arrive via
        :meth:`stage_version`); ``donate=False`` keeps the padded entry
        buffers un-donated for this query. Re-registering an existing name
        replaces its whole route and mints a new token — outstanding submit
        handles go stale, which is the intended guard against serving a
        structurally different query through an old handle.

        ``retry`` overrides the scheduler's default
        :class:`~repro.exec.faults.RetryPolicy` for this queue;
        ``breaker_threshold`` the consecutive-failure count that trips this
        query's circuit breaker onto the kernel-free fallback plan.
        """
        token = f"route#{next(self._reg_serial)}"
        reg = self._build_registration(
            name, query, database, fact_table,
            optimized=optimized, params=params, token=token,
            version_label=version_label, donate=donate,
        )
        if breaker_threshold is not None:
            reg.breaker_threshold = max(1, int(breaker_threshold))
        route = QueryRoute(name=name, token=token, live=version_label)
        route.versions[version_label] = reg
        route.required = set(reg.scan_columns)
        with self._lock:
            self.routes[name] = route
            self.queries[name] = reg
        self.scheduler.configure(
            name, max_latency_ms=max_latency_ms, max_pending=max_pending,
            max_coalesce=max_coalesce, retry=retry,
        )
        with self._lock:
            self.stats.queries_registered += 1
        return reg

    def _build_registration(
        self,
        name: str,
        query: PredictionQuery,
        database: dict[str, dict[str, np.ndarray]],
        fact_table: Optional[str] = None,
        *,
        optimized: Optional[tuple[PhysicalPlan, OptimizationReport]] = None,
        params: Optional[dict[str, Any]] = None,
        token: str = "",
        version_label: str = "v1",
        donate: bool = True,
    ) -> RegisteredQuery:
        """Optimize/compile/verify/warm-start one version's registration
        (shared by :meth:`register` and :meth:`stage_version`); installs no
        routing state."""
        if optimized is not None:
            # externally optimized (the session's PreparedQuery path): the
            # caller's optimizer options may differ from this server's, so
            # key on the supplied physical plan rather than seeding the
            # (query, server-options) cache with a foreign plan. Neither a
            # cache hit nor a miss — no optimizer run happened here.
            plan, report = optimized
            qfp = fingerprint(
                query.plan, query.stats, "external", pins=self._pins,
            )
        else:
            qfp = fingerprint(
                query.plan, query.stats, self.optimizer.options,
                self.optimizer.strategy, pins=self._pins,
            )
            cached = self._optimized.get(qfp)
            if cached is not None:
                with self._lock:
                    self.stats.plan_cache_hits += 1
                plan, report = cached
            else:
                with self._lock:
                    self.stats.plan_cache_misses += 1
                plan, report = self.optimizer.optimize(query)
                self._optimized[qfp] = (plan, report)
        compiled = compile_plan(plan)
        verify_mode = resolve_verify_mode(
            getattr(self.optimizer.options, "verify", None)
        )
        if verify_mode != "off":
            # the disk plan-cache path skips the optimizer's differential
            # checks, so the server re-verifies the graph it will actually
            # serve — including abstract execution against the registered
            # database schema (bucket polymorphism, dtype stability)
            vs = check_graph(compiled.graph)
            vs += check_exec(compiled.graph, database)
            lines = enforce(vs, verify_mode, f"register '{name}'")
            if lines and report is not None:
                report.verification += [
                    ln for ln in lines if ln not in report.verification
                ]
        # warm start: deserialize every AOT-exported bucket program the
        # artifact store holds for this plan's stages, so previously-served
        # shapes run with zero new XLA traces from the very first submit
        from repro.relational.engine import get_artifact_store

        if get_artifact_store() is not None:
            warmed = compiled.warm_start()
            with self._lock:
                self.stats.warm_started_buckets += warmed
        param_names = frozenset(plan_params(plan))
        bound = dict(params or {})
        check_params(param_names, bound, context=f"query '{name}'")

        scans = [p for p in walk_plan(plan) if isinstance(p, Scan)]
        if fact_table is None:
            fact_table = scans[0].table
        if fact_table not in database:
            raise KeyError(f"fact table '{fact_table}' missing from database")
        scan_columns = [c for s in scans if s.table == fact_table for c in s.columns]
        db = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in database.items()
            if t != fact_table
        }
        return RegisteredQuery(
            name=name,
            # plan fingerprints are deliberately invariant under :param
            # values (rebinding must not recompile), so a handle guard keyed
            # on them alone would miss a re-registration that only changed
            # bound params; the route-level serial token closes that hole —
            # and, unlike a per-registration token, survives version cutovers
            token=token,
            query_fingerprint=qfp,
            plan=plan,
            report=report,
            compiled=compiled,
            database=db,
            fact_table=fact_table,
            scan_columns=scan_columns,
            # the *full* registered fact schema, not just this plan's scan
            # columns: submit normalizes every provided fact column against
            # it, so a staged version whose optimizer pruned a different
            # subset (a retrained tree reads different splits; a model-family
            # change reads different features) can serve the same queue
            fact_dtypes={
                c: canonical_dtype(np.asarray(database[fact_table][c]).dtype)
                for c in database[fact_table]
            },
            has_aggregate=any(isinstance(p, Aggregate) for p in walk_plan(plan)),
            param_names=param_names,
            params={k: jnp.asarray(v, jnp.float32) for k, v in bound.items()},
            version_label=version_label,
            donate=donate,
        )

    def rebind(self, name: str, params: dict[str, Any]) -> RegisteredQuery:
        """Re-bind ``:param`` values for a registered query.

        Fingerprint-stable: the optimized plan, compiled stages, and shape
        buckets are untouched — the new values simply flow into the next
        execution as runtime inputs (zero new XLA traces). Applied to
        *every* version on the route: parameter values are plan-invariant,
        so a staged or shadow version must score the same binding the live
        one answers with.
        """
        reg = self._registered(name)
        check_params(
            reg.param_names, params, require_all=False, context=f"query '{name}'"
        )
        jvals = {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
        with self._lock:
            route = self.routes.get(name)
            regs = list(route.versions.values()) if route is not None else [reg]
        for r in regs:
            r.params.update(jvals)
        return reg

    # -- model-version lifecycle ---------------------------------------------

    def _route(self, name: str) -> QueryRoute:
        route = self.routes.get(name)
        if route is None:
            raise UnknownQueryError(
                f"no query registered under '{name}' — registered: "
                f"{sorted(self.routes) or '(none)'}"
            )
        return route

    def _version(self, route: QueryRoute, label: str) -> RegisteredQuery:
        reg = route.versions.get(label)
        if reg is None:
            raise UnknownModelVersionError(
                f"route '{route.name}' has no staged version {label!r} — "
                f"staged: {sorted(route.versions)}"
            )
        return reg

    @staticmethod
    def _refresh_required(route: QueryRoute) -> None:
        """Recompute the submit-time required column set (caller holds the
        server lock): the union over every version currently routable —
        live, shadow, and split targets."""
        labels = {route.live, *route.split}
        if route.shadow is not None:
            labels.add(route.shadow)
        route.required = {
            c for lb in labels for c in route.versions[lb].scan_columns
        }

    def stage_version(
        self,
        name: str,
        query: PredictionQuery,
        database: dict[str, dict[str, np.ndarray]],
        *,
        version_label: str,
        optimized: Optional[tuple[PhysicalPlan, OptimizationReport]] = None,
        params: Optional[dict[str, Any]] = None,
    ) -> RegisteredQuery:
        """Compile an incoming version for ``name`` without touching routing.

        The staged registration shares the route's token and fact table;
        its scan columns may differ from the live version's (a retrained
        model reads different features) but must stay inside the fact
        schema the route was registered over, with identical canonical
        dtypes — submitted batches are validated and normalized against
        that schema, so every routable version can serve the same queue.
        When an artifact store is active the compiled stages warm-start
        from disk here; live bucket coverage comes from
        :meth:`warm_version`.
        """
        route = self._route(name)
        live = self._version(route, route.live)
        reg = self._build_registration(
            name, query, database, live.fact_table,
            optimized=optimized,
            params=params if params is not None else dict(live.params),
            token=route.token, version_label=version_label,
            donate=live.donate,
        )
        outside = sorted(set(reg.scan_columns) - set(live.fact_dtypes))
        if outside:
            raise RegistryStateError(
                f"version {version_label!r} of '{name}' reads columns "
                f"{outside} outside the fact schema the route was "
                f"registered over — re-serve the query instead"
            )
        drift = {
            c: (str(reg.fact_dtypes[c]), str(live.fact_dtypes[c]))
            for c in reg.scan_columns
            if reg.fact_dtypes[c] != live.fact_dtypes[c]
        }
        if drift:
            raise RegistryStateError(
                f"version {version_label!r} of '{name}' disagrees with the "
                f"route's registered submit dtypes: {drift}"
            )
        with self._lock:
            reg.breaker_threshold = live.breaker_threshold
            route.versions[version_label] = reg
            route.version_stats(version_label)  # materialize the counter row
        return reg

    def warm_version(self, name: str, version_label: str) -> int:
        """Replay the route's observed bucket ladder through a staged
        version so every (row bucket, segment-slot) program it will serve is
        compiled *now*, off the request path — the zero-retrace guarantee an
        atomic cutover depends on. Returns the number of ladder entries
        replayed; marks the version warm.

        Replay goes through the exact ``_padded_kwargs`` path real traffic
        takes (zero-filled rows, all-valid mask), so the jit specializations
        it creates are byte-identical to the ones post-cutover traffic
        requests — and, with an artifact store active, each replayed bucket
        is AOT-exported for the next process too.
        """
        route = self._route(name)
        reg = self._version(route, version_label)
        with self._lock:
            ladder = set(route.ladder) or {(self.min_bucket, 0)}
            pending = sorted(ladder - reg.warmed_ladder)
        replayed = 0
        for bucket, seg_slots in pending:
            fact = {
                c: np.zeros(bucket, dtype=reg.fact_dtypes[c])
                for c in reg.scan_columns
            }
            segments = None
            if seg_slots:
                segments = (np.zeros(bucket, dtype=np.int32), seg_slots)
            self._execute_padded(reg, fact, bucket, segments=segments)
            replayed += 1
        with self._lock:
            reg.warmed = True
            self.stats.warm_replayed_buckets += replayed
        return replayed

    def set_shadow(
        self, name: str, version_label: Optional[str]
    ) -> None:
        """Mirror every coalesced group for ``name`` through a staged
        version (None disables). The shadow scores the same padded batch on
        a boundary-pool thread, its results are diffed against the primary's
        and counted in the route's per-version stats — and are never
        attached to any request."""
        route = self._route(name)
        if version_label is not None:
            self._version(route, version_label)
        with self._lock:
            route.shadow = version_label
            self._refresh_required(route)

    def set_split(self, name: str, split: dict[str, float]) -> None:
        """Route a fraction of dispatched groups to staged versions.

        ``split`` maps version labels to fractions in [0, 1); the live
        version serves the remainder. Selection is smooth weighted
        round-robin — deterministic, no RNG — so a 0.25 split sends exactly
        one group in four to the staged version. Pass ``{}`` to clear."""
        route = self._route(name)
        total = 0.0
        for label, frac in split.items():
            self._version(route, label)
            if not 0.0 <= frac < 1.0:
                raise RegistryStateError(
                    f"split fraction for {label!r} must be in [0, 1), "
                    f"got {frac}"
                )
            if label == route.live:
                raise RegistryStateError(
                    f"{label!r} is the live version — it already serves the "
                    f"unsplit remainder"
                )
            total += frac
        if total >= 1.0:
            raise RegistryStateError(
                f"split fractions sum to {total} — the live version must "
                f"keep a nonzero remainder"
            )
        with self._lock:
            route.split = dict(split)
            route._wrr.clear()
            self._refresh_required(route)

    def cutover(
        self, name: str, version_label: str, *, require_warm: bool = True
    ) -> RegisteredQuery:
        """Atomically make a staged version the live one.

        The swap happens under the scheduler lock: no group can be popped
        while routing changes, groups already dispatched hold their
        version's registration and complete on it (zero dropped requests),
        and every group popped afterwards runs the incoming version. With
        ``require_warm`` (default) the incoming version must have replayed
        the route's full bucket ladder (:meth:`warm_version`), so the swap
        also re-traces nothing; ``require_warm=False`` forces the swap and
        records the warm deficit on the route (the ``registry-warm``
        analysis rule flags it). The route token is untouched — outstanding
        submit handles keep working across the swap.
        """
        route = self._route(name)
        incoming = self._version(route, version_label)
        with self.scheduler.hold():
            with self._lock:
                deficit = len(route.ladder - incoming.warmed_ladder)
                if require_warm and (deficit or not incoming.warmed):
                    raise RegistryStateError(
                        f"version {version_label!r} of '{name}' is not warm "
                        f"({deficit} of {len(route.ladder)} bucket(s) cold) "
                        f"— call warm_version() first, or force with "
                        f"require_warm=False"
                    )
                route.last_cutover_deficit = deficit
                route.live = version_label
                route.split.pop(version_label, None)
                route._wrr.clear()
                if route.shadow == version_label:
                    route.shadow = None
                route.cutovers += 1
                self._refresh_required(route)
                self.queries[name] = incoming
                self.stats.cutovers += 1
        return incoming

    def retire_version(self, name: str, version_label: str) -> None:
        """Drop a non-live staged version from the route (its compiled plan
        stays in the engine cache until evicted). Refuses to retire the
        live version or one still designated shadow / holding split
        traffic."""
        route = self._route(name)
        self._version(route, version_label)
        with self._lock:
            if version_label == route.live:
                raise RegistryStateError(
                    f"cannot retire live version {version_label!r} of "
                    f"'{name}' — cut over to another version first"
                )
            if route.shadow == version_label or version_label in route.split:
                raise RegistryStateError(
                    f"version {version_label!r} of '{name}' still receives "
                    f"shadow/split traffic — clear that first"
                )
            del route.versions[version_label]
            self._refresh_required(route)

    def route_snapshot(self, name: str) -> dict[str, Any]:
        """One route's versioned state (live/shadow/split, ladder,
        per-version counters) — the operator-facing stats surface."""
        route = self._route(name)
        with self._lock:
            return route.snapshot()

    def _registered(self, name: str) -> RegisteredQuery:
        reg = self.queries.get(name)
        if reg is None:
            raise UnknownQueryError(
                f"no query registered under '{name}' — registered: "
                f"{sorted(self.queries) or '(none)'}"
            )
        return reg

    # -- the pump ------------------------------------------------------------

    def start_pump(self, max_latency_ms: float = 5.0) -> Scheduler:
        """Start (or retune) the background pump thread: submitted requests
        flush automatically, each queue by its own deadline (queues without
        an explicit ``max_latency_ms`` use the scheduler default, which the
        tightest ``start_pump`` call wins)."""
        sch = self.scheduler
        if sch.running:
            sch.default_latency_ms = min(
                sch.default_latency_ms, float(max_latency_ms)
            )
        else:
            sch.default_latency_ms = float(max_latency_ms)
            sch.start()
        return sch

    def stop_pump(self) -> None:
        if self.scheduler.running:
            self.scheduler.stop()  # drains pending requests

    @property
    def pump(self) -> Optional[Scheduler]:
        """The scheduler, when its pump thread is running (else None)."""
        return self.scheduler if self.scheduler.running else None

    def shutdown(self) -> None:
        """Stop the pump (draining) and release the boundary pool."""
        self.stop_pump()
        self.executor.shutdown()

    # -- request lifecycle ---------------------------------------------------

    def submit(
        self,
        name: str,
        columns: dict[str, np.ndarray],
        *,
        expect_token: Optional[str] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> QueryRequest:
        """Enqueue one batch of fact rows for ``name``; run via ``flush`` (or
        the pump). ``expect_token`` guards against serving through a stale
        handle: if ``name`` has been re-registered since the caller's
        ``serve()`` — different plan *or* different bound params — the
        submit is rejected instead of silently answering the wrong query.

        When the query was registered with ``max_pending`` and its queue is
        full, a blocking submit waits (up to ``timeout`` seconds) for the
        scheduler to free space; ``block=False`` — or an expired timeout —
        raises :class:`~repro.errors.ServerOverloadedError` instead.
        """
        reg = self._registered(name)
        if expect_token is not None and expect_token != reg.token:
            raise StaleQueryError(
                f"query '{name}' was re-registered since this handle served "
                f"it (registration {reg.token} != handle's "
                f"{expect_token}) — re-serve the prepared query to refresh "
                f"the handle"
            )
        with self._lock:
            route = self.routes.get(name)
            required = (
                set(route.required) if route is not None else set(reg.scan_columns)
            )
        missing = [c for c in sorted(required) if c not in columns]
        if missing:
            raise KeyError(f"batch for '{name}' missing columns {missing}")
        # normalize dtypes to the registered fact schema so every bucket-sized
        # batch maps onto the same compiled program. Keep every schema column
        # the caller provided (not just the live version's scan set): shadow
        # and split versions of the same route may read columns the live plan
        # pruned away, and the group must carry enough for all of them.
        cols = {
            c: np.asarray(v).astype(reg.fact_dtypes[c], copy=False)
            for c, v in columns.items()
            if c in reg.fact_dtypes
        }
        lengths = {len(v) for v in cols.values()}
        if len(lengths) > 1:
            raise ValueError(
                f"batch for '{name}' has ragged columns: "
                f"{ {c: len(v) for c, v in cols.items()} }"
            )
        n = lengths.pop() if lengths else 0
        req = QueryRequest(
            rid=next(self._rid), query=name, columns=cols, n_rows=n,
            t_submit=time.perf_counter(),
        )
        self.scheduler.enqueue(name, req, n, block=block, timeout=timeout)
        with self._lock:
            self.stats.rows_in += n
        return req

    def flush(self) -> list[QueryRequest]:
        """Execute all pending requests (coalescing per query, earliest
        deadline first) and return them with results filled. Safe to call
        from any thread; an empty queue is a no-op."""
        return self.scheduler.drain()

    def execute(
        self, name: str, columns: dict[str, np.ndarray]
    ) -> dict[str, np.ndarray]:
        """One-shot convenience: submit + flush + return the result."""
        req = self.submit(name, columns)
        self.flush()
        # under a pump another thread's flush may have raced ours and taken
        # this request; either way the result is ready once both finish
        return req.wait(timeout=60.0)

    # -- group dispatch (called by the scheduler) -----------------------------

    def _dispatch_group(self, name: str, group: list[QueryRequest]) -> Future:
        """Execute one scheduler group; returns a future resolving when every
        request in the group is finished (or failed). Never raises — a
        failure lands on the future, and *deterministic* failures are also
        attached to the group's requests here. Transient failures leave the
        requests unsettled on purpose: the scheduler owns them — it requeues
        the group whole (retry/backoff) or, once the policy is exhausted,
        delivers a typed :class:`~repro.errors.RequestFailedError` to every
        waiter via the ``fail`` callback."""
        done: Future = Future()
        reg: Optional[RegisteredQuery] = None
        try:
            # "dispatch" fault site: the whole group dispatch raises before
            # any stage runs — the canonical transient-retry drill
            maybe_inject("dispatch", token=name)
            reg = self._registered(name)
            route = self.routes.get(name)
            shadow_reg = None
            if route is not None:
                reg, shadow_reg = self._pick_version(route)
            if asserts_enabled():
                runtime_assert(len(group) > 0, "dispatched an empty group")
                runtime_assert(
                    all(r.query == name for r in group),
                    f"group for '{name}' contains misrouted request(s) "
                    f"{[r.rid for r in group if r.query != name]}",
                )
                runtime_assert(
                    all(not r.done for r in group),
                    f"group for '{name}' re-dispatches finished request(s) "
                    f"{[r.rid for r in group if r.done]}",
                )
            with self._lock:
                self.stats.flushes += 1
                self.stats.requests_served += len(group)
                if route is not None:
                    st = route.version_stats(reg.version_label)
                    st.groups += 1
                    st.requests += len(group)
                    st.rows += sum(r.n_rows for r in group)
            for r in group:
                r.served_by = reg.version_label

            def _mirror() -> None:
                # score the same group on the shadow version, off the
                # dispatch path; diffing waits on `done`, so the mirror can
                # never race (or touch) the primary's request results
                if shadow_reg is not None:
                    self.executor.pool.submit(
                        self._mirror_shadow, route, shadow_reg, group, done
                    )

            if not self.pipelined:
                self._run_group(reg, group)
                self._record_success(reg)
                done.set_result(group)
                _mirror()
                return done
            n = sum(r.n_rows for r in group)
            if reg.sliceable and n > self.max_bucket:
                # oversized spine: the serial chunked path keeps compiled
                # programs bounded at max_bucket; run it off-thread so the
                # pump stays responsive
                f = self.executor.pool.submit(self._run_group, reg, group)

                def _chunked_done(f2, _reg=reg, _group=group, _done=done):
                    e = f2.exception()
                    if e is not None:
                        self._settle_dispatch_failure(_reg, _group, e)
                        _done.set_exception(e)
                    else:
                        self._record_success(_reg)
                        _done.set_result(_group)

                f.add_done_callback(_chunked_done)
                return done
            with self._lock:
                self.stats.pipelined_groups += 1
            cat, n, segments = self._group_batch(reg, group)
            gfut = self._execute_padded_async(reg, cat, n, segments=segments)

            def _complete(f2, _reg=reg, _group=group, _n=n, _done=done):
                try:
                    res = f2.result()
                    self._split_group(_reg, _group, res, _n)
                    self._record_success(_reg)
                    _done.set_result(_group)
                except BaseException as e:  # noqa: BLE001
                    self._settle_dispatch_failure(_reg, _group, e)
                    _done.set_exception(e)

            gfut.add_done_callback(_complete)
            _mirror()
        except BaseException as e:  # noqa: BLE001
            self._settle_dispatch_failure(reg, group, e)
            if not done.done():
                done.set_exception(e)
        return done

    def _settle_dispatch_failure(
        self,
        reg: Optional[RegisteredQuery],
        group: list[QueryRequest],
        e: BaseException,
    ) -> None:
        """Route one group-execution failure: deterministic errors are
        attached to the requests immediately; transient ones are left for
        the scheduler (which requeues the group or fails it terminally
        through the ``fail`` callback). Either way the failure counts toward
        the serving version's error rate and its circuit breaker."""
        if not isinstance(e, TransientError):
            self._fail_group(group, e)
        if reg is not None:
            self._record_failure(reg)

    def _record_failure(self, reg: RegisteredQuery) -> None:
        trip = False
        with self._lock:
            route = self.routes.get(reg.name)
            if route is not None:
                route.version_stats(reg.version_label).errors += 1
            reg.breaker_failures += 1
            if (
                not reg.degraded
                and reg.fallback is None
                and reg.breaker_failures >= reg.breaker_threshold
            ):
                # claim the trip under the lock; compile outside it
                reg.degraded = True
                trip = True
        if trip:
            self._degrade(reg)

    def _record_success(self, reg: RegisteredQuery) -> None:
        with self._lock:
            reg.breaker_failures = 0

    def _degrade(self, reg: RegisteredQuery) -> None:
        """Trip the circuit breaker: compile this registration's plan with
        the relational kernels disabled and route its traffic through the
        result. The fallback is fingerprint-forked from the primary (the
        kernel-mode token folds into plan/stage fingerprints) and
        bitwise-identical by the kernel parity contract, so degradation
        trades throughput for availability — never correctness. Plans with
        no Join/Aggregate stage fork to the same fingerprint and the
        "fallback" is simply the primary again."""
        try:
            prev = os.environ.get("RAVEN_KERNELS")
            os.environ["RAVEN_KERNELS"] = "off"
            try:
                fb = compile_plan(reg.plan)
            finally:
                if prev is None:
                    os.environ.pop("RAVEN_KERNELS", None)
                else:
                    os.environ["RAVEN_KERNELS"] = prev
            from repro.relational.engine import get_artifact_store

            if get_artifact_store() is not None:
                fb.warm_start()
        except BaseException:  # noqa: BLE001
            # fallback compile failed too: release the claim so the next
            # failure can re-trip; traffic keeps flowing on the primary
            with self._lock:
                reg.degraded = False
            return
        with self._lock:
            reg.fallback = fb
            reg.breaker_trips += 1
            self.stats.breaker_trips += 1

    def _pick_version(
        self, route: QueryRoute
    ) -> tuple[RegisteredQuery, Optional[RegisteredQuery]]:
        """Choose the version serving this group, plus the shadow (if set).

        Split traffic uses smooth weighted round-robin — every label's
        credit grows by its weight each pick, the largest credit wins and
        pays back the total — so the selection is deterministic (no RNG) and
        a 0.25 split sends exactly every fourth group to the staged version,
        interleaved rather than bursty.
        """
        with self._lock:
            shadow_reg = (
                route.versions.get(route.shadow) if route.shadow else None
            )
            if not route.split:
                return route.versions[route.live], shadow_reg
            weights = dict(route.split)
            weights[route.live] = 1.0 - sum(weights.values())
            for label, w in weights.items():
                route._wrr[label] = route._wrr.get(label, 0.0) + w
            pick = max(
                route._wrr,
                key=lambda lb: (route._wrr[lb], lb == route.live, lb),
            )
            route._wrr[pick] -= sum(weights.values())
            return route.versions[pick], shadow_reg

    def _mirror_shadow(
        self,
        route: QueryRoute,
        shadow_reg: RegisteredQuery,
        group: list[QueryRequest],
        primary_done: Future,
    ) -> None:
        """Score a mirrored copy of one coalesced group on the shadow
        version (boundary-pool thread) and diff it against what the primary
        actually returned. Builds its own concatenated batch — the primary
        may donate its padded buffers — and never touches request state: a
        shadow failure is counted on the route, not raised, and shadow
        results are unreachable from any response."""
        label = shadow_reg.version_label
        try:
            n = sum(r.n_rows for r in group)
            if len(group) == 1:
                cat = dict(group[0].columns)
            else:
                cat = {
                    c: np.concatenate([r.columns[c] for r in group])
                    for c in shadow_reg.scan_columns
                }
            segments = None
            if len(group) > 1 and not shadow_reg.sliceable:
                seg_ids = np.repeat(
                    np.arange(len(group), dtype=np.int32),
                    [r.n_rows for r in group],
                )
                segments = (seg_ids, len(group))
            res = self._execute_padded(shadow_reg, cat, n, segments=segments)
            shadow_out = self._split_results(shadow_reg, group, res, n)
            primary_done.result(timeout=60.0)
            diff_rows, max_diff, rows = self._diff_shadow(group, shadow_out)
            with self._lock:
                st = route.version_stats(label)
                st.shadow_groups += 1
                st.shadow_rows += rows
                st.shadow_diff_rows += diff_rows
                st.shadow_max_abs_diff = max(st.shadow_max_abs_diff, max_diff)
                self.stats.shadow_mirrored_groups += 1
        except BaseException:  # noqa: BLE001 — contained, counted, never raised
            with self._lock:
                route.version_stats(label).shadow_errors += 1

    @staticmethod
    def _diff_shadow(
        group: list[QueryRequest],
        shadow_out: list[dict[str, np.ndarray]],
    ) -> tuple[int, float, int]:
        """Compare shadow per-request results against the primary's returned
        ones: (rows not bitwise-equal, largest numeric divergence, rows
        compared). A column-set or row-count mismatch counts every primary
        row as differing — a shape drift is the loudest possible diff."""
        diff_rows, max_diff, rows = 0, 0.0, 0
        for req, sh in zip(group, shadow_out):
            pr = req.result or {}
            n_pr = len(next(iter(pr.values()))) if pr else 0
            rows += n_pr
            n_sh = len(next(iter(sh.values()))) if sh else 0
            if sorted(pr) != sorted(sh) or n_pr != n_sh:
                diff_rows += n_pr
                continue
            row_diff = np.zeros(n_pr, dtype=bool)
            for k, pv in pr.items():
                sv = np.asarray(sh[k])
                pv = np.asarray(pv)
                neq = pv != sv
                if pv.dtype.kind == "f":
                    neq &= ~(np.isnan(pv) & np.isnan(sv))
                    d = np.abs(
                        np.nan_to_num(pv.astype(np.float64))
                        - np.nan_to_num(sv.astype(np.float64))
                    )
                    if d.size:
                        max_diff = max(max_diff, float(d.max()))
                row_diff |= neq.reshape(n_pr, -1).any(axis=1)
            diff_rows += int(row_diff.sum())
        return diff_rows, max_diff, rows

    def _fail_group(self, group: list[QueryRequest], e: BaseException) -> None:
        """Contain the blast radius: fail this group's requests (waiters
        re-raise from wait()) while the server keeps serving other groups."""
        for r in group:
            if not r.done:
                r.error = e
                r._event.set()

    # -- internals -----------------------------------------------------------

    def _group_batch(
        self, reg: RegisteredQuery, group: list[QueryRequest]
    ) -> tuple[dict[str, np.ndarray], int, Optional[tuple[np.ndarray, int]]]:
        """Concatenate a group into one fact batch (+ segment ids when the
        plan cannot be split positionally)."""
        n = sum(r.n_rows for r in group)
        if len(group) == 1:
            return group[0].columns, n, None
        cat = {
            c: np.concatenate([r.columns[c] for r in group])
            for c in reg.scan_columns
        }
        with self._lock:
            self.stats.coalesced_requests += len(group)
        if reg.sliceable:
            return cat, n, None
        # host boundaries compact data-dependently and aggregates fold the
        # spine, so positional slicing is impossible: thread per-request
        # segment ids through the stage graph instead
        seg_ids = np.repeat(
            np.arange(len(group), dtype=np.int32),
            [r.n_rows for r in group],
        )
        with self._lock:
            self.stats.segmented_batches += 1
        return cat, n, (seg_ids, len(group))

    def _padded_kwargs(
        self,
        reg: RegisteredQuery,
        fact_np: dict[str, np.ndarray],
        n: int,
        segments: Optional[tuple[np.ndarray, int]] = None,
    ) -> dict[str, Any]:
        """Pad ``n`` fact rows to their bucket; returns the kwargs shared by
        ``CompiledPlan.run`` and ``run_async`` (plus bucket accounting)."""
        bucket = row_bucket(n, self.min_bucket)
        fact: dict[str, jnp.ndarray] = {}
        for c in reg.scan_columns:
            col = fact_np[c]
            if len(col) < bucket:
                pad = np.zeros(bucket - len(col), dtype=col.dtype)
                col = np.concatenate([col, pad])
            fact[c] = jnp.asarray(col)
        row_valid = np.arange(bucket) < n
        if segments is not None:
            ids, k = segments
            if len(ids) < bucket:
                ids = np.concatenate(
                    [ids, np.zeros(bucket - len(ids), dtype=np.int32)]
                )
            segments = (ids, k)

        # key on the *active* plan: a breaker-degraded registration serves
        # (and warms buckets for) its fallback's fingerprint
        active_fp = reg.active.fingerprint
        schema = tuple((c, str(reg.fact_dtypes[c])) for c in reg.scan_columns)
        key = (active_fp, schema, bucket)
        # (row bucket, segment-slot bucket) is exactly the jit-specialization
        # key (segment *count* is a dynamic scalar): recording it on the
        # route is what lets warm_version replay an incoming version into
        # full coverage before a cutover
        entry = (bucket, seg_bucket(segments[1]) if segments is not None else 0)
        with self._lock:
            if key in self._seen_buckets:
                self.stats.bucket_hits += 1
            else:
                self.stats.bucket_misses += 1
                self._seen_buckets.add(key)
            self.stats.batches_executed += 1
            self.stats.rows_padded += bucket - n
            reg.warmed_ladder.add(entry)
            route = self.routes.get(reg.name)
            if route is not None:
                route.ladder.add(entry)

        def track_mid(stage_index: int, b: int) -> None:
            mid_key = (active_fp, stage_index, b)
            with self._lock:
                if mid_key in self._seen_mid_buckets:
                    self.stats.mid_bucket_hits += 1
                else:
                    self.stats.mid_bucket_misses += 1
                    self._seen_mid_buckets.add(mid_key)

        db = dict(reg.database)
        db[reg.fact_table] = fact
        return {
            "database": db,
            "row_valid": jnp.asarray(row_valid),
            "params": reg.params if reg.param_names else None,
            "segments": segments,
            "bucketer": (
                (lambda m: row_bucket(m, self.min_bucket))
                if self.mid_bucketing else None
            ),
            "on_mid_bucket": track_mid,
            # the padded fact spine is freshly built per group: safe to
            # donate to XLA on backends that support aliasing (unless the
            # registration opted out via ServeOptions(donate=False))
            "donate": frozenset((reg.fact_table,)) if reg.donate else frozenset(),
        }

    def _execute_padded(
        self,
        reg: RegisteredQuery,
        fact_np: dict[str, np.ndarray],
        n: int,
        segments: Optional[tuple[np.ndarray, int]] = None,
    ):
        """Serial padded execution (blocks at every stage)."""
        return reg.active.run(**self._padded_kwargs(reg, fact_np, n, segments))

    def _execute_padded_async(
        self,
        reg: RegisteredQuery,
        fact_np: dict[str, np.ndarray],
        n: int,
        segments: Optional[tuple[np.ndarray, int]] = None,
    ) -> Future:
        """Pipelined padded execution; returns ``Future[RunResult]``."""
        return reg.active.run_async(
            executor=self.executor,
            **self._padded_kwargs(reg, fact_np, n, segments),
        )

    def _finish(self, req: QueryRequest) -> None:
        if asserts_enabled():
            runtime_assert(
                not req.done, f"request {req.rid} finished twice"
            )
            runtime_assert(
                not any(k.startswith("__pv_") for k in (req.result or {})),
                f"request {req.rid} result leaks reserved block column(s) "
                f"{[k for k in (req.result or {}) if k.startswith('__pv_')]}",
            )
        req.done = True
        req.t_done = time.perf_counter()
        if req.served_by:
            with self._lock:
                route = self.routes.get(req.query)
                if route is not None:
                    route.record_latency(
                        req.served_by, (req.t_done - req.t_submit) * 1e3
                    )
        req._event.set()

    def _positional_split(
        self,
        group: list[QueryRequest],
        cols: dict[str, np.ndarray],
        valid: np.ndarray,
    ) -> None:
        """Output rows align 1:1 with the fact spine: slice each request's
        span, then compact by its validity slice."""
        for r, out in zip(group, self._positional_results(group, cols, valid)):
            r.result = out
            self._finish(r)

    @staticmethod
    def _positional_results(
        group: list[QueryRequest],
        cols: dict[str, np.ndarray],
        valid: np.ndarray,
    ) -> list[dict[str, np.ndarray]]:
        out, off = [], 0
        for r in group:
            sl = slice(off, off + r.n_rows)
            m = valid[sl]
            out.append({k: v[sl][m] for k, v in cols.items()})
            off += r.n_rows
        return out

    def _split_results(
        self,
        reg: RegisteredQuery,
        group: list[QueryRequest],
        res,
        n: int,
    ) -> list[dict[str, np.ndarray]]:
        """Split one executed group's table into per-request column dicts —
        pure (no request mutation), shared by the primary finish path and
        the shadow diff path."""
        if reg.sliceable:
            cols = {
                k: np.asarray(v)[:n] for k, v in res.table.columns.items()
            }
            valid = np.asarray(res.table.valid)[:n]
            return self._positional_results(group, cols, valid)
        if len(group) == 1:
            # a lone host-boundary/aggregate request: no splitting needed
            return [res.table.to_numpy(compact=True)]
        cols = {k: np.asarray(v) for k, v in res.table.columns.items()}
        valid = np.asarray(res.table.valid)
        if reg.has_aggregate:
            # segmented fold: output row i belongs to request i
            return [
                {k: v[i:i + 1] for k, v in cols.items()}
                for i in range(len(group))
            ]
        seg = np.asarray(res.seg)
        return [
            {k: v[valid & (seg == i)] for k, v in cols.items()}
            for i in range(len(group))
        ]

    def _split_group(
        self,
        reg: RegisteredQuery,
        group: list[QueryRequest],
        res,
        n: int,
    ) -> None:
        """Split one executed group's result back per request and finish
        them. Runs on whichever thread completed the group (the dispatching
        thread for pure graphs, a boundary worker otherwise)."""
        for r, out in zip(group, self._split_results(reg, group, res, n)):
            r.result = out
            self._finish(r)

    def _run_group(self, reg: RegisteredQuery, group: list[QueryRequest]) -> None:
        """Serial group execution (the ``pipelined=False`` baseline, and the
        chunked path for sliceable spines wider than ``max_bucket``)."""
        cat, n, segments = self._group_batch(reg, group)
        if reg.sliceable and n > self.max_bucket:
            # row-aligned output lets a spine wider than max_bucket run as
            # max_bucket-sized chunks, keeping the compiled-program count
            # bounded by log2(max_bucket / min_bucket) + 1 per query
            out_cols: dict[str, list[np.ndarray]] = {}
            out_valid: list[np.ndarray] = []
            for off in range(0, max(n, 1), self.max_bucket):
                span = min(self.max_bucket, n - off) if n else 0
                chunk = {c: v[off:off + span] for c, v in cat.items()}
                table = self._execute_padded(reg, chunk, span).table
                valid = np.asarray(table.valid)[:span]
                out_valid.append(valid)
                for k, v in table.columns.items():
                    out_cols.setdefault(k, []).append(np.asarray(v)[:span])
            cols = {k: np.concatenate(v) for k, v in out_cols.items()}
            valid = np.concatenate(out_valid)
            self._positional_split(group, cols, valid)
            return
        res = self._execute_padded(reg, cat, n, segments=segments)
        self._split_group(reg, group, res, n)

    # -- introspection --------------------------------------------------------

    def recompiles(self) -> int:
        """Total XLA stage compiles across every registered version (staged
        and shadow versions included — a warm cutover must not move this)."""
        with self._lock:
            regs = {
                id(r): r
                for route in self.routes.values()
                for r in route.versions.values()
            }
            for r in self.queries.values():
                regs.setdefault(id(r), r)
        return sum(r.recompiles for r in regs.values())

    def stats_snapshot(self) -> dict[str, Any]:
        """Server counters merged with the scheduler's queue gauges, the
        pipelined executor's overlap gauges, and per-route version state
        (what ``db.cache_stats()`` surfaces under ``"server"``)."""
        out = self.stats.snapshot()
        out.update(self.scheduler.snapshot())
        out["queue_depths"] = self.scheduler.depths()
        out["pipeline"] = self.executor.snapshot()
        plan = get_fault_plan()
        out["faults_injected"] = plan.injected() if plan is not None else {}
        with self._lock:
            out["routes"] = {
                name: route.snapshot() for name, route in self.routes.items()
            }
        return out
