from repro.exec.pump import RequestPump
from repro.serve.engine import Request, ServeEngine
from repro.serve.query_server import (
    PredictionQueryServer,
    QueryRequest,
    RegisteredQuery,
    ServerStats,
    row_bucket,
)

__all__ = [
    "Request",
    "RequestPump",
    "ServeEngine",
    "PredictionQueryServer",
    "QueryRequest",
    "RegisteredQuery",
    "ServerStats",
    "row_bucket",
]
