from repro.exec.pipeline import PipelineExecutor
from repro.exec.scheduler import RequestPump, Scheduler
from repro.serve.engine import Request, ServeEngine
from repro.serve.query_server import (
    PredictionQueryServer,
    QueryRequest,
    QueryRoute,
    RegisteredQuery,
    ServerStats,
    VersionStats,
    row_bucket,
)
from repro.serve.registry import ModelRegistry, ModelVersion

__all__ = [
    "Request",
    "RequestPump",
    "PipelineExecutor",
    "Scheduler",
    "ServeEngine",
    "PredictionQueryServer",
    "QueryRequest",
    "RegisteredQuery",
    "ServerStats",
    "row_bucket",
    "QueryRoute",
    "VersionStats",
    "ModelRegistry",
    "ModelVersion",
]
