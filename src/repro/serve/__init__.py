from repro.serve.engine import Request, ServeEngine
from repro.serve.query_server import (
    PredictionQueryServer,
    QueryRequest,
    RegisteredQuery,
    ServerStats,
    row_bucket,
)

__all__ = [
    "Request",
    "ServeEngine",
    "PredictionQueryServer",
    "QueryRequest",
    "RegisteredQuery",
    "ServerStats",
    "row_bucket",
]
