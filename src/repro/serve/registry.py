"""Versioned model lifecycle: publish → warm → shadow/split → cutover.

Serving froze one model per registration: shipping model v2 meant
re-registering, which mints a new token and stales every outstanding
handle (:class:`~repro.errors.StaleQueryError`) — correct for a
*different query*, hostile for *the same query with a newer model*. The
:class:`ModelRegistry` is the production story on top of the machinery
that already exists — content fingerprints, the artifact store's warm
starts, and the server's versioned :class:`~repro.serve.query_server.QueryRoute`:

    db = raven.connect(tables, stats="auto")
    v1 = db.models.publish("risk", pipe)          # version handle (live)
    prep = db.sql("... PREDICT(model='risk' ...)").prepare().serve("q")

    v2 = db.models.publish("risk", pipe2)         # staged + warm-compiled
    v2.wait_ready()                               #   (background by default)
    db.models.shadow("risk", 2)                   # mirrored, diffed, counted
    db.models.split("risk", {2: 0.25})            # every 4th group on v2
    db.models.cutover("risk", 2)                  # atomic: zero dropped,
                                                  #   zero re-traced requests
    db.models.retire("risk", 1)

Every version moves through an explicit state machine — ``published →
warming → ready → live → retired`` — whose recorded history the
``registry-state`` analysis rule replays. Publishing onto a model with
served routes stages the new version onto each route (same query IR,
re-optimized for the new pipeline — new weights are a new fingerprint,
so plan/stage caches never collide) and replays the route's observed
bucket ladder through it, so by ``ready`` the incoming version holds a
compiled program for every shape live traffic uses.

``PREDICT(model=...)`` references resolve through one documented path,
:meth:`ModelRegistry.resolve`:

    ``"name"``          the live version (what production traffic gets)
    ``"name@2"``        that exact published version
    ``"name@latest"``   the newest published version
    ``"name@live"``     explicit spelling of the default
    ``"name@shadow"``   the version currently shadowed (error if none)

The registry implements the mapping protocol the SQL frontend already
uses for the plain model dict (``in`` / ``[]`` / iteration), so the
parser did not change: ``models[spec.model]`` now returns the resolved
version's pipeline and raises the precise
:class:`~repro.errors.UnknownModelVersionError` /
:class:`~repro.errors.RegistryStateError` instead of a generic miss.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

from repro.errors import (
    RecoveryError,
    RegistryStateError,
    UnknownModelError,
    UnknownModelVersionError,
)
from repro.exec.faults import RollbackPolicy

# the recorded-history state machine the registry-state rule replays
ALLOWED_TRANSITIONS: dict[str, frozenset] = {
    "published": frozenset({"warming", "ready", "live", "retired"}),
    "warming": frozenset({"ready", "live", "retired"}),
    "ready": frozenset({"live", "retired"}),
    "live": frozenset({"ready", "retired"}),
    "retired": frozenset(),
}


class ModelVersion:
    """One published version of a named model: pipeline + fingerprint +
    lifecycle state. Returned by :meth:`ModelRegistry.publish`."""

    def __init__(self, name: str, version: int, pipeline, fingerprint: str):
        self.name = name
        self.version = version
        self.pipeline = pipeline
        self.fingerprint = fingerprint
        self.state = "published"
        self.history: list[str] = ["published"]
        self.events: list[str] = []  # lifecycle decisions (e.g. rollbacks)
        self.error: Optional[BaseException] = None  # warm-compile failure
        self._ready = threading.Event()

    @property
    def ref(self) -> str:
        """The canonical ``name@version`` reference for this version."""
        return f"{self.name}@{self.version}"

    @property
    def label(self) -> str:
        """The version label used on server routes (``v<version>``)."""
        return f"v{self.version}"

    def wait_ready(self, timeout: Optional[float] = None) -> "ModelVersion":
        """Block until background warm-compile finished (or failed: the
        contained error re-raises here, wrapped)."""
        if not self._ready.wait(timeout):
            raise RegistryStateError(
                f"version {self.ref} not ready within {timeout}s"
            )
        if self.error is not None:
            raise RegistryStateError(
                f"warm-compile of {self.ref} failed: {self.error}"
            ) from self.error
        return self

    def _transition(self, new: str) -> None:
        if new == self.state:
            return
        if new not in ALLOWED_TRANSITIONS[self.state]:
            raise RegistryStateError(
                f"{self.ref}: illegal state transition "
                f"{self.state!r} -> {new!r}"
            )
        self.state = new
        self.history.append(new)

    def __repr__(self) -> str:
        return (
            f"ModelVersion({self.ref}, state={self.state!r}, "
            f"fingerprint={self.fingerprint[:12]}…)"
        )


@dataclasses.dataclass
class _Route:
    """One served query whose PREDICT references a registered model."""

    serve_name: str
    prep: Any       # the PreparedQuery that served it (options + params)
    server: Any     # the PredictionQueryServer owning the route


class ModelRegistry:
    """Names → ordered published versions, plus the routes serving them.

    All state lives under one reentrant lock (lifecycle methods call each
    other: ``publish`` warms, ``cutover`` resolves); the slow work —
    optimizing and warm-compiling an incoming version — happens *outside*
    it, on the publishing (or a background) thread, so serving never
    stalls behind a publish.
    """

    def __init__(self, session):
        self._session = session
        self._lock = threading.RLock()
        self._versions: dict[str, list[ModelVersion]] = {}
        self._live: dict[str, int] = {}
        self._shadow: dict[str, int] = {}
        self._split: dict[str, dict[int, float]] = {}  # active split state
        self._routes: dict[str, list[_Route]] = {}  # model name -> routes
        self._pins: list[Any] = []  # identity-hashed pipeline components
        # rollback machinery: per-model pre-cutover baseline (previous live
        # version + its p99), recorded decisions, and running guards
        self._baselines: dict[str, dict[str, Any]] = {}
        self._rollbacks: list[dict[str, Any]] = []
        self._guards: list["RollbackGuard"] = []

    # -- publish -------------------------------------------------------------

    def publish(self, name: str, pipe_or_path, *, warm: str = "background"):
        """Publish a pipeline (or saved-pipeline path) as the next version
        of ``name``; returns the :class:`ModelVersion` handle.

        The first version of a name goes live immediately — it *is* the
        model. Later versions are staged: when the model has served routes,
        the new version is compiled onto each route and the route's
        observed bucket ladder replayed through it (``warm="background"``
        on a daemon thread — ``handle.wait_ready()`` joins it;
        ``warm="sync"`` inline; ``warm="off"`` defers both to
        :meth:`shadow`/:meth:`split`/:meth:`cutover` time, which warm
        lazily). A warm failure never disturbs serving: it is contained on
        the handle (``error``, state ``retired``) and re-raised only by
        ``wait_ready()``.
        """
        if warm not in ("background", "sync", "off"):
            raise RegistryStateError(
                f"warm must be 'background', 'sync', or 'off' — got {warm!r}"
            )
        if isinstance(pipe_or_path, str):
            from repro.ml.pipeline import load_pipeline

            pipe_or_path = load_pipeline(pipe_or_path)
        from repro.core.fingerprint import fingerprint

        with self._lock:
            versions = self._versions.setdefault(name, [])
            number = len(versions) + 1
            fp = fingerprint(
                "model-version", name, number, pipe_or_path, pins=self._pins
            )
            mv = ModelVersion(name, number, pipe_or_path, fp)
            versions.append(mv)
            if number == 1:
                mv._transition("live")
                self._live[name] = 1
                mv._ready.set()
                self._journal()
                return mv
        if warm == "off":
            mv._ready.set()
            self._journal()
            return mv
        if warm == "sync":
            self._warm(mv)
        else:
            threading.Thread(
                target=self._warm, args=(mv,),
                name=f"registry-warm-{mv.ref}", daemon=True,
            ).start()
        return mv

    def _warm(self, mv: ModelVersion) -> None:
        """Stage ``mv`` onto every tracked route and replay each route's
        bucket ladder through it (runs on the publisher or a warm thread)."""
        try:
            mv._transition("warming")
            for rt in self._routes_for(mv.name):
                self._stage_on_route(mv, rt)
                rt.server.warm_version(rt.serve_name, mv.label)
            mv._transition("ready")
        except BaseException as e:  # noqa: BLE001 — contained on the handle
            mv.error = e
            mv._transition("retired")
        finally:
            # journal BEFORE releasing waiters: once wait_ready() returns,
            # the caller may shadow/split/cutover and journal — a background
            # warm thread journaling afterwards would overwrite that newer
            # state with this stale one
            try:
                self._journal()
            finally:
                mv._ready.set()

    def _stage_on_route(self, mv: ModelVersion, rt: _Route) -> None:
        """Compile ``mv`` as a staged version on one served route: same
        query spec re-pointed at ``name@version``, re-optimized (new
        weights are a new fingerprint — plan/stage caches cannot collide
        with the live version's), registered via the server's
        ``stage_version`` so the submit-schema compatibility checks run."""
        route = rt.server.routes.get(rt.serve_name)
        if route is not None and mv.label in route.versions:
            return  # already staged (e.g. shadow before cutover)
        prep = rt.prep
        spec = dataclasses.replace(prep.query.spec, model=mv.ref)
        q = type(prep.query)(self._session, spec)
        plan, report = q._optimize(prep.options, prep.strategy)
        rt.server.stage_version(
            rt.serve_name, q.ir, self._session.tables,
            version_label=mv.label, optimized=(plan, report),
            params=prep.params,
        )

    def _ensure_staged(self, mv: ModelVersion) -> None:
        """Lazily stage + warm a version published with ``warm='off'`` (or
        routes served after it was published)."""
        if mv.state == "retired":
            raise RegistryStateError(
                f"{mv.ref} is retired"
                + (f" (warm-compile failed: {mv.error})" if mv.error else "")
            )
        if mv.state == "warming":
            # a background publish is mid-warm: join it rather than racing
            # it onto the same routes
            mv.wait_ready(timeout=600.0)
        missing = [
            rt for rt in self._routes_for(mv.name)
            if mv.label not in rt.server.routes[rt.serve_name].versions
        ]
        for rt in missing:
            self._stage_on_route(mv, rt)
            rt.server.warm_version(rt.serve_name, mv.label)
        if mv.state == "published":
            mv._transition("warming")
            mv._transition("ready")

    def _routes_for(self, name: str) -> list[_Route]:
        with self._lock:
            return list(self._routes.get(name, ()))

    def _track_route(self, model_ref: str, serve_name: str, prep, server) -> None:
        """Record that a served query's PREDICT references ``model_ref``
        (called by ``PreparedQuery.serve``); lifecycle operations fan out
        over these routes."""
        name, _ = self._parse_ref(model_ref)
        with self._lock:
            if name not in self._versions:
                return
            routes = self._routes.setdefault(name, [])
            routes[:] = [r for r in routes if r.serve_name != serve_name]
            routes.append(_Route(serve_name, prep, server))
        self._journal()

    # -- lifecycle -----------------------------------------------------------

    def shadow(self, name: str, version: Optional[int]) -> None:
        """Mirror live traffic for ``name`` through ``version`` on every
        route: scored on copies of the same coalesced groups, diffed
        against the returned results, counted in per-version stats — and
        never returned. ``None`` stops shadowing."""
        if version is None:
            with self._lock:
                self._shadow.pop(name, None)
            for rt in self._routes_for(name):
                rt.server.set_shadow(rt.serve_name, None)
            self._journal()
            return
        mv = self._get_version(name, version)
        self._ensure_staged(mv)
        for rt in self._routes_for(name):
            rt.server.set_shadow(rt.serve_name, mv.label)
        with self._lock:
            self._shadow[name] = version
        self._journal()

    def split(self, name: str, fractions: dict[int, float]) -> None:
        """Send a deterministic fraction of dispatched groups to staged
        versions (``{version: fraction}``; the live version serves the
        remainder); ``{}`` clears the split."""
        regs = {}
        for version, frac in fractions.items():
            mv = self._get_version(name, int(version))
            self._ensure_staged(mv)
            regs[mv.label] = float(frac)
        for rt in self._routes_for(name):
            rt.server.set_split(rt.serve_name, regs)
        with self._lock:
            if fractions:
                self._split[name] = {
                    int(v): float(f) for v, f in fractions.items()
                }
            else:
                self._split.pop(name, None)
        self._journal()

    def cutover(
        self, name: str, version: int, *, require_warm: bool = True
    ) -> ModelVersion:
        """Atomically make ``version`` the live model for ``name``.

        Every route swaps under its scheduler's hold — in-flight groups
        finish on the version that dispatched them (zero dropped), groups
        popped afterwards run the new version, and with ``require_warm``
        (default) the swap is also zero-retrace (the incoming version must
        have replayed the route's full bucket ladder). Outstanding submit
        handles keep working: the route token does not change. Fresh
        ``PREDICT(model='name')`` queries resolve to the new version from
        this call on."""
        mv = self._get_version(name, version)
        with self._lock:
            if self._live.get(name) == version:
                raise RegistryStateError(f"{mv.ref} is already live")
            outgoing = self._live.get(name)
        self._ensure_staged(mv)
        # pre-cutover baseline for the rollback guard: the outgoing live
        # version's p99 over each route's rolling latency window, captured
        # before any traffic reaches the incoming version
        baseline_p99 = 0.0
        if outgoing is not None:
            out_label = f"v{outgoing}"
            for rt in self._routes_for(name):
                snap = rt.server.route_snapshot(rt.serve_name)
                v = snap["versions"].get(out_label)
                if v is not None:
                    baseline_p99 = max(baseline_p99, v["p99_ms"])
        for rt in self._routes_for(name):
            rt.server.cutover(
                rt.serve_name, mv.label, require_warm=require_warm
            )
        with self._lock:
            old = self._live.get(name)
            self._live[name] = version
            if self._shadow.get(name) == version:
                del self._shadow[name]
            split = self._split.get(name)
            if split is not None and split.pop(version, None) is not None:
                if not split:
                    del self._split[name]
            if old is not None:
                self._versions[name][old - 1]._transition("ready")
                self._baselines[name] = {"prev": old, "p99_ms": baseline_p99}
            mv._transition("live")
        self._journal()
        return mv

    def retire(self, name: str, version: int) -> None:
        """Drop a non-live version: its route registrations are removed
        (refused while it still takes shadow/split traffic) and its state
        machine terminates."""
        mv = self._get_version(name, version)
        with self._lock:
            if self._live.get(name) == version:
                raise RegistryStateError(
                    f"cannot retire live version {mv.ref} — cut over to "
                    f"another version first"
                )
            if self._shadow.get(name) == version:
                raise RegistryStateError(
                    f"{mv.ref} is the active shadow — shadow(name, None) first"
                )
        doomed: set[str] = set()
        servers: list[Any] = []
        for rt in self._routes_for(name):
            servers.append(rt.server)
            route = rt.server.routes.get(rt.serve_name)
            if route is not None and mv.label in route.versions:
                reg = route.versions[mv.label]
                doomed |= {
                    st.fingerprint for st in reg.compiled.graph.stages
                }
                rt.server.retire_version(rt.serve_name, mv.label)
        with self._lock:
            mv._transition("retired")
        self._gc_retired(doomed, servers)
        self._journal()

    def _gc_retired(self, doomed: set, servers: list) -> None:
        """Garbage-collect a retired version's stage artifacts from the
        artifact store through the existing ``prune`` machinery — minus any
        stage fingerprint a still-registered version shares (structural
        sharing is real: a pre-model stage unchanged across versions keeps
        its fingerprint, and its on-disk programs stay warm)."""
        store = getattr(self._session, "artifact_store", None)
        if store is None or not doomed:
            return
        live_fps: set[str] = set()
        for srv in {id(s): s for s in servers}.values():
            for route in srv.routes.values():
                for reg in route.versions.values():
                    live_fps |= {
                        st.fingerprint for st in reg.compiled.graph.stages
                    }
        keys = doomed - live_fps
        if keys:
            store.prune(keys=keys)

    # -- automated rollback --------------------------------------------------

    def rollback(self, name: str, *, reason: str = "operator") -> ModelVersion:
        """Cut the live model back to the version it replaced.

        The reverse swap rides the exact cutover machinery forward swaps
        use — every route flips under its scheduler's hold, so zero
        requests are dropped — and the outgoing-at-rollback version's warm
        deficit is closed first (``warm_version`` replays only ladder
        entries the restored version has not covered), so the rollback is
        also zero-retrace. The decision is recorded on both versions'
        ``events`` and in the registry's rollback log (journaled, surfaced
        by ``snapshot()`` and ``explain()``)."""
        with self._lock:
            live = self._live.get(name)
            base = self._baselines.get(name) or {}
            prev = base.get("prev")
        if live is None or prev is None or prev == live:
            raise RegistryStateError(
                f"model '{name}' has no previous live version to roll back "
                f"to — rollback needs a completed cutover first"
            )
        prev_mv = self._get_version(name, prev)
        bad_mv = self._get_version(name, live)
        # close any warm deficit the restored version accrued while demoted
        # (buckets first seen after the cutover), so the reverse swap
        # re-traces nothing
        for rt in self._routes_for(name):
            route = rt.server.routes.get(rt.serve_name)
            if route is not None and prev_mv.label in route.versions:
                rt.server.warm_version(rt.serve_name, prev_mv.label)
        self.cutover(name, prev, require_warm=True)
        with self._lock:
            # the cutover above recorded the *bad* version as the new
            # baseline "prev" — drop it, or an auto-guard could ping-pong
            # right back. Rollback is one-shot until the next forward
            # cutover records a fresh baseline.
            self._baselines.pop(name, None)
            bad_mv.events.append(f"rolled back to v{prev}: {reason}")
            prev_mv.events.append(
                f"restored live by rollback from v{live}: {reason}"
            )
            self._rollbacks.append(
                {"model": name, "from": live, "to": prev, "reason": reason}
            )
        self._journal()
        return prev_mv

    def check_rollback(
        self, name: str, policy: Optional[RollbackPolicy] = None
    ) -> Optional[ModelVersion]:
        """Evaluate the rollback policy against the live version's serving
        stats (aggregated over every route) and roll back on a breach.

        Returns the restored :class:`ModelVersion` when a rollback
        happened, else None. The three signals come from counters the
        server already keeps: per-version dispatch error rate (errors count
        even when the scheduler retried the group to success — detection
        fires before users see failures), the shadow diff-row rate observed
        while the version was mirrored, and the rolling p99 against the
        pre-cutover baseline recorded at swap time. ``policy=None`` uses
        ``ConnectOptions.rollback``; with neither, this is a no-op."""
        if policy is None:
            copts = getattr(self._session, "connect_options", None)
            policy = getattr(copts, "rollback", None)
        if policy is None:
            return None
        with self._lock:
            live = self._live.get(name)
            base = dict(self._baselines.get(name) or {})
        if live is None or base.get("prev") is None:
            return None
        label = f"v{live}"
        groups = requests = errors = 0
        sh_rows = sh_diff = 0
        p99 = 0.0
        for rt in self._routes_for(name):
            snap = rt.server.route_snapshot(rt.serve_name)
            v = snap["versions"].get(label)
            if v is None:
                continue
            groups += v["groups"]
            requests += v["requests"]
            errors += v["errors"]
            sh_rows += v["shadow_rows"]
            sh_diff += v["shadow_diff_rows"]
            p99 = max(p99, v["p99_ms"])
        if requests < policy.min_requests:
            return None
        reasons = []
        if policy.max_error_rate is not None and groups:
            rate = errors / groups
            if rate > policy.max_error_rate:
                reasons.append(
                    f"error rate {rate:.3f} > {policy.max_error_rate}"
                )
        if policy.max_shadow_diff_rate is not None and sh_rows:
            rate = sh_diff / sh_rows
            if rate > policy.max_shadow_diff_rate:
                reasons.append(
                    f"shadow diff rate {rate:.4f} > "
                    f"{policy.max_shadow_diff_rate}"
                )
        if policy.max_p99_ratio is not None and base.get("p99_ms", 0.0) > 0.0:
            ratio = p99 / base["p99_ms"]
            if ratio > policy.max_p99_ratio:
                reasons.append(
                    f"p99 {p99:.2f}ms is {ratio:.2f}x the pre-cutover "
                    f"baseline {base['p99_ms']:.2f}ms"
                )
        if not reasons:
            return None
        return self.rollback(name, reason="; ".join(reasons))

    def guard(
        self,
        name: str,
        policy: Optional[RollbackPolicy] = None,
        *,
        interval_s: float = 0.25,
        start: bool = True,
    ) -> "RollbackGuard":
        """Create (and by default start) a :class:`RollbackGuard` watching
        ``name``'s live version; ``session.close()`` stops it."""
        g = RollbackGuard(self, name, policy, interval_s=interval_s)
        with self._lock:
            self._guards.append(g)
        if start:
            g.start()
        return g

    def close(self) -> None:
        """Stop every running rollback guard (called by ``Session.close``)."""
        with self._lock:
            guards, self._guards = list(self._guards), []
        for g in guards:
            g.stop()

    # -- crash-safe journal + recovery ---------------------------------------

    def _journal(self) -> None:
        """Persist the registry's route/version topology through the
        artifact store (atomic single-file rewrite keyed on the session's
        table-schema fingerprint). Called after every lifecycle mutation;
        fail-soft by design — an unpicklable pipeline or absent store skips
        the write (counted on ``StoreStats.skipped``), never breaks the
        mutation itself."""
        store = getattr(self._session, "artifact_store", None)
        if store is None:
            return
        store.save_registry(self._session._journal_key(), self._journal_state())

    def _journal_state(self) -> dict[str, Any]:
        with self._lock:
            models: dict[str, Any] = {}
            for name, versions in self._versions.items():
                models[name] = {
                    "live": self._live.get(name),
                    "shadow": self._shadow.get(name),
                    "split": dict(self._split.get(name, {})),
                    "baseline": dict(self._baselines.get(name, {})),
                    "versions": [
                        {
                            "version": mv.version,
                            "state": mv.state,
                            "history": list(mv.history),
                            "events": list(mv.events),
                            "fingerprint": mv.fingerprint,
                            "pipeline": mv.pipeline,
                            "error": str(mv.error) if mv.error else None,
                        }
                        for mv in versions
                    ],
                }
            routes: dict[str, list] = {}
            for name, rts in self._routes.items():
                routes[name] = []
                for rt in rts:
                    prep = rt.prep
                    route = rt.server.routes.get(rt.serve_name)
                    routes[name].append({
                        "serve_name": rt.serve_name,
                        "spec": prep.query.spec,
                        "params": dict(prep.params),
                        "options": prep.options,
                        "strategy": prep.strategy,
                        "serve_options": prep._serve_options,
                        "ladder": sorted(route.ladder) if route else [],
                    })
            return {
                "models": models,
                "routes": routes,
                "rollbacks": list(self._rollbacks),
            }

    def _restore(self, state: dict[str, Any]) -> dict[str, Any]:
        """Rebuild registry + serving topology from a recovered journal
        (the implementation behind :meth:`Session.recover`). Versions and
        pointers are restored verbatim; each journaled route is re-prepared
        (a plan-layer disk hit — no re-optimization), re-served under its
        original name and options, its observed bucket ladder restored, and
        the live version warm-replayed — so the recovered server answers on
        previously-seen shapes with zero new XLA traces."""
        counts: dict[str, Any] = {
            "models": 0, "versions": 0, "routes": 0, "skipped": [],
        }
        with self._lock:
            if self._versions:
                raise RecoveryError(
                    "recover() must run on a fresh session — this registry "
                    f"already holds models {sorted(self._versions)}"
                )
            for name, rec in state.get("models", {}).items():
                versions: list[ModelVersion] = []
                for vrec in rec.get("versions", ()):
                    mv = ModelVersion(
                        name, vrec["version"], vrec["pipeline"],
                        vrec["fingerprint"],
                    )
                    mv.state = vrec["state"]
                    mv.history = list(vrec["history"])
                    mv.events = list(vrec.get("events", ()))
                    mv._ready.set()
                    versions.append(mv)
                    counts["versions"] += 1
                self._versions[name] = versions
                if rec.get("live") is not None:
                    self._live[name] = rec["live"]
                if rec.get("shadow") is not None:
                    self._shadow[name] = rec["shadow"]
                if rec.get("split"):
                    self._split[name] = dict(rec["split"])
                if rec.get("baseline"):
                    self._baselines[name] = dict(rec["baseline"])
                counts["models"] += 1
            self._rollbacks = list(state.get("rollbacks", ()))
        # re-serve journaled routes outside the lock (optimize-from-disk +
        # compile + warm-start are the slow part); one broken route is
        # skipped and reported, not fatal to the rest
        for name, rts in state.get("routes", {}).items():
            for rrec in rts:
                try:
                    self._restore_route(name, rrec)
                    counts["routes"] += 1
                except BaseException as e:  # noqa: BLE001 — fail-soft per route
                    counts["skipped"].append(
                        f"{rrec.get('serve_name', '?')}: {e}"
                    )
        # re-apply the mirrored/split topology onto the restored routes
        for name in list(state.get("models", {})):
            shadow = self._shadow.get(name)
            if shadow is not None and name in self._versions:
                self.shadow(name, shadow)
            split = self._split.get(name)
            if split:
                self.split(name, dict(split))
        return counts

    def _restore_route(self, model_name: str, rrec: dict[str, Any]) -> None:
        """Re-serve one journaled route: prepare (disk plan tier), serve
        under the original name/options, restore the bucket ladder, and
        warm-replay the live version through it."""
        from repro.session import Query

        session = self._session
        q = Query(session, rrec["spec"])
        prep = q.prepare(
            strategy=rrec.get("strategy"),
            params=rrec.get("params") or None,
            options=rrec.get("options"),
        )
        prep.serve(
            name=rrec["serve_name"], options=rrec.get("serve_options"),
        )
        srv = session.server
        route = srv.routes.get(rrec["serve_name"])
        live = self._live.get(model_name)
        if route is not None and rrec.get("ladder"):
            with srv._lock:
                route.ladder |= {tuple(e) for e in rrec["ladder"]}
        if live is not None:
            srv.warm_version(rrec["serve_name"], f"v{live}")

    # -- resolution (the one documented path) --------------------------------

    def _parse_ref(self, ref: str) -> tuple[str, Optional[str]]:
        name, sep, selector = str(ref).partition("@")
        return name, (selector if sep else None)

    def _get_version(self, name: str, version: int) -> ModelVersion:
        with self._lock:
            versions = self._versions.get(name)
            if versions is None:
                raise UnknownModelError(
                    f"unknown model '{name}' — registered models: "
                    f"{sorted(self._versions) or '(none)'}"
                )
            if not 1 <= version <= len(versions):
                raise UnknownModelVersionError(
                    f"model '{name}' has no version {version} — published: "
                    f"1..{len(versions)}"
                )
            return versions[version - 1]

    def resolve(self, ref: str) -> ModelVersion:
        """Resolve a model reference to a :class:`ModelVersion`.

        ``"name"`` / ``"name@live"`` → the live version; ``"name@2"`` →
        that exact version; ``"name@latest"`` → the newest published;
        ``"name@shadow"`` → the currently shadowed version (a
        :class:`~repro.errors.RegistryStateError` when none is)."""
        name, selector = self._parse_ref(ref)
        with self._lock:
            if name not in self._versions:
                raise UnknownModelError(
                    f"unknown model '{name}' — registered models: "
                    f"{sorted(self._versions) or '(none)'}"
                )
            if selector is None or selector == "live":
                return self._get_version(name, self._live[name])
            if selector == "latest":
                return self._get_version(name, len(self._versions[name]))
            if selector == "shadow":
                shadowed = self._shadow.get(name)
                if shadowed is None:
                    raise RegistryStateError(
                        f"model '{name}' has no shadow version — set one "
                        f"with db.models.shadow('{name}', <version>)"
                    )
                return self._get_version(name, shadowed)
            if selector.isdigit():
                return self._get_version(name, int(selector))
            raise UnknownModelVersionError(
                f"malformed model reference {ref!r} — use 'name', 'name@N', "
                f"'name@latest', 'name@live', or 'name@shadow'"
            )

    # -- the mapping protocol the SQL frontend uses --------------------------

    def __contains__(self, ref) -> bool:
        name, _ = self._parse_ref(ref)
        with self._lock:
            return name in self._versions

    def __getitem__(self, ref):
        """The resolved version's *pipeline* (what ``build_prediction_query``
        embeds in the IR) — precise typed errors instead of KeyError."""
        return self.resolve(ref).pipeline

    def __iter__(self):
        with self._lock:
            return iter(sorted(self._versions))

    def __len__(self) -> int:
        with self._lock:
            return len(self._versions)

    # -- introspection -------------------------------------------------------

    def versions(self, name: str) -> list[ModelVersion]:
        with self._lock:
            if name not in self._versions:
                raise UnknownModelError(
                    f"unknown model '{name}' — registered models: "
                    f"{sorted(self._versions) or '(none)'}"
                )
            return list(self._versions[name])

    def snapshot(self) -> dict[str, Any]:
        """Registry state for ``db.cache_stats()['models']`` and the
        analysis layer: per-model live/shadow pointers, routes, and every
        version's state + recorded history."""
        with self._lock:
            return {
                name: {
                    "live": self._live.get(name),
                    "shadow": self._shadow.get(name),
                    "split": dict(self._split.get(name, {})),
                    "routes": [r.serve_name for r in self._routes.get(name, ())],
                    "rollbacks": [
                        dict(r) for r in self._rollbacks if r["model"] == name
                    ],
                    "versions": [
                        {
                            "version": mv.version,
                            "state": mv.state,
                            "history": list(mv.history),
                            "events": list(mv.events),
                            "fingerprint": mv.fingerprint,
                            "error": str(mv.error) if mv.error else None,
                        }
                        for mv in versions
                    ],
                }
                for name, versions in self._versions.items()
            }


class RollbackGuard:
    """Background watchdog for one model's live version.

    Periodically runs :meth:`ModelRegistry.check_rollback` and stops
    itself after triggering (rollback is one-shot until the next forward
    cutover records a fresh baseline) or on a contained evaluation error
    (``error`` — a watchdog must never raise into the serving path). The
    cadence uses ``Event.wait`` — no wall-clock reads — so ``stop()``
    interrupts a sleeping guard immediately.
    """

    def __init__(
        self,
        registry: ModelRegistry,
        name: str,
        policy: Optional[RollbackPolicy] = None,
        *,
        interval_s: float = 0.25,
    ):
        self._registry = registry
        self.name = name
        self.policy = policy
        self.interval_s = float(interval_s)
        self.checks = 0
        self.triggered: Optional[dict[str, Any]] = None
        self.error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name=f"rollback-guard-{name}", daemon=True
        )

    def start(self) -> "RollbackGuard":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)

    @property
    def running(self) -> bool:
        return self._thread.is_alive()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.checks += 1
            try:
                restored = self._registry.check_rollback(
                    self.name, self.policy
                )
            except BaseException as e:  # noqa: BLE001 — contained watchdog
                self.error = e
                return
            if restored is not None:
                self.triggered = {
                    "model": self.name, "restored": restored.version,
                }
                return
