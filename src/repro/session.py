"""One front door for prediction queries: sessions, prepared queries, EXPLAIN.

The paper's Raven is *one* system — parse, unified IR, optimize, pick a
runtime, serve. This module is the single user-facing surface over those
layers::

    import repro as raven

    db = raven.connect(tables, stats="auto")        # tables + stats, once
    db.models.publish("risk", pipe)                 # the model registry
    #   (db.register_model(...) remains as a thin alias)

    q = db.sql(
        "SELECT * FROM PREDICT(model='risk', data=patients) AS p "
        "WHERE score >= :t"
    )
    # ...or the fluent builder — same unified IR, same fingerprint:
    q = db.table("patients").predict("risk").where("score >= :t")

    prep = q.prepare(transform="sql", params={"t": 0.6})
    print(prep.explain())        # logical -> physical -> stage graph
    out = prep(batch)            # one-shot execution
    prep.serve(max_latency_ms=5) # register + background request pump
    r = prep.submit(batch)       # bucketed, coalesced hot path ...
    out = r.wait()               # ... flushed by the pump, no db.flush()
    prep.bind(t=0.9)             # re-bind: same plan, zero new XLA traces
    db.cache_stats()             # plan-cache + per-stage trace accounting

``:param`` placeholders lower to canonical ``Param`` slots that hash by name,
so a prepared plan re-binds thresholds without re-optimizing, re-compiling,
or changing any fingerprint the serving layer keys on. ``serve()`` without a
latency target keeps the synchronous submit/``db.flush()`` protocol.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core.ir import (
    PredictionQuery,
    TableStats,
    format_logical_plan,
)
from repro.core.optimizer import (
    OptimizationReport,
    OptimizerOptions,
    RavenOptimizer,
    format_physical_plan,
)
from repro.errors import (
    RavenError,
    RecoveryError,
    UnknownTableError,
    check_params,
)
from repro.exec.faults import get_fault_plan, set_fault_plan
from repro.options import ConnectOptions, ServeOptions
from repro.relational.engine import (
    PhysicalPlan,
    Scan,
    compile_plan,
    walk_plan,
)
from repro.relational.expr import Const, Expr, Param
from repro.serve.query_server import PredictionQueryServer, QueryRequest
from repro.sql.parser import (
    QuerySpec,
    build_prediction_query,
    canonical_op,
    parse_condition,
    parse_select_items,
    parse_spec,
)


def connect(
    tables: dict[str, dict[str, np.ndarray]],
    stats: Union[str, dict[str, TableStats], None] = "auto",
    *,
    partition_cols: Optional[dict[str, str]] = None,
    strategy=None,
    options: Union[ConnectOptions, OptimizerOptions, None] = None,
    cache_dir: Optional[str] = None,
    cache_max_bytes: Optional[int] = None,
    verify: Union[str, bool, None] = None,
) -> "Session":
    """Open a session over a database of named column-dict tables.

    ``stats="auto"`` computes :class:`TableStats` for every table once (with
    optional per-table partition columns for the data-induced rule); pass a
    dict to supply stats yourself, or ``None`` to skip statistics entirely.
    ``strategy``/``options`` set session-wide optimizer defaults that
    :meth:`Query.prepare` can override per query.

    ``options`` is the typed front door: a :class:`repro.ConnectOptions`
    bundling every session knob (optimizer, strategy, partition columns,
    cache, verification) with a content-stable fingerprint that
    ``explain()`` renders. A bare :class:`OptimizerOptions` is still
    accepted directly. The loose ``cache_dir``/``cache_max_bytes``/
    ``verify`` keywords keep working through a shim that emits
    :class:`DeprecationWarning`; a keyword conflicting with the bundle
    raises.

    ``cache_dir`` enables **warm starts across processes**: an
    :class:`~repro.exec.artifact_store.ArtifactStore` rooted there persists
    optimizer output per query fingerprint (``prepare()`` skips
    re-optimization when the query, statistics, and model weights match) and
    AOT-exports every compiled stage program per shape bucket (a fresh
    process deserializes instead of re-tracing; ``serve()`` preloads all
    buckets found on disk at registration). Artifacts are keyed on canonical
    content fingerprints and checked against a version/backend header, so a
    stale or corrupted cache falls back to live compilation — never wrong
    results. The store is installed process-wide (the compiled-plan cache it
    backs is process-wide too); the most recent ``connect`` wins.
    ``cache_max_bytes`` bounds the cache dir by total size (oldest entries
    evicted first) on top of the store's entry-count cap.

    ``verify`` sets the session-wide plan-verification mode: ``"off"`` (the
    default), ``"warn"`` (verifier violations surface as
    :class:`~repro.analysis.rules.VerificationWarning`), or ``"strict"``
    (:class:`~repro.errors.PlanVerificationError`). ``True`` means
    ``"strict"``. Unset, the ``RAVEN_VERIFY`` environment variable applies.
    The verifier runs differentially after each optimizer rewrite and again
    over the lowered stage graph at prepare time; the mode never changes
    which plan is produced, only whether it is checked, so it is excluded
    from every plan fingerprint and cache key.
    """
    return Session(
        tables, stats, partition_cols=partition_cols,
        strategy=strategy, options=options, cache_dir=cache_dir,
        cache_max_bytes=cache_max_bytes, verify=verify,
    )


class Session:
    """Owns the database, statistics, model registry, and serving layer."""

    def __init__(
        self,
        tables: dict[str, dict[str, np.ndarray]],
        stats: Union[str, dict[str, TableStats], None] = "auto",
        *,
        partition_cols: Optional[dict[str, str]] = None,
        strategy=None,
        options: Union[ConnectOptions, OptimizerOptions, None] = None,
        cache_dir: Optional[str] = None,
        cache_max_bytes: Optional[int] = None,
        verify: Union[str, bool, None] = None,
    ):
        copts = ConnectOptions.resolve(
            options, partition_cols=partition_cols, strategy=strategy,
            cache_dir=cache_dir, cache_max_bytes=cache_max_bytes,
            verify=verify,
        )
        self.connect_options = copts
        opt_options = copts.optimizer
        if copts.verify is not None:
            from repro.analysis.verifier import resolve_verify_mode

            opt_options = dataclasses.replace(
                opt_options or OptimizerOptions(),
                verify=resolve_verify_mode(copts.verify),
            )
        self.tables = {
            t: {c: np.asarray(v) for c, v in cols.items()}
            for t, cols in tables.items()
        }
        if stats == "auto":
            parts = copts.partition_cols or {}
            self.stats = {
                t: TableStats.of(cols, partition_col=parts.get(t))
                for t, cols in self.tables.items()
            }
        elif stats is None:
            self.stats = {}
        elif isinstance(stats, dict):
            self.stats = dict(stats)
        else:
            raise RavenError(
                f"stats must be 'auto', a dict, or None — got {stats!r}"
            )
        from repro.serve.registry import ModelRegistry

        self.models = ModelRegistry(self)
        self.strategy = copts.strategy
        self.options = opt_options
        from repro.relational.engine import set_artifact_store

        self.artifact_store = None
        if copts.cache_dir is not None:
            from repro.exec.artifact_store import ArtifactStore

            self.artifact_store = ArtifactStore(
                copts.cache_dir, max_bytes=copts.cache_max_bytes
            )
        # the most recent connect wins — including a cache-less connect,
        # which must *clear* a previous session's store rather than let it
        # keep intercepting (and writing to) every later compilation
        set_artifact_store(self.artifact_store)
        # a session-supplied FaultPlan is installed process-wide for its
        # lifetime (same most-recent-wins contract as the artifact store);
        # without one, the RAVEN_FAULTS env plan (if any) stays in effect
        self._fault_plan = copts.faults
        if copts.faults is not None:
            set_fault_plan(copts.faults)
        self._server: Optional[PredictionQueryServer] = None
        self._names = itertools.count()

    # -- registration --------------------------------------------------------

    def register_model(self, name: str, pipe_or_path):
        """Thin alias for :meth:`ModelRegistry.publish` — kept so existing
        call sites work unchanged (same contract: returns the pipeline).
        New code should use ``db.models.publish(name, pipe)``, which returns
        the :class:`~repro.serve.registry.ModelVersion` lifecycle handle."""
        return self.models.publish(name, pipe_or_path).pipeline

    # -- query construction --------------------------------------------------

    def sql(self, text: str) -> "Query":
        """Parse PREDICT-statement SQL into a session-bound :class:`Query`."""
        q = Query(self, parse_spec(text))
        _ = q.ir  # build eagerly: unknown models/tables/columns fail here
        return q

    def table(self, name: str) -> "QueryBuilder":
        """Start a fluent query over ``name`` (the fact table)."""
        if name not in self.tables:
            raise UnknownTableError(
                f"unknown table '{name}' — known tables: {sorted(self.tables)}"
            )
        return QueryBuilder(self, QuerySpec(base=name))

    # -- serving -------------------------------------------------------------

    @property
    def server(self) -> PredictionQueryServer:
        """The session-owned :class:`PredictionQueryServer` (created lazily)."""
        if self._server is None:
            self._server = PredictionQueryServer(
                strategy=self.strategy, options=self.options
            )
        return self._server

    def flush(self) -> list[QueryRequest]:
        """Execute everything submitted to served queries (micro-batched)."""
        return self._server.flush() if self._server is not None else []

    def cache_stats(self) -> dict:
        """Compiled-plan cache + serving accounting, in one snapshot.

        Returns the engine's :class:`CacheStats` snapshot (``hits``/
        ``misses``/``traces``/``disk_hits``/``disk_misses`` plus per-stage
        ``stage_traces`` keyed by stage fingerprint) merged with the session
        server's :class:`ServerStats` under ``"server"`` — including the
        scheduler's queue gauges (``queue_depths``, ``max_queue_depth``,
        ``backpressure_waits``, ``overloads``) and the pipelined executor's
        overlap gauges under ``"server"]["pipeline"`` (groups in flight,
        ``overlap_s`` wall time with ≥2 groups overlapping, host-pool busy
        time) — and, when the session was opened with ``cache_dir``, the
        artifact store's :class:`~repro.exec.artifact_store.StoreStats`
        under ``"artifact_store"``, so benchmarks and tests can assert
        zero-retrace warm paths without reaching into module globals.

        Fault tolerance is accounted here too: the server snapshot carries
        scheduler retry gauges (``retries``/``retries_exhausted``/
        ``redo_depth``), ``breaker_trips``, per-version breaker/fallback
        state in ``route_snapshot``, and ``faults_injected`` per injection
        site when a :class:`~repro.exec.faults.FaultPlan` is installed; the
        artifact-store snapshot carries corruption/quarantine and
        ``fallbacks`` counts plus registry-journal save/load counters.
        """
        from repro.relational.engine import PLAN_CACHE_STATS

        out = PLAN_CACHE_STATS.snapshot()
        if self._server is not None:
            out["server"] = self._server.stats_snapshot()
            out["server"]["recompiles"] = self._server.recompiles()
        if self.artifact_store is not None:
            out["artifact_store"] = self.artifact_store.stats.snapshot()
        out["models"] = self.models.snapshot()
        return out

    def close(self) -> None:
        """Stop the background request pump (drains pending requests) and
        any running rollback guards, release the boundary pool, flush the
        artifact store's background writer, uninstall this session's
        artifact store and fault plan (if still the active ones)."""
        self.models.close()  # stop rollback guards before the pump drains
        if self._server is not None:
            self._server.shutdown()
        if self.artifact_store is not None:
            from repro.relational.engine import get_artifact_store, set_artifact_store

            self.artifact_store.close()  # flush writes + stop the writer
            if get_artifact_store() is self.artifact_store:
                set_artifact_store(None)
        if self._fault_plan is not None and get_fault_plan() is self._fault_plan:
            set_fault_plan(None)

    def recover(self) -> dict:
        """Rebuild the model registry + serving topology from the journal.

        A session opened with ``cache_dir`` journals every registry
        lifecycle mutation (publish/shadow/split/cutover/retire/rollback and
        route registrations) through the artifact store, keyed on the
        session's table-schema fingerprint. After a crash, a fresh session
        over the same tables and cache dir calls ``recover()`` to restore
        published versions (with their recorded histories), live/shadow/
        split pointers, the rollback log, and every served route — re-served
        under its original name and options, its observed bucket ladder
        restored and warm-replayed from on-disk stage executables, so the
        recovered server answers previously-seen shapes with zero new XLA
        traces. Returns ``{"recovered": False}`` when no journal exists,
        else counts (models/versions/routes restored, routes skipped)."""
        if self.artifact_store is None:
            raise RecoveryError(
                "recover() needs an artifact store — connect with "
                "ConnectOptions(cache_dir=...)"
            )
        state = self.artifact_store.load_registry(self._journal_key())
        if state is None:
            return {"recovered": False}
        counts = self.models._restore(state)
        counts["recovered"] = True
        return counts

    def _journal_key(self) -> str:
        """The registry journal's store key: a fingerprint of the session's
        table schemas (names, columns, dtypes — not row contents), so a
        restarted server over the same database finds its journal while a
        schema change quietly orphans the stale one."""
        from repro.core.fingerprint import fingerprint

        return fingerprint(
            "registry-journal",
            tuple(
                (t, tuple((c, str(v.dtype)) for c, v in sorted(cols.items())))
                for t, cols in sorted(self.tables.items())
            ),
        )

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _next_name(self) -> str:
        return f"q{next(self._names)}"


class Query:
    """A prediction query bound to a session (unified IR + parameters)."""

    def __init__(self, session: Session, spec: QuerySpec):
        self._session = session
        self._spec = spec
        self._ir: Optional[PredictionQuery] = None

    @property
    def session(self) -> Session:
        return self._session

    @property
    def spec(self) -> QuerySpec:
        return self._spec

    @property
    def ir(self) -> PredictionQuery:
        """The unified IR (built once; SQL text and the fluent builder lower
        through the same spec -> IR path, so equal queries hash equal)."""
        if self._ir is None:
            self._ir = build_prediction_query(
                self._spec, self._session.models, self._session.tables,
                self._session.stats,
            )
        return self._ir

    def fingerprint(self) -> str:
        return self.ir.fingerprint()

    def param_names(self) -> frozenset[str]:
        return frozenset(self.ir.params())

    def prepare(
        self,
        *,
        strategy=None,
        transform: Optional[str] = None,
        params: Optional[dict[str, Any]] = None,
        options: Optional[OptimizerOptions] = None,
        verify: Union[str, bool, None] = None,
    ) -> "PreparedQuery":
        """Run the optimizer once and compile; returns a reusable handle.

        ``transform`` forces a runtime ({'none','sql','dnn'}); ``strategy``
        picks one from pipeline statistics; ``options`` overrides the full
        optimizer configuration. All ``:param`` placeholders must be bound
        via ``params`` (re-bindable later with :meth:`PreparedQuery.bind`).

        ``verify`` overrides the session's plan-verification mode for this
        prepare only — ``True`` (= ``"strict"``) raises
        :class:`~repro.errors.PlanVerificationError` on any verifier
        violation, ``"warn"`` warns, ``"off"`` disables. The mode does not
        change the produced plan, its fingerprint, or any cache key.

        When the session has an artifact store (``connect(cache_dir=...)``),
        the optimizer's output is persisted per query fingerprint — a fresh
        process re-preparing the same query over the same statistics and
        model weights loads the optimized plan from disk instead of
        re-running the optimizer (a changed fingerprint simply misses and
        optimizes live).
        """
        opts = options or self._session.options or OptimizerOptions()
        if transform is not None:
            opts = dataclasses.replace(opts, transform=transform)
        if verify is not None:
            from repro.analysis.verifier import resolve_verify_mode

            opts = dataclasses.replace(opts, verify=resolve_verify_mode(verify))
        strat = strategy if strategy is not None else self._session.strategy
        declared = self.param_names()
        bound = dict(params or {})
        check_params(declared, bound, context="query")
        plan, report = self._optimize(opts, strat)
        return PreparedQuery(self, plan, report, opts, strat, bound)

    def _optimize(self, opts: OptimizerOptions, strat):
        """Run the optimizer, through the disk tier when one is active."""
        from repro.core.fingerprint import fingerprint
        from repro.relational.engine import PLAN_CACHE_STATS

        store = self._session.artifact_store
        key: Optional[str] = None
        if store is not None:
            # the optimizer is a pure function of (IR plan incl. model
            # weights, stats, options, strategy); a key hashing any component
            # by identity is not valid in another process, so skip the store.
            # the verify mode only decides whether the plan is *checked*,
            # never what plan comes out, so it must not fork cache entries
            pins: list = []
            key = fingerprint(
                self.ir.plan, self.ir.stats,
                dataclasses.replace(opts, verify=None), strat, pins=pins,
            )
            if pins:
                store.stats.skipped += 1
                key = None
        if key is not None:
            hit = store.load_plan(key)
            if hit is not None:
                PLAN_CACHE_STATS.disk_hits += 1
                return hit
            PLAN_CACHE_STATS.disk_misses += 1
        plan, report = RavenOptimizer(strategy=strat, options=opts).optimize(
            self.ir
        )
        if key is not None:
            store.save_plan(key, plan, report)
        return plan, report


class QueryBuilder(Query):
    """Fluent construction of the same :class:`QuerySpec` the SQL parser
    produces (so builder and SQL queries are fingerprint-identical)."""

    def _with(self, **changes) -> "QueryBuilder":
        return QueryBuilder(
            self._session, dataclasses.replace(self._spec, **changes)
        )

    def join(
        self, dim_table: str, on: Union[str, tuple[str, str]]
    ) -> "QueryBuilder":
        """FK-join a dimension table; ``on`` is a shared key name or a
        ``(fact_col, dim_col)`` pair."""
        a, b = (on, on) if isinstance(on, str) else on
        return self._with(joins=[*self._spec.joins, (dim_table, a, b)])

    def predict(self, model: str) -> "QueryBuilder":
        """Apply a registered model (its outputs become columns
        ``score``/``pred``)."""
        return self._with(model=model)

    def where(
        self, cond: str, op: Optional[str] = None, value: Any = None
    ) -> "QueryBuilder":
        """Add one conjunct: ``where("score >= :t")`` or
        ``where("score", ">=", 0.6)``."""
        if op is None:
            pred = parse_condition(cond)
        else:
            if isinstance(value, Expr):
                v = value
            elif isinstance(value, str):
                # same lowering as the SQL parser: ':name' is a parameter,
                # any other string a literal
                v = Param(value[1:]) if value.startswith(":") else Const(value)
            else:
                v = Const(float(value))
            pred = (cond, canonical_op(op), v)
        return self._with(preds=[*self._spec.preds, pred])

    def select(self, *items: str) -> "QueryBuilder":
        """Set the select list, e.g. ``select("COUNT(*)", "AVG(score)")``;
        the default (no select) is ``*``."""
        parsed = [it for s in items for it in parse_select_items(s)]
        return self._with(items=parsed)


class PreparedQuery:
    """An optimized + compiled prediction query.

    ``plan``/``report`` are the optimizer's output; ``compiled`` the cached
    stage executables. Call it for one-shot execution, :meth:`serve` it for
    the bucketed micro-batched hot path, :meth:`bind` to re-bind ``:param``
    values without re-optimizing (fingerprint-stable, zero new XLA traces).
    """

    def __init__(
        self,
        query: Query,
        plan: PhysicalPlan,
        report: OptimizationReport,
        options: OptimizerOptions,
        strategy,
        params: dict[str, Any],
    ):
        self.query = query
        self.plan = plan
        self.report = report
        self.options = options
        self.strategy = strategy
        self.params = dict(params)
        self.compiled = compile_plan(plan)
        self._verify_compiled()
        self.param_names = query.param_names()
        self._serve_name: Optional[str] = None
        self._serve_token: Optional[str] = None
        self._serve_options: Optional[ServeOptions] = None
        self._server: Optional[PredictionQueryServer] = None

    def _verify_compiled(self) -> None:
        """Static verification of the lowered stage graph (mode permitting).

        Runs at prepare time — after ``compile_plan`` — so it also covers
        plans loaded from the artifact store, which skip the optimizer's
        differential checks. Verified lines land in
        ``report.verification`` (rendered by :meth:`explain`); strict mode
        raises :class:`~repro.errors.PlanVerificationError`.
        """
        from repro.analysis.verifier import (
            check_exec,
            check_graph,
            enforce,
            resolve_verify_mode,
        )

        mode = resolve_verify_mode(getattr(self.options, "verify", None))
        if mode == "off":
            return
        vs = check_graph(self.compiled.graph)
        vs += check_exec(self.compiled.graph, self.query.session.tables)
        lines = enforce(vs, mode, "prepare (stage graph)")
        ver = getattr(self.report, "verification", None)
        if ver is None:  # report unpickled from a pre-verifier artifact
            ver = self.report.verification = []
        ver += [ln for ln in lines if ln not in ver]

    @property
    def fingerprint(self) -> str:
        """Content hash of the physical plan (the compiled-plan cache key)."""
        return self.compiled.fingerprint

    @property
    def name(self) -> Optional[str]:
        """The name this query is served under (None until :meth:`serve`)."""
        return self._serve_name

    # -- parameter binding ---------------------------------------------------

    def bind(self, _params: Optional[dict[str, Any]] = None, **kw) -> "PreparedQuery":
        """Re-bind ``:param`` values: ``prep.bind(t=0.9)``.

        The optimized plan, its fingerprint, and every compiled XLA program
        are reused as-is — the value rides in as a runtime input.
        """
        new = {**(_params or {}), **kw}
        check_params(self.param_names, new, require_all=False, context="query")
        self.params.update(new)
        if self._server is not None:
            self._server.rebind(self._serve_name, new)
        return self

    # -- one-shot execution --------------------------------------------------

    def __call__(
        self, batch: Optional[dict[str, np.ndarray]] = None
    ) -> dict[str, np.ndarray]:
        """Execute once against the session tables (``batch`` replaces the
        fact table's rows) and return compacted numpy columns."""
        session = self.query.session
        db = dict(session.tables)
        fact = self._fact_table()
        if batch is not None:
            scan_cols = {
                c for s in walk_plan(self.plan)
                if isinstance(s, Scan) and s.table == fact
                for c in s.columns
            }
            missing = sorted(scan_cols - set(batch))
            if missing:
                raise RavenError(
                    f"batch for fact table '{fact}' is missing columns "
                    f"{missing}"
                )
            db[fact] = batch
        jdb = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in db.items()
        }
        table = self.compiled(
            jdb, params=self.params if self.param_names else None
        )
        return table.to_numpy(compact=True)

    def _fact_table(self) -> str:
        base = self.query.spec.base
        if base is not None:
            return base
        return next(s.table for s in walk_plan(self.plan) if isinstance(s, Scan))

    # -- serving -------------------------------------------------------------

    def serve(
        self,
        name: Optional[str] = None,
        server: Optional[PredictionQueryServer] = None,
        *,
        options: Optional[ServeOptions] = None,
        max_latency_ms: Optional[float] = None,
        max_pending: Optional[int] = None,
        max_coalesce: Optional[int] = None,
    ) -> "PreparedQuery":
        """Register into the session-owned server (bucketed, coalesced hot
        path): afterwards ``prep.submit(batch)`` enqueues.

        ``options`` is the typed surface (:class:`repro.ServeOptions`); the
        loose keywords keep working through a :class:`DeprecationWarning`
        shim, and a keyword conflicting with the bundle raises. With
        ``max_latency_ms`` a background pump flushes automatically once
        this query's oldest pending request has waited that long — results
        arrive via ``request.wait()`` with no ``db.flush()`` required, and
        queues are flushed earliest-deadline-first so a tight target keeps
        its priority next to bulk queries. Without it the protocol stays
        synchronous (caller drives ``db.flush()``).

        ``max_pending`` bounds this query's queue: a submit against a full
        queue blocks (``prep.submit(..., block=True)``) or raises
        :class:`~repro.errors.ServerOverloadedError`. ``max_coalesce`` caps
        how many rows one dispatched group may coalesce, so a huge backlog
        is pipelined as bounded groups instead of monopolizing a flush.

        Serving also registers this query's route with the session's
        :class:`~repro.serve.registry.ModelRegistry`: later
        ``db.models.publish()`` calls for the referenced model stage their
        new version onto this route, and ``shadow``/``split``/``cutover``
        act on it.
        """
        sopts = ServeOptions.resolve(
            options, max_latency_ms=max_latency_ms,
            max_pending=max_pending, max_coalesce=max_coalesce,
        )
        self._serve_options = sopts
        session = self.query.session
        srv = server if server is not None else session.server
        self._serve_name = name or session._next_name()
        model_ref = self.query.spec.model
        version_label = "v1"
        if model_ref is not None:
            try:
                version_label = session.models.resolve(model_ref).label
            except RavenError:
                pass  # model outside the registry (e.g. a bare test server)
        reg = srv.register(
            self._serve_name, self.query.ir, session.tables,
            fact_table=self._fact_table(),
            optimized=(self.plan, self.report),
            params=self.params,
            max_latency_ms=sopts.max_latency_ms,
            max_pending=sopts.max_pending,
            max_coalesce=sopts.max_coalesce,
            version_label=version_label,
            donate=sopts.donate,
            retry=sopts.retry,
            breaker_threshold=sopts.breaker_threshold,
        )
        self._serve_token = reg.token
        self._server = srv
        if model_ref is not None:
            session.models._track_route(
                model_ref, self._serve_name, self, srv
            )
        if sopts.max_latency_ms is not None:
            srv.start_pump(sopts.max_latency_ms)
        return self

    def submit(
        self,
        columns: dict[str, np.ndarray],
        *,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> QueryRequest:
        """Enqueue one fact-row batch (requires :meth:`serve` first); results
        land on the returned request after ``db.flush()`` — or, when the
        query is served with a latency target, after the pump's next flush
        (``request.wait()``). Submitting through a handle whose serve name
        was since re-registered (different plan or bound params) raises
        :class:`~repro.errors.StaleQueryError`; a submit against a full
        bounded queue (``serve(max_pending=...)``) blocks up to ``timeout``
        seconds or (``block=False``) raises
        :class:`~repro.errors.ServerOverloadedError`."""
        if self._server is None:
            raise RavenError(
                "query is not served — call .serve() before .submit()"
            )
        return self._server.submit(
            self._serve_name, columns, expect_token=self._serve_token,
            block=block, timeout=timeout,
        )

    # -- introspection -------------------------------------------------------

    def explain(self) -> str:
        """Pretty-print the logical -> physical story: the query as written,
        the optimized plan (chosen runtimes, pushed projections, rewritten
        thresholds), and the optimizer's notes."""
        session = self.query.session
        lines = [f"PreparedQuery  fingerprint={self.fingerprint[:16]}…"]
        if self.param_names:
            binds = ", ".join(
                f":{k} = {self.params[k]!r}" if k in self.params else f":{k} (unbound)"
                for k in sorted(self.param_names)
            )
            lines.append(f"params: {binds}")
        lines.append("-- resolved options " + "-" * 35)
        lines.append(f"connect: {session.connect_options.describe()}")
        if self._serve_options is not None:
            lines.append(f"serve:   {self._serve_options.describe()}")
        model_ref = self.query.spec.model
        if model_ref is not None:
            name = str(model_ref).partition("@")[0]
            rec = session.models.snapshot().get(name)
            if rec is not None:
                lines.append("-- model lifecycle " + "-" * 36)
                extra = ""
                if rec["shadow"] is not None:
                    extra += f", shadow=v{rec['shadow']}"
                if rec["split"]:
                    extra += f", split={rec['split']}"
                lines.append(f"{name}: live=v{rec['live']}{extra}")
                for r in rec["rollbacks"]:
                    lines.append(
                        f"* rolled back v{r['from']} -> v{r['to']}: "
                        f"{r['reason']}"
                    )
        lines.append("-- logical plan (as written) " + "-" * 26)
        lines.append(format_logical_plan(self.query.ir.plan))
        lines.append("-- physical plan (optimized) " + "-" * 26)
        lines.append(format_physical_plan(self.plan))
        lines.append("-- chosen runtimes " + "-" * 36)
        for i, t in sorted(self.report.transforms.items()):
            lines.append(f"predict[{i}] -> {t}")
        if self.report.placement:
            lines.append("-- runtime placement (per pipeline op) " + "-" * 17)
            for i, nodes in enumerate(self.report.placement):
                runtimes = {r for _, r in nodes}
                if any("/" in r for r in runtimes):
                    # split lowering: summarize each contiguous segment
                    lines.append(f"predict[{i}]: split across runtimes")
                    for label, r in nodes:
                        lines.append(f"  {r:<16} {label}")
                else:
                    only = runtimes.pop() if len(runtimes) == 1 else None
                    if only is not None:
                        lines.append(
                            f"predict[{i}]: all {len(nodes)} ops on {only}"
                        )
                    else:
                        for label, r in nodes:
                            lines.append(f"  {r:<16} {label}")
        relational = getattr(self.report, "relational", [])
        if relational:
            lines.append("-- runtime placement (relational ops) " + "-" * 18)
            for label, r in relational:
                lines.append(f"  {label}")
                lines.append(f"    -> {r}")
        scans = [s for s in walk_plan(self.plan) if isinstance(s, Scan)]
        if scans:
            lines.append("-- pushed projections " + "-" * 33)
            for s in scans:
                total = len(session.tables.get(s.table, s.columns))
                lines.append(
                    f"{s.table}: reads {len(s.columns)}/{total} columns"
                )
        if self.report.notes:
            lines.append("-- optimizer notes " + "-" * 36)
            for n in self.report.notes:
                lines.append(f"* {n}")
        verification = getattr(self.report, "verification", [])
        if verification:
            lines.append("-- plan verification " + "-" * 34)
            for v in verification:
                lines.append(f"* {v}")
        graph = self.compiled.graph
        summary = "1 fused XLA program" if self.compiled.is_pure else (
            f"{self.compiled.n_stages} stages, "
            f"{graph.n_host_boundaries} host boundary(ies)"
        )
        lines.append(f"-- stage graph: {summary} " + "-" * 20)
        for st in graph.stages:
            lines.append(st.describe())
        return "\n".join(lines)

    def __repr__(self) -> str:
        served = f", served as '{self._serve_name}'" if self._serve_name else ""
        return (
            f"PreparedQuery(fingerprint={self.fingerprint[:12]}…, "
            f"params={self.params}{served})"
        )
