"""Batched serving driver (deliverable b, serving kind): continuous-batching
engine over a small trained LM — requests of mixed lengths stream through
fixed-shape prefill/decode programs with slot recycling.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

from repro.configs import reduced_config
from repro.launch.train import train_loop
from repro.models import build_model
from repro.serve import ServeEngine

# quick-train a tiny LM so generations are non-degenerate
print("training a tiny LM for 40 steps...")
out = train_loop(arch="qwen2-0.5b", steps=40, batch=8, seq=64, lr=2e-3,
                 log_every=20)
params = out["params"]
model = build_model(reduced_config("qwen2-0.5b"))

eng = ServeEngine(model, params, n_slots=4, cache_len=128)
rng = np.random.default_rng(0)
print("submitting 12 requests (mixed prompt lengths, max_new_tokens=16)...")
reqs = [
    eng.submit(list(rng.integers(1, 100, rng.integers(2, 24))),
               max_new_tokens=16)
    for _ in range(12)
]
t0 = time.perf_counter()
done = eng.run()
dt = time.perf_counter() - t0
tokens = sum(len(r.output) for r in done)
print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens/dt:.1f} tok/s through 4 slots)")
for r in done[:3]:
    print(f"  req {r.rid}: prompt[:6]={r.prompt[:6]} -> {r.output}")
assert len(done) == 12
