"""Quickstart: train a pipeline, write a PREDICT query, let Raven optimize it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.core.ir import TableStats
from repro.data.datasets import make_hospital
from repro.ml import GradientBoostingClassifier, fit_pipeline
from repro.relational.engine import execute_plan
from repro.sql.parser import parse_prediction_query

# 1. data + trained pipeline (scaler + one-hot + gradient boosting)
ds = make_hospital(50_000)
joined = ds.joined_columns()
pipe = fit_pipeline(
    joined, ds.label, ds.numeric, ds.categorical,
    GradientBoostingClassifier(n_estimators=20, max_depth=3),
    categories=ds.categories(),
)
print(f"trained pipeline: {pipe.n_ops()} ops, {len(pipe.inputs)} inputs")

# 2. a prediction query (SQL Server PREDICT-TVF syntax, paper §6)
sql = """
    SELECT COUNT(*), AVG(score)
    FROM PREDICT(model = 'covid_risk', data = patients) AS p
    WHERE asthma = 1 AND score >= 0.5
"""
query = parse_prediction_query(
    sql, {"covid_risk": pipe}, ds.tables,
    stats={"patients": TableStats.of(ds.tables["patients"])},
)

# 3. optimize + execute: unoptimized vs Raven
for label, opts in [
    ("no-opt", OptimizerOptions(predicate_pruning=False,
                                projection_pushdown=False,
                                data_induced=False, transform="none")),
    ("raven ", OptimizerOptions()),  # logical rules + default physical pick
]:
    plan, report = RavenOptimizer(options=opts).optimize(query)
    out = execute_plan(plan, ds.tables)
    cols = {k: float(np.asarray(v)[0]) for k, v in out.columns.items()}
    print(f"{label}: {cols}  notes={report.notes}")
