"""Quickstart: connect, register a model, write a PREDICT query, prepare it,
read the EXPLAIN, execute, and re-bind the threshold — all through the
session front door.

    PYTHONPATH=src python examples/quickstart.py

Set RAVEN_EXAMPLE_N to shrink the dataset (used by the examples smoke test).
"""
import os

import repro as raven
from repro.core.optimizer import OptimizerOptions
from repro.data.datasets import make_hospital
from repro.ml import GradientBoostingClassifier, fit_pipeline

N = int(os.environ.get("RAVEN_EXAMPLE_N", 50_000))

# 1. data + trained pipeline (scaler + one-hot + gradient boosting)
ds = make_hospital(N)
pipe = fit_pipeline(
    ds.joined_columns(), ds.label, ds.numeric, ds.categorical,
    GradientBoostingClassifier(n_estimators=20, max_depth=3),
    categories=ds.categories(),
)
print(f"trained pipeline: {pipe.n_ops()} ops, {len(pipe.inputs)} inputs")

# 2. one front door: session owns tables, stats, models
db = raven.connect(ds.tables, stats="auto")
db.register_model("covid_risk", pipe)

# 3. a prediction query (SQL Server PREDICT-TVF syntax, paper §6) with a
#    named :threshold parameter
query = db.sql("""
    SELECT COUNT(*), AVG(score)
    FROM PREDICT(model = 'covid_risk', data = patients) AS p
    WHERE asthma = 1 AND score >= :threshold
""")

# ... the fluent builder produces the identical IR (same fingerprint):
built = (
    db.table("patients").predict("covid_risk")
    .where("asthma = 1").where("score >= :threshold")
    .select("COUNT(*)", "AVG(score)")
)
assert built.fingerprint() == query.fingerprint()

# 4. prepare: optimizer runs once; EXPLAIN shows the logical -> physical story
prep = query.prepare(params={"threshold": 0.5})
print(prep.explain())

# 5. execute: unoptimized baseline vs Raven
noopt = query.prepare(
    params={"threshold": 0.5},
    options=OptimizerOptions(predicate_pruning=False,
                             projection_pushdown=False,
                             data_induced=False, transform="none"),
)
print(f"no-opt: { {k: float(v[0]) for k, v in noopt().items()} }")
print(f"raven : { {k: float(v[0]) for k, v in prep().items()} }")

# 6. re-bind the threshold: same plan, same compiled program, new answer
prep.bind(threshold=0.8)
print(f"raven (threshold=0.8): { {k: float(v[0]) for k, v in prep().items()} }")
