"""End-to-end LM training driver (deliverable b): trains a ~100M-param
qwen2-family model for a few hundred steps on CPU with the full
fault-tolerance stack (checkpointing, straggler monitor, deterministic
elastic loader), reporting loss curve + restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

By default runs the reduced config (CPU-friendly). `--width 512 --layers 8`
gets ~100M params if you have minutes to spare.
"""
import argparse

import numpy as np

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--width", type=int, default=0, help="override d_model")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    out = train_loop(
        arch="qwen2-0.5b", reduced=True, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        kill_host=3, kill_at_step=args.steps // 2,  # fault injection demo
        log_every=20,
    )
    losses = out["losses"]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    print("resuming from the last checkpoint for 10 more steps...")
    out2 = train_loop(
        arch="qwen2-0.5b", reduced=True, steps=out["final_step"] + 11,
        batch=args.batch, seq=args.seq, lr=1e-3, ckpt_dir=args.ckpt_dir,
        resume=True, log_every=5,
    )
    assert np.isfinite(out2["losses"]).all()
    print("restart OK — fault-tolerant loop verified")


if __name__ == "__main__":
    main()
