"""The paper's running example (Fig. 2–3), end to end, with every
optimization stage shown: predicate-based model pruning, model-projection
pushdown, data-induced per-partition models, and runtime selection.

    PYTHONPATH=src python examples/covid_running_example.py
"""
import time

import numpy as np

from repro.core.ir import TableStats
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.core.rules.predicate_pruning import apply_predicate_pruning
from repro.core.rules.projection_pushdown import apply_projection_pushdown
from repro.data.datasets import make_hospital
from repro.ml import DecisionTreeClassifier, fit_pipeline
from repro.relational.engine import execute_plan
from repro.sql.parser import parse_prediction_query

ds = make_hospital(200_000)
joined = ds.joined_columns()

# "find asthma patients likely in the high-risk COVID group"
pipe = fit_pipeline(
    joined, ds.label, ds.numeric, ds.categorical,
    DecisionTreeClassifier(max_depth=10), categories=ds.categories(),
)
sql = """
    SELECT COUNT(*) FROM PREDICT(model = 'M', data = patients) AS p
    WHERE asthma = 1 AND score >= 0.5
"""
stats = {"patients": TableStats.of(ds.tables["patients"],
                                   partition_col="rcount")}
query = parse_prediction_query(sql, {"M": pipe}, ds.tables, stats=stats)

print("== unified IR built ==")
print(f"  pipeline: {pipe.n_ops()} ops / {len(pipe.inputs)} inputs / "
      f"{pipe.model_nodes()[0].attrs['ensemble'].n_nodes} tree nodes")

q1 = query.copy()
apply_predicate_pruning(q1)
p1 = q1.predict_nodes()[0].pipeline
print("== after predicate-based model pruning (asthma=1 -> constant; tree "
      "branches pruned) ==")
print(f"  inputs {len(pipe.inputs)} -> {len(p1.inputs)}; tree nodes "
      f"{pipe.model_nodes()[0].attrs['ensemble'].n_nodes} -> "
      f"{p1.model_nodes()[0].attrs['ensemble'].n_nodes}")

apply_projection_pushdown(q1)
p2 = q1.predict_nodes()[0].pipeline
from repro.core.ir import LScan, walk

scan = [n for n in walk(q1.plan) if isinstance(n, LScan)][0]
print("== after model-projection pushdown ==")
print(f"  model inputs -> {len(p2.inputs)}; scan reads "
      f"{len(scan.columns)}/{len(ds.tables['patients'])} columns")

print("== execution: no-opt vs Raven (all rules + MLtoSQL) ==")
for label, opts in [
    ("no-opt        ", OptimizerOptions(predicate_pruning=False,
                                        projection_pushdown=False,
                                        data_induced=False,
                                        transform="none")),
    ("raven (none)  ", OptimizerOptions(transform="none")),
    ("raven (sql)   ", OptimizerOptions(transform="sql")),
    ("raven (dnn)   ", OptimizerOptions(transform="dnn")),
]:
    plan, report = RavenOptimizer(options=opts).optimize(query)
    import jax
    import jax.numpy as jnp

    from repro.relational.engine import compile_plan

    runner = compile_plan(plan)
    db = {t: {c: jnp.asarray(v) for c, v in cols.items()}
          for t, cols in ds.tables.items()}
    runner(db)  # warm
    t0 = time.perf_counter()
    out = runner(db)
    jax.block_until_ready(out.columns)
    dt = time.perf_counter() - t0
    n = float(np.asarray(out.columns["count_rows"])[0])
    notes = f"  [{report.notes[0]}]" if report.notes else ""
    print(f"  {label} count={n:8.0f}  {dt*1e3:8.1f} ms{notes}")
