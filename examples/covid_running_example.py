"""The paper's running example (Fig. 2–3), end to end, with every
optimization stage shown: predicate-based model pruning, model-projection
pushdown, data-induced per-partition models, and runtime selection — driven
through the session front door, with EXPLAIN showing the chosen plan.

    PYTHONPATH=src python examples/covid_running_example.py

Set RAVEN_EXAMPLE_N to shrink the dataset (used by the examples smoke test).
"""
import os
import time

import numpy as np

import repro as raven
from repro.core.optimizer import OptimizerOptions
from repro.core.rules.predicate_pruning import apply_predicate_pruning
from repro.core.rules.projection_pushdown import apply_projection_pushdown
from repro.data.datasets import make_hospital
from repro.ml import DecisionTreeClassifier, fit_pipeline

N = int(os.environ.get("RAVEN_EXAMPLE_N", 200_000))

ds = make_hospital(N)
joined = ds.joined_columns()

# "find asthma patients likely in the high-risk COVID group"
pipe = fit_pipeline(
    joined, ds.label, ds.numeric, ds.categorical,
    DecisionTreeClassifier(max_depth=10), categories=ds.categories(),
)

db = raven.connect(
    ds.tables, stats="auto", partition_cols={"patients": "rcount"}
)
db.register_model("M", pipe)
query = db.sql("""
    SELECT COUNT(*) FROM PREDICT(model = 'M', data = patients) AS p
    WHERE asthma = 1 AND score >= 0.5
""")

print("== unified IR built ==")
print(f"  pipeline: {pipe.n_ops()} ops / {len(pipe.inputs)} inputs / "
      f"{pipe.model_nodes()[0].attrs['ensemble'].n_nodes} tree nodes")

q1 = query.ir.copy()
apply_predicate_pruning(q1)
p1 = q1.predict_nodes()[0].pipeline
print("== after predicate-based model pruning (asthma=1 -> constant; tree "
      "branches pruned) ==")
print(f"  inputs {len(pipe.inputs)} -> {len(p1.inputs)}; tree nodes "
      f"{pipe.model_nodes()[0].attrs['ensemble'].n_nodes} -> "
      f"{p1.model_nodes()[0].attrs['ensemble'].n_nodes}")

apply_projection_pushdown(q1)
p2 = q1.predict_nodes()[0].pipeline
from repro.core.ir import LScan, walk

scan = [n for n in walk(q1.plan) if isinstance(n, LScan)][0]
print("== after model-projection pushdown ==")
print(f"  model inputs -> {len(p2.inputs)}; scan reads "
      f"{len(scan.columns)}/{len(ds.tables['patients'])} columns")

print("== EXPLAIN (all rules + MLtoSQL) ==")
print(query.prepare(transform="sql").explain())

print("== execution: no-opt vs Raven (all rules + each runtime) ==")
for label, kwargs in [
    ("no-opt        ", {"options": OptimizerOptions(
        predicate_pruning=False, projection_pushdown=False,
        data_induced=False, transform="none")}),
    ("raven (none)  ", {"transform": "none"}),
    ("raven (sql)   ", {"transform": "sql"}),
    ("raven (dnn)   ", {"transform": "dnn"}),
]:
    prep = query.prepare(**kwargs)
    prep()  # warm
    t0 = time.perf_counter()
    out = prep()
    dt = time.perf_counter() - t0
    n = float(np.asarray(out["count_rows"])[0])
    notes = f"  [{prep.report.notes[0]}]" if prep.report.notes else ""
    print(f"  {label} count={n:8.0f}  {dt*1e3:8.1f} ms{notes}")
