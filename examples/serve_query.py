"""Prediction-query serving through the session front door: prepare once,
serve hot.

A hospital risk query is prepared with MLtoSQL (model compiled into the
relational program), served via the session-owned PredictionQueryServer, and
driven with a stream of mixed-size request batches. Power-of-two row buckets
+ validity-mask padding mean the whole stream runs on a handful of compiled
XLA programs; micro-batched submits coalesce into shared executions; the
:threshold parameter re-binds mid-stream without a single recompile.

    PYTHONPATH=src python examples/serve_query.py

Set RAVEN_EXAMPLE_N to shrink the workload (used by the examples smoke test).
"""
import os
import time

import numpy as np

import repro as raven
from repro.data.datasets import make_hospital
from repro.ml import GradientBoostingClassifier
from repro.ml.pipeline import fit_pipeline

N = int(os.environ.get("RAVEN_EXAMPLE_N", 8192))

print("training a GBDT on the hospital dataset...")
ds = make_hospital(N, seed=1)
pipe = fit_pipeline(
    ds.joined_columns(), ds.label, ds.numeric, ds.categorical,
    GradientBoostingClassifier(n_estimators=10, max_depth=3),
    categories=ds.categories(),
)

db = raven.connect(ds.tables, stats="auto")
db.register_model("m", pipe)

prep = db.sql(
    "SELECT * FROM PREDICT(model='m', data=patients) AS p "
    "WHERE score >= :t"
).prepare(transform="sql", params={"t": 0.6}).serve(name="risk")
print(f"served 'risk': pure={prep.compiled.is_pure} "
      f"(one fused XLA program), notes={prep.report.notes}")

rng = np.random.default_rng(0)
sizes = [int(n) for n in rng.integers(max(2, N // 80), max(4, N // 3), size=20)]
batches = [make_hospital(n, seed=50 + i).tables["patients"]
           for i, n in enumerate(sizes)]

print("warmup (compiles the first shape bucket)...")
prep.submit(batches[0])
db.flush()
warm = db.server.recompiles()

print(f"serving {len(batches)} mixed-size batches ({sum(sizes)} rows)...")
t0 = time.perf_counter()
reqs = [prep.submit(b) for b in batches]
db.flush()
dt = time.perf_counter() - t0

flagged = sum(len(r.result["score"]) for r in reqs)
print(f"served {len(reqs)} requests / {sum(sizes)} rows in {dt*1e3:.1f} ms "
      f"({sum(sizes)/dt:.0f} rows/s); {flagged} rows passed score >= 0.6")
print(f"XLA recompiles after warmup: {db.server.recompiles() - warm}")

print("re-binding :t = 0.9 (no re-optimize, no recompile)...")
before = db.server.recompiles()
prep.bind(t=0.9)
req = prep.submit(batches[0])
db.flush()
print(f"rows passing at 0.9: {len(req.result['score'])}; "
      f"new recompiles: {db.server.recompiles() - before}")
print(f"server stats: {db.server.stats.snapshot()}")
assert all(r.done for r in reqs)

# -- pump-driven serving: no db.flush() anywhere --------------------------
print("\nserving with a background pump (prep.serve(max_latency_ms=5))...")
udf = db.sql(
    "SELECT * FROM PREDICT(model='m', data=patients) AS p "
    "WHERE score >= :t"
).prepare(transform="none", params={"t": 0.6}).serve(
    name="udf", max_latency_ms=5.0,
)
# a host-boundary (MLUdf) plan: the stage graph buckets at every pure-stage
# boundary, so warm requests re-trace nothing even as sizes churn
pump_reqs = [udf.submit(b) for b in batches[:6]]
outs = [r.wait(timeout=60) for r in pump_reqs]  # pump flushes; no db.flush()
lat = sorted(r.latency_s * 1e3 for r in pump_reqs)
print(f"pump served {len(outs)} requests, median latency {lat[len(lat)//2]:.1f} ms")
print("stage graph:")
for stage in udf.compiled.stages:
    print(f"  {stage.describe()}")
db.close()  # stops the pump (drains anything still pending)
