"""Prediction-query serving driver: register a query once, serve it hot.

A hospital risk query is optimized with MLtoSQL (model compiled into the
relational program), registered with the PredictionQueryServer, and then
driven with a stream of mixed-size request batches. Power-of-two row buckets
+ validity-mask padding mean the whole stream runs on a handful of compiled
XLA programs; micro-batched submits coalesce into shared executions.

    PYTHONPATH=src python examples/serve_query.py
"""
import time

import numpy as np

from repro.core.ir import TableStats
from repro.core.optimizer import OptimizerOptions
from repro.data.datasets import make_hospital
from repro.ml import GradientBoostingClassifier
from repro.ml.pipeline import fit_pipeline
from repro.serve import PredictionQueryServer
from repro.sql.parser import parse_prediction_query

print("training a GBDT on the hospital dataset...")
ds = make_hospital(8192, seed=1)
pipe = fit_pipeline(
    ds.joined_columns(), ds.label, ds.numeric, ds.categorical,
    GradientBoostingClassifier(n_estimators=10, max_depth=3),
    categories=ds.categories(),
)

sql = (
    "SELECT * FROM PREDICT(model='m', data=patients) AS p "
    "WHERE score >= 0.6"
)
query = parse_prediction_query(
    sql, {"m": pipe}, ds.tables,
    stats={"patients": TableStats.of(ds.tables["patients"])},
)

srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
reg = srv.register("risk", query, ds.tables)
print(f"registered 'risk': pure={reg.compiled.is_pure} "
      f"(one fused XLA program), notes={reg.report.notes}")

rng = np.random.default_rng(0)
sizes = [int(n) for n in rng.integers(100, 3000, size=20)]
batches = [make_hospital(n, seed=50 + i).tables["patients"]
           for i, n in enumerate(sizes)]

print("warmup (compiles the first shape bucket)...")
srv.execute("risk", batches[0])
warm = srv.recompiles()

print(f"serving {len(batches)} mixed-size batches ({sum(sizes)} rows)...")
t0 = time.perf_counter()
reqs = [srv.submit("risk", b) for b in batches]
srv.flush()
dt = time.perf_counter() - t0

flagged = sum(len(r.result["score"]) for r in reqs)
print(f"served {len(reqs)} requests / {sum(sizes)} rows in {dt*1e3:.1f} ms "
      f"({sum(sizes)/dt:.0f} rows/s); {flagged} rows passed score >= 0.6")
print(f"XLA recompiles after warmup: {srv.recompiles() - warm}")
print(f"server stats: {srv.stats.snapshot()}")
assert all(r.done for r in reqs)
