"""Fig. 12 analog: complex gradient-boosting models — interpreted ML runtime
vs MLtoDNN tensor programs (the paper's GPU story becomes the fused-XLA /
MXU-targeted tensor-runtime story on TPU; crossover re-learned, §5.2).

Models: 60–500 estimators, depth 4–8, on Hospital. For these, the paper
reports ModelProj pointless (all inputs used), MLtoSQL detrimental, and the
DNN runtime the clear winner — exactly what the tensor path must show here.
"""
from __future__ import annotations

from benchmarks.common import NOOPT, build_query, make_dataset, run_variant, train_model

MODELS = [(60, 4), (150, 5), (300, 6), (500, 8)]


def run(quick: bool = False):
    rows = []
    scale = 10_000 if quick else 100_000
    train, infer = make_dataset("hospital", scale)
    for n_est, depth in (MODELS[:1] if quick else MODELS):
        pipe = train_model(train, "gb", n_estimators=n_est, depth=depth)
        q = build_query(infer, pipe)
        t_interp = run_variant(q, infer.tables, **NOOPT)
        t_dnn = run_variant(q, infer.tables, transform="dnn")
        rows.append({"estimators": n_est, "depth": depth,
                     "interp_s": t_interp, "dnn_s": t_dnn,
                     "speedup": t_interp / t_dnn})
        print(
            f"fig12,{n_est},{depth},{t_interp:.3f},{t_dnn:.3f},"
            f"{t_interp/t_dnn:.2f}x"
        )
    return rows


if __name__ == "__main__":
    print("fig12,estimators,depth,interp_s,dnn_s,speedup")
    run()
