"""§Perf profiling view: lower one cell, print trip-scaled byte/flop
attribution by opcode and by source op_name.

    PYTHONPATH=src python -m benchmarks.perf_profile --arch qwen2-moe-a2.7b \
        --shape train_4k [--set moe_dispatch=scatter]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def main() -> None:
    from repro.configs import ARCHS
    from repro.launch.dryrun import _parse_override, _to_struct
    from repro.launch.hlo_analysis import (
        analyze_hlo,
        per_opcode_bytes,
        per_source_bytes,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shardings import batch_shardings, input_spec_for
    from repro.models import build_model
    from repro.models.base import SHAPES, shardings_for
    from repro.models.zoo import decode_caches_from_specs
    from repro.train.step import (
        init_opt_state,
        make_prefill_step,
        make_serve_step,
        make_train_step,
    )
    import dataclasses

    from repro.configs import get_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    overrides = dict(map(_parse_override, args.set))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sp = SHAPES[args.shape]
    mesh = make_production_mesh()
    model = build_model(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params_s = _to_struct(model.shapes, dt)
    ps = shardings_for(params_s, mesh)
    batch_s = model.input_specs(sp)
    bs = batch_shardings(batch_s, mesh)
    with mesh:
        if sp.kind == "train":
            opt_s = init_opt_state(model, params_s, materialize=False)
            opt_sh = shardings_for(opt_s, mesh)
            step = make_train_step(model, mesh=mesh, accum_steps=cfg.accum_steps)
            compiled = jax.jit(
                step, in_shardings=(ps, opt_sh, bs),
                out_shardings=(ps, opt_sh, None), donate_argnums=(0, 1),
            ).lower(params_s, opt_s, batch_s).compile()
        elif sp.kind == "prefill":
            step = make_prefill_step(model, mesh=mesh)
            compiled = jax.jit(step, in_shardings=(ps, bs)).lower(
                params_s, batch_s
            ).compile()
        else:
            caches_s = decode_caches_from_specs(model, sp)
            cache_names = [k for k in batch_s if k not in ("tokens", "lengths")]
            cache_sh = tuple(
                jax.sharding.NamedSharding(
                    mesh, input_spec_for(n, batch_s[n].shape, mesh)
                )
                for n in cache_names
            )
            small = {"tokens": batch_s["tokens"], "lengths": batch_s["lengths"]}
            small_sh = {k: bs[k] for k in small}
            step = make_serve_step(model, mesh=mesh)
            compiled = jax.jit(
                step, in_shardings=(ps, small_sh, cache_sh),
                out_shardings=(None, None, cache_sh), donate_argnums=(2,),
            ).lower(params_s, small, caches_s).compile()

    text = compiled.as_text()
    cost = analyze_hlo(text)
    print(f"exec_flops={cost.flops:.3e}  exec_bytes={cost.bytes:.3e}  "
          f"coll={ {k: f'{v:.2e}' for k, v in cost.collective_bytes.items()} }")
    print("\n-- bytes by opcode --")
    for k, v in per_opcode_bytes(text):
        print(f"  {k:28s} {v:.3e}")
    print("\n-- bytes by source op_name --")
    for k, v in per_source_bytes(text):
        print(f"  {k:48s} {v:.3e}")


if __name__ == "__main__":
    main()
