"""§Roofline: three-term analysis per (arch × shape) from the dry-run records.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh sp|mp] [--md]

Terms (seconds/step, PER CHIP — the analyzer operates on the per-device
SPMD module, see repro/launch/hlo_analysis.py):

    compute    = exec_flops / PEAK_FLOPS          (197 TFLOP/s bf16, v5e)
    memory     = exec_bytes / HBM_BW              (819 GB/s)
    collective = Σ exec_collective_bytes / ICI_BW (~50 GB/s/link)

``exec_*`` are while-trip-scaled executed totals (cost_analysis counts loop
bodies once; we verified and corrected — see EXPERIMENTS.md methodology).
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train, 2·N·D for
prefill/decode; the ratio MODEL_FLOPS/exec_flops exposes remat/redundancy.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_records(mesh: str = "sp", results_dir: str = RESULTS) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, f"dryrun_{mesh}_*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def three_terms(rec: dict) -> dict:
    """Per-chip seconds for each roofline term + bookkeeping."""
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    compute = rec["exec_flops"] / PEAK_FLOPS
    memory = rec["exec_bytes"] / HBM_BW
    coll_bytes = sum(rec.get("exec_collective_bytes", {}).values())
    collective = coll_bytes / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    model_per_chip = rec["model_flops"] / chips
    ratio = model_per_chip / rec["exec_flops"] if rec["exec_flops"] else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops per chip / (time-bound × peak)
    frac = model_per_chip / (bound * PEAK_FLOPS) if bound > 0 else 0.0
    return {
        **terms,
        "dominant": dominant,
        "model_flops_ratio": ratio,
        "roofline_fraction": frac,
        "chips": chips,
        "coll_bytes": coll_bytes,
    }


def _advice(rec: dict, t: dict) -> str:
    arch, shape, dom = rec["arch"], rec["shape"], t["dominant"]
    if dom == "memory":
        if rec["kind"] == "decode":
            return ("KV/state streaming bound: fuse decode attention "
                    "(Pallas decode kernel) and shrink cache dtype")
        return ("HBM-traffic bound: fuse attention (flash kernel — no S^2 "
                "materialization) / increase per-chip arithmetic intensity")
    if dom == "collective":
        return ("ICI bound: shrink FSDP all-gathers (wider TP shards or "
                "overlap-friendly per-layer gathering), compress inter-pod")
    if t["model_flops_ratio"] < 0.5:
        return ("compute bound with low useful-flop ratio: reduce remat "
                "recompute / pick a cheaper checkpoint policy")
    return "near compute roofline: increase per-chip batch or tolerate"


def report(mesh: str = "sp", md: bool = False) -> str:
    recs = load_records(mesh)
    lines = []
    if md:
        lines.append(
            "| arch | shape | compute s | memory s | collective s | dominant "
            "| MODEL/exec flops | roofline frac | what would move it |"
        )
        lines.append("|---|---|---|---|---|---|---|---|---|")
    else:
        lines.append(
            f"{'arch':18s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
            f"{'coll_s':>9s} {'dominant':>10s} {'MF/HF':>6s} {'roofl%':>7s}"
        )
    for rec in recs:
        if rec["status"] == "skipped":
            if md:
                lines.append(
                    f"| {rec['arch']} | {rec['shape']} | — | — | — | skipped "
                    f"| — | — | {rec['reason'][:60]} |"
                )
            else:
                lines.append(
                    f"{rec['arch']:18s} {rec['shape']:12s} "
                    f"{'skipped (' + rec['reason'][:40] + ')':>40s}"
                )
            continue
        t = three_terms(rec)
        if md:
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {t['compute']:.3e} "
                f"| {t['memory']:.3e} | {t['collective']:.3e} "
                f"| **{t['dominant']}** | {t['model_flops_ratio']:.2f} "
                f"| {t['roofline_fraction']:.1%} | {_advice(rec, t)} |"
            )
        else:
            lines.append(
                f"{rec['arch']:18s} {rec['shape']:12s} {t['compute']:9.3e} "
                f"{t['memory']:9.3e} {t['collective']:9.3e} "
                f"{t['dominant']:>10s} {t['model_flops_ratio']:6.2f} "
                f"{t['roofline_fraction']:7.1%}"
            )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["sp", "mp"], default="sp")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    print(report(args.mesh, args.md))


if __name__ == "__main__":
    main()
