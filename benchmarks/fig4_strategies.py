"""Fig. 4 analog: optimization-strategy evaluation on the generated corpus.

Stratified 5-fold CV repeated to 200 runs (paper's protocol): accuracy +
speedup-vs-optimal distribution per strategy.
"""
from __future__ import annotations

import numpy as np

from repro.core.corpus import build_corpus
from repro.core.strategies import (
    ClassificationStrategy,
    RegressionStrategy,
    RuleBasedStrategy,
    evaluate_strategy,
)


def _stratified_folds(labels, k, rng):
    folds = [[] for _ in range(k)]
    for cls in np.unique(labels):
        idx = np.flatnonzero(labels == cls)
        rng.shuffle(idx)
        for i, j in enumerate(idx):
            folds[i % k].append(j)
    return [np.asarray(f) for f in folds]


def run(quick: bool = False, n_pipelines: int = 138, n_repeats: int = 8):
    if quick:
        n_pipelines, n_repeats = 30, 2
    corpus = build_corpus(n_pipelines=n_pipelines, n_rows=20_000, seed=0)
    rng = np.random.default_rng(0)
    results = {"rule": [], "clf": [], "reg": []}
    for _rep in range(n_repeats):  # n_repeats × 5 folds
        folds = _stratified_folds(corpus.labels, 5, rng)
        for i in range(5):
            test = folds[i]
            tr = np.concatenate([folds[j] for j in range(5) if j != i])
            Xtr, ytr = corpus.stats[tr], corpus.labels[tr]
            rtr = corpus.runtimes[tr]
            Xte, yte, rte = corpus.stats[test], corpus.labels[test], corpus.runtimes[test]
            for name, strat in (
                ("rule", RuleBasedStrategy().fit(Xtr, ytr)),
                ("clf", ClassificationStrategy().fit(Xtr, ytr)),
                ("reg", RegressionStrategy().fit(Xtr, rtr)),
            ):
                results[name].append(
                    evaluate_strategy(strat, Xte, yte, rte)
                )
    rows = []
    for name, rs in results.items():
        acc = np.asarray([r["accuracy"] for r in rs])
        sp = np.asarray([r["speedup_vs_optimal"] for r in rs])
        rows.append({
            "strategy": name, "acc_mean": float(acc.mean()),
            "speedup_median": float(np.median(sp)),
            "speedup_p25": float(np.percentile(sp, 25)),
            "speedup_min": float(sp.min()),
        })
        print(
            f"fig4,{name},{acc.mean():.3f},{np.median(sp):.3f},"
            f"{np.percentile(sp,25):.3f},{sp.min():.3f}"
        )
    return rows


if __name__ == "__main__":
    print("fig4,strategy,accuracy,speedup_median,speedup_p25,speedup_min")
    run()
