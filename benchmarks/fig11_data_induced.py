"""Fig. 11 + Tab. 2 analog: data-induced per-partition model specialization.

Hospital partitioned on num_issues (2 parts) and rcount (6 parts); DT depths
10/15/20; variants: no-opt, Raven w/o partitioning, Raven + partitioned.
Also reports the Tab. 2 metric: average #columns pruned per partition model.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import NOOPT, build_query, make_dataset, run_variant, train_model
from repro.core.rules.data_induced import apply_data_induced


def _avg_pruned_cols(q) -> float:
    """Tab. 2 metric: features the partition-specialized models stop using
    (averaged over partitions)."""
    q2 = q.copy()
    apply_data_induced(q2)
    pn = q2.predict_nodes()[0]
    if not pn.partitioned:
        return 0.0
    out = []
    for _, spec in pn.partitioned:
        ens = spec.model_nodes()[0].attrs["ensemble"]
        out.append(ens.n_features - len(ens.used_features()))
    return float(np.mean(out))


DEPTHS = [10, 15, 20]
PARTITIONS = ["num_issues", "rcount"]


def run(quick: bool = False):
    rows = []
    scale = 20_000 if quick else 300_000
    train, infer = make_dataset("hospital", scale)
    for depth in (DEPTHS[:1] if quick else DEPTHS):
        pipe = train_model(train, "dt", depth=depth)
        q_nopart = build_query(infer, pipe, where="score >= 0.5")
        t0 = run_variant(q_nopart, infer.tables, **NOOPT)
        t_nopart = run_variant(q_nopart, infer.tables, transform="sql",
                               data_induced=False)
        for pcol in (PARTITIONS[:1] if quick else PARTITIONS):
            q = build_query(infer, pipe, where="score >= 0.5",
                            partition_col=pcol)
            t_part = run_variant(q, infer.tables, transform="sql")
            pruned = _avg_pruned_cols(q)
            rows.append({
                "depth": depth, "partition": pcol, "noopt_s": t0,
                "nopart_s": t_nopart, "part_s": t_part,
                "avg_pruned_features": pruned,
            })
            print(
                f"fig11,{depth},{pcol},{t0:.3f},{t_nopart:.3f},{t_part:.3f},"
                f"{pruned:.1f},{t0/t_part:.2f}x"
            )
    return rows


if __name__ == "__main__":
    print("fig11,depth,partition,noopt_s,nopart_s,part_s,avg_pruned,speedup")
    run()
