"""Fig. 10 analog: DT depth sweep on Hospital × rules.

Reproduces the paper's headline §5 observation: MLtoSQL is a big win for
shallow trees and becomes a *slowdown* as depth grows — the motivation for
data-driven runtime selection.
"""
from __future__ import annotations

from benchmarks.common import NOOPT, build_query, make_dataset, run_variant, train_model

DEPTHS = [3, 6, 10, 14, 18]


def run(quick: bool = False):
    rows = []
    scale = 20_000 if quick else 300_000
    train, infer = make_dataset("hospital", scale)
    for depth in (DEPTHS[:2] if quick else DEPTHS):
        pipe = train_model(train, "dt", depth=depth)
        ens = pipe.model_nodes()[0].attrs["ensemble"]
        unused = len(train.numeric + train.categorical) - len(
            {int(f) for f in ens.feature if f >= 0}
        )
        q = build_query(infer, pipe)
        t0 = run_variant(q, infer.tables, **NOOPT)
        t_proj = run_variant(
            q, infer.tables, predicate_pruning=False, data_induced=False,
            transform="none",
        )
        t_sql = run_variant(q, infer.tables, transform="sql")
        t_dnn = run_variant(q, infer.tables, transform="dnn")
        rows.append({"depth": depth, "noopt_s": t0, "proj_s": t_proj,
                     "sql_s": t_sql, "dnn_s": t_dnn})
        print(
            f"fig10,{depth},{t0:.3f},{t_proj:.3f},{t_sql:.3f},{t_dnn:.3f},"
            f"sql={'win' if t_sql < t0 else 'SLOWDOWN'}"
        )
    return rows


if __name__ == "__main__":
    print("fig10,depth,noopt_s,modelproj_s,mltosql_s,mltodnn_s,verdict")
    run()
