"""Serving-layer benchmark: cold per-call execution vs the warm cached path.

Measures the MLtoSQL-lowered hospital query under three regimes:

  percall — compile_plan(cache=False) + execute on every request: the
            pre-serving behavior (re-lower, re-jit, re-trace per call).
  cached  — execute_plan through the module-level compiled-plan cache
            (compile once, jit reuses shape-specialized programs).
  served  — PredictionQueryServer with power-of-two row buckets and
            micro-batched submits: the steady-state hot path.

Reports throughput (rows/s), per-request latency, and XLA recompile counts;
the served/percall ratio is the headline (target: >= 5x warm speedup).

    PYTHONPATH=src:. python benchmarks/serve_query.py [--quick]
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_query, make_dataset, train_model
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.relational.engine import (
    PLAN_CACHE_STATS,
    clear_plan_cache,
    compile_plan,
    execute_plan,
)
from repro.data.datasets import make_hospital
from repro.serve import PredictionQueryServer

import jax


def _request_sizes(n_requests: int, seed: int = 0) -> list[int]:
    """Mixed request sizes, the shape churn a real endpoint sees."""
    rng = np.random.default_rng(seed)
    return [int(n) for n in rng.integers(200, 4096, size=n_requests)]


def run(quick: bool = False):
    n_requests = 8 if quick else 24
    sizes = _request_sizes(n_requests)
    train, _ = make_dataset("hospital", 20_000)
    pipe = train_model(train, "gb")
    query = build_query(train, pipe, agg="*", where="score >= 0.6")
    batches = [make_hospital(n, seed=100 + i).tables["patients"]
               for i, n in enumerate(sizes)]
    total_rows = sum(sizes)

    plan, _ = RavenOptimizer(
        options=OptimizerOptions(transform="sql")
    ).optimize(query)

    def tables_for(batch):
        t = dict(train.tables)
        t["patients"] = batch
        return t

    # -- percall: compile + execute from scratch every request ---------------
    clear_plan_cache()
    t0 = time.perf_counter()
    for b in batches:
        out = compile_plan(plan, cache=False)(
            {t: {c: np.asarray(v) for c, v in cols.items()}
             for t, cols in tables_for(b).items()}
        )
        jax.block_until_ready(out.columns)
    t_percall = time.perf_counter() - t0
    percall_traces = PLAN_CACHE_STATS.traces

    # -- cached: execute_plan through the compiled-plan cache ----------------
    clear_plan_cache()
    execute_plan(plan, tables_for(batches[0]))  # warm the compile
    t0 = time.perf_counter()
    for b in batches:
        jax.block_until_ready(execute_plan(plan, tables_for(b)).columns)
    t_cached = time.perf_counter() - t0
    cached_traces = PLAN_CACHE_STATS.traces

    # -- served: bucketed + micro-batched server -----------------------------
    clear_plan_cache()
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("hospital", query, train.tables)
    srv.execute("hospital", batches[0])  # warm one bucket
    warm_traces = srv.recompiles()
    t0 = time.perf_counter()
    reqs = [srv.submit("hospital", b) for b in batches]
    srv.flush()
    t_served = time.perf_counter() - t0
    assert all(r.done for r in reqs)

    rows = {
        "requests": n_requests,
        "rows": total_rows,
        "percall_s": t_percall,
        "cached_s": t_cached,
        "served_s": t_served,
        "percall_rows_s": total_rows / t_percall,
        "cached_rows_s": total_rows / t_cached,
        "served_rows_s": total_rows / t_served,
        "percall_recompiles": percall_traces,
        "cached_recompiles": cached_traces,
        "served_recompiles_after_warmup": srv.recompiles() - warm_traces,
        "speedup_cached": t_percall / t_cached,
        "speedup_served": t_percall / t_served,
    }
    print("serve_query,variant,seconds,rows_per_s,recompiles")
    print(f"serve_query,percall,{t_percall:.3f},{rows['percall_rows_s']:.0f},"
          f"{percall_traces}")
    print(f"serve_query,cached,{t_cached:.3f},{rows['cached_rows_s']:.0f},"
          f"{cached_traces}")
    print(f"serve_query,served,{t_served:.3f},{rows['served_rows_s']:.0f},"
          f"{srv.recompiles() - warm_traces} (after warmup)")
    print(f"serve_query,speedup,served vs percall = "
          f"{rows['speedup_served']:.1f}x, cached vs percall = "
          f"{rows['speedup_cached']:.1f}x")
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
