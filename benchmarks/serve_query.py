"""Serving-layer benchmark: cold per-call execution vs the warm cached path,
driven through the session front door (connect -> sql -> prepare -> serve).

Part 1 — pure (MLtoSQL) plan, three regimes:

  percall — compile_plan(cache=False) + execute on every request: the
            pre-serving behavior (re-lower, re-jit, re-trace per call).
  cached  — PreparedQuery one-shot calls through the module-level
            compiled-plan cache (compile once, jit reuses shape-specialized
            programs).
  served  — PreparedQuery.serve(): power-of-two row buckets and
            micro-batched submits on the session server — the steady-state
            hot path.

Part 2 — multi-stage (MLUdf host-boundary) plan, the StageGraph payoff:

  postudf — the old batch-at-a-time post-UDF path: no mid-stage bucketing
            (host-boundary outputs run at their exact data-dependent shape,
            re-tracing the post-UDF stage on every new size) and one
            request per execution.
  staged  — per-stage bucketing + segment-id coalescing: every pure stage
            runs on power-of-two shapes, submits share executions.
  pump    — same, flushed by the background pump (prep.serve(
            max_latency_ms=...)) with per-request p50/p99 latency.

Part 3 — cold-process A/B, the artifact-store payoff:

  each leg spawns a FRESH interpreter (``--cold-child``) that connects,
  prepares, serves, and submits a fixed bucket ladder, timing prepare +
  first-flush — the cold-start cost a restarted serving process pays.
  ``nocache`` runs without a cache_dir; ``cold`` populates a fresh one
  (optimizer output + AOT-exported stage programs land on disk); ``warm``
  reuses it: the optimizer is skipped and every bucket deserializes with
  zero new XLA traces.

Part 4 — mixed workload, the pipelined-scheduler payoff:

  one UDF-heavy query (MLUdf host boundary, bulk batches) and one small
  latency-sensitive pure query served from the SAME server under concurrent
  threaded load. ``serial`` runs the old stage-at-a-time group runner on a
  single pump; ``pipelined`` runs the EDF scheduler + pipelined executor:
  host boundaries on the boundary pool, device stages dispatched async, the
  small query's queue flushed by its own deadline. Reports per-class
  throughput and p50/p99 — the headline is pipelined >= 1.5x serial
  throughput with the small query's p99 staying near its latency target
  while bulk groups are in flight.

Part 5 — wide-row fused featurization, the partial-MLtoDNN payoff:

  a wide synthetic table (dozens of scaled numerics + one-hot categoricals)
  predicted by a tree ensemble. ``host`` runs transform='none': the whole
  pipeline is one MLUdf host boundary. ``fused`` runs transform='dnn': the
  scaler/one-hot/concat chain collapses into the fused featurize kernel and
  the tree into the GEMM program, all inside one pure TensorOp stage — the
  former host boundary *vanishes* (``n_host_boundaries`` 1 -> 0).

Part 6 — relational kernels, the filter→join→group-by payoff:

  a star-schema fact scan filtered, gather-joined against a unique-key dim
  table, and segment-aggregated (count/sum/mean/min/max). ``host`` is a
  careful-f32 numpy oracle (the bitwise ground truth); ``jnp`` runs the
  legacy inline stage composition (``RAVEN_KERNELS=off``); ``kernel`` runs
  the relational kernel ops (``RAVEN_KERNELS=on`` — Pallas on TPU, fused
  jnp oracles on CPU). All three legs must agree bit-for-bit (dyadic-
  rational data keeps f32 sums exact), the kernel leg must not trail the
  jnp leg, and the warm loop must not re-trace.

Reports throughput (rows/s), XLA recompile counts, per-stage timings, and
request-latency percentiles. Headlines: served/percall >= 5x on the pure
plan, staged/postudf >= 2x on the multi-stage plan, warm cold-start traces
== 0, pipelined/serial >= 1.5x on the mixed workload, host boundary count
1 -> 0 on the wide-row featurize workload, kernel >= jnp rows/s with
bitwise-equal results on the relational workload.

    PYTHONPATH=src:. python benchmarks/serve_query.py \
        [--quick | --smoke] [--json [PATH]]

``--json`` writes the headline numbers to BENCH_serving.json (or PATH) —
the committed baseline + the artifact nightly CI uploads.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

import jax

import repro as raven
from benchmarks.common import make_dataset, train_model
from repro.data.datasets import make_hospital
from repro.relational.engine import (
    PLAN_CACHE_STATS,
    clear_plan_cache,
    compile_plan,
)
from repro.serve import PredictionQueryServer


def _request_sizes(n_requests: int, seed: int = 0) -> list[int]:
    """Mixed request sizes, the shape churn a real endpoint sees."""
    rng = np.random.default_rng(seed)
    return [int(n) for n in rng.integers(200, 4096, size=n_requests)]


def _stage_report(prep) -> list[str]:
    return [st.describe() for st in prep.compiled.stages]


def run_pure(db, sql, batches, total_rows, n_requests):
    """Pure-plan regimes: percall / cached / served."""
    prep = db.sql(sql).prepare(transform="sql", params={"t": 0.6})

    # -- percall: compile + execute from scratch every request ---------------
    clear_plan_cache()
    t0 = time.perf_counter()
    for b in batches:
        db_np = dict(db.tables)
        db_np["patients"] = b
        out = compile_plan(prep.plan, cache=False)(
            {t: {c: np.asarray(v) for c, v in cols.items()}
             for t, cols in db_np.items()},
            params=prep.params,
        )
        jax.block_until_ready(out.columns)
    t_percall = time.perf_counter() - t0
    percall_traces = PLAN_CACHE_STATS.traces

    # -- cached: one-shot PreparedQuery calls through the plan cache ---------
    clear_plan_cache()
    prep = db.sql(sql).prepare(transform="sql", params={"t": 0.6})
    prep(batches[0])  # warm the compile
    t0 = time.perf_counter()
    for b in batches:
        prep(b)
    t_cached = time.perf_counter() - t0
    cached_traces = PLAN_CACHE_STATS.traces

    # -- served: bucketed + micro-batched session server ---------------------
    clear_plan_cache()
    prep = db.sql(sql).prepare(transform="sql", params={"t": 0.6}).serve("hot")
    prep.submit(batches[0])
    db.flush()  # warm one bucket
    warm_traces = db.cache_stats()["traces"]
    t0 = time.perf_counter()
    reqs = [prep.submit(b) for b in batches]
    db.flush()
    t_served = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    served_traces = db.cache_stats()["traces"] - warm_traces

    print("serve_query,variant,seconds,rows_per_s,recompiles")
    print(f"serve_query,percall,{t_percall:.3f},{total_rows / t_percall:.0f},"
          f"{percall_traces}")
    print(f"serve_query,cached,{t_cached:.3f},{total_rows / t_cached:.0f},"
          f"{cached_traces}")
    print(f"serve_query,served,{t_served:.3f},{total_rows / t_served:.0f},"
          f"{served_traces} (after warmup)")
    print(f"serve_query,speedup,served vs percall = "
          f"{t_percall / t_served:.1f}x, cached vs percall = "
          f"{t_percall / t_cached:.1f}x")
    return {
        "requests": n_requests, "rows": total_rows,
        "percall_s": t_percall, "cached_s": t_cached, "served_s": t_served,
        "percall_rows_s": total_rows / t_percall,
        "cached_rows_s": total_rows / t_cached,
        "served_rows_s": total_rows / t_served,
        "percall_recompiles": percall_traces,
        "cached_recompiles": cached_traces,
        "served_recompiles_after_warmup": served_traces,
        "speedup_cached": t_percall / t_cached,
        "speedup_served": t_percall / t_served,
    }


def run_multistage(db, sql, batches, total_rows):
    """Host-boundary plan: old batch-at-a-time post-UDF path vs StageGraph
    per-stage bucketing + coalescing, sync and pump-driven."""
    ir = db.sql(sql).ir

    # -- postudf: the pre-StageGraph behavior --------------------------------
    from repro.core.optimizer import OptimizerOptions

    clear_plan_cache()
    old = PredictionQueryServer(
        options=OptimizerOptions(transform="none"), mid_bucketing=False,
    )
    old.register("udf", ir, db.tables, params={"t": 0.6})
    old.execute("udf", batches[0])  # warm entry bucket
    warm = old.recompiles()
    t0 = time.perf_counter()
    for b in batches:  # one request per execution, exact-shape post-UDF
        old.execute("udf", b)
    t_old = time.perf_counter() - t0
    old_retraces = old.recompiles() - warm

    # -- staged: per-stage bucketing + coalesced flushes ---------------------
    clear_plan_cache()
    prep = db.sql(sql).prepare(
        transform="none", params={"t": 0.6}
    ).serve("udf_hot")
    prep.submit(batches[0])
    db.flush()
    warm = db.cache_stats()["traces"]
    t0 = time.perf_counter()
    reqs = [prep.submit(b) for b in batches]
    db.flush()
    t_new = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    new_retraces = db.cache_stats()["traces"] - warm

    # -- pump: same, flushed by the background pump --------------------------
    prep = prep.serve("udf_pump", max_latency_ms=5.0)
    prep.submit(batches[0]).wait(timeout=60)  # warm
    t0 = time.perf_counter()
    reqs = [prep.submit(b) for b in batches]
    outs = [r.wait(timeout=60) for r in reqs]
    t_pump = time.perf_counter() - t0
    assert all(o is not None for o in outs)
    lat_ms = np.array([r.latency_s * 1e3 for r in reqs])
    p50, p99 = np.percentile(lat_ms, [50, 99])
    db.server.stop_pump()

    print("serve_query_multistage,variant,seconds,rows_per_s,"
          "post_warm_recompiles")
    print(f"serve_query_multistage,postudf,{t_old:.3f},"
          f"{total_rows / t_old:.0f},{old_retraces}")
    print(f"serve_query_multistage,staged,{t_new:.3f},"
          f"{total_rows / t_new:.0f},{new_retraces}")
    print(f"serve_query_multistage,pump,{t_pump:.3f},"
          f"{total_rows / t_pump:.0f},-")
    print(f"serve_query_multistage,speedup,staged vs postudf = "
          f"{t_old / t_new:.1f}x")
    print(f"serve_query_multistage,latency_ms,p50={p50:.2f},p99={p99:.2f}")
    print("per-stage timings (staged+pump serving):")
    for line in _stage_report(prep):
        print(f"  {line}")
    return {
        "postudf_s": t_old, "staged_s": t_new, "pump_s": t_pump,
        "postudf_rows_s": total_rows / t_old,
        "staged_rows_s": total_rows / t_new,
        "pump_rows_s": total_rows / t_pump,
        "postudf_recompiles_after_warmup": old_retraces,
        "staged_recompiles_after_warmup": new_retraces,
        "speedup_staged": t_old / t_new,
        "latency_p50_ms": float(p50), "latency_p99_ms": float(p99),
    }


def _cold_child(pipe_path: str, cache_dir: str) -> None:
    """One fresh-interpreter serving cold start (invoked via --cold-child).

    Times connect+prepare and the first flush of a fixed bucket ladder, then
    prints one json line the parent collects. ``cache_dir`` empty -> no
    artifact store (the baseline).
    """
    from repro.ml.pipeline import load_pipeline

    pipe = load_pipeline(pipe_path)
    ds = make_hospital(4096, seed=0)
    batches = [make_hospital(n, seed=50 + i).tables["patients"]
               for i, n in enumerate((120, 250, 500, 1000))]
    t0 = time.perf_counter()
    db = raven.connect(ds.tables, stats="auto", cache_dir=cache_dir or None)
    db.register_model("m", pipe)
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.6}).serve("hot")
    t_prepare = time.perf_counter() - t0
    t0 = time.perf_counter()
    for b in batches:
        prep.submit(b)
        db.flush()  # flush per submit: each size lands its own bucket
    t_first = time.perf_counter() - t0
    s = db.cache_stats()
    print(json.dumps({
        "prepare_s": t_prepare, "first_flush_s": t_first,
        "traces": s["traces"], "disk_hits": s["disk_hits"],
    }))


def run_cold(pipe_path: str) -> dict:
    """Cold-process A/B: fresh interpreter with cache off / cold / warm."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + "."

    def leg(cache_dir: str) -> dict:
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--cold-child",
             pipe_path, cache_dir],
            capture_output=True, text=True, timeout=600, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        if proc.returncode != 0:
            raise RuntimeError(f"cold child failed:\n{proc.stderr[-2000:]}")
        return json.loads(proc.stdout.strip().splitlines()[-1])

    with tempfile.TemporaryDirectory() as cache:
        nocache = leg("")
        cold = leg(cache)    # populates the store
        warm = leg(cache)    # the restarted-process payoff

    print("serve_query_cold,variant,prepare_s,first_flush_s,traces,disk_hits")
    for name, r in (("nocache", nocache), ("cold", cold), ("warm", warm)):
        print(f"serve_query_cold,{name},{r['prepare_s']:.3f},"
              f"{r['first_flush_s']:.3f},{r['traces']},{r['disk_hits']}")
    total = lambda r: r["prepare_s"] + r["first_flush_s"]  # noqa: E731
    print(f"serve_query_cold,speedup,warm vs nocache = "
          f"{total(nocache) / total(warm):.1f}x "
          f"(traces {nocache['traces']} -> {warm['traces']})")
    assert warm["traces"] == 0, "warm cold-start must not re-trace"
    assert warm["disk_hits"] > 0, "warm cold-start must hit the disk tier"
    return {
        "cold_nocache_s": total(nocache), "cold_cold_s": total(cold),
        "cold_warm_s": total(warm),
        "cold_warm_traces": warm["traces"],
        "cold_warm_disk_hits": warm["disk_hits"],
        "cold_speedup_warm": total(nocache) / total(warm),
    }


def parallel_efficiency() -> float:
    """How much concurrent CPU this machine actually grants the process.

    Two GIL-free BLAS streams vs one: ~2.0 on an unloaded 2-core box, ~1.0
    in a cgroup throttled to a single effective core. Host/device overlap
    cannot beat this ceiling — a pipelined schedule on a 1-core quota just
    time-slices — so the A/B below reports it alongside the speedup (and CI
    gates its assertion on it).
    """
    import threading

    a = np.random.default_rng(0).random((1024, 1024))

    def work():
        for _ in range(4):
            np.dot(a, a)

    work()  # warm BLAS pools
    t0 = time.perf_counter()
    work()
    solo = time.perf_counter() - t0
    threads = [threading.Thread(target=work) for _ in range(2)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dual = time.perf_counter() - t0
    return 2.0 * solo / max(dual, 1e-9)


def run_mixed(db, sql, quick: bool = False) -> dict:
    """Part 4: serial vs pipelined scheduling under a mixed concurrent load.

    The heavy class is the UDF (transform='none') plan: its bulk batches
    arrive as one backlog, so the serial runner pins the pump inside each
    group's host boundary. The small class is the pure (MLtoSQL) plan, paced
    as a steady trickle of latency probes on a tight target. Both legs serve
    both queries from one server; only the execution/scheduling mode
    differs. Each leg runs twice and keeps its best pass (cgroup throttling
    on shared CI boxes makes single passes noisy).
    """
    n_heavy = 6 if quick else 10
    heavy_rows = 8192
    n_small = 16 if quick else 24
    small_rows = 1024
    small_every_s = 0.02
    small_target_ms = 10.0
    heavy_target_ms = 25.0  # bulk declares it can wait: the scheduler keeps
    #                         the small query's tighter deadlines ahead of it
    heavy_batches = [make_hospital(heavy_rows, seed=400 + i).tables["patients"]
                     for i in range(n_heavy)]
    small_batches = [make_hospital(small_rows, seed=700 + i).tables["patients"]
                     for i in range(n_small)]
    total_rows = n_heavy * heavy_rows + n_small * small_rows

    def one_pass(pipelined: bool) -> dict:
        clear_plan_cache()
        # one boundary worker: on this workload the UDF's numpy kernels are
        # memory-bound, so the overlap win is host-vs-device, not
        # host-vs-host. max_inflight is raised so the pump keeps feeding
        # cheap device groups while bulk groups sit in the boundary queue
        srv = PredictionQueryServer(
            pipelined=pipelined, boundary_workers=1, max_inflight=32,
        )
        # coalesce caps pin each measured group to the bucket shapes the
        # warmup below compiles, so the A/B measures scheduling — not
        # whichever leg happens to hit a fresh XLA specialization first
        heavy = db.sql(sql).prepare(transform="none", params={"t": 0.6}).serve(
            "heavy", server=srv, max_latency_ms=heavy_target_ms,
            max_coalesce=heavy_rows,
        )
        small = db.sql(sql).prepare(transform="sql", params={"t": 0.6}).serve(
            "small", server=srv, max_latency_ms=small_target_ms,
            max_coalesce=small_rows,
        )
        # warm every bucket both classes will touch, then measure
        heavy.submit(heavy_batches[0]).wait(timeout=300)
        small.submit(small_batches[0]).wait(timeout=300)
        warm_traces = PLAN_CACHE_STATS.traces
        h_reqs, s_reqs = [], []

        def small_submitter():
            for b in small_batches:
                s_reqs.append(small.submit(b))
                time.sleep(small_every_s)

        t0 = time.perf_counter()
        prober = threading.Thread(target=small_submitter)
        prober.start()
        for b in heavy_batches:  # the bulk backlog lands at once
            h_reqs.append(heavy.submit(b))
        prober.join()
        for r in h_reqs + s_reqs:
            r.wait(timeout=600)
        wall = time.perf_counter() - t0
        retraces = PLAN_CACHE_STATS.traces - warm_traces
        h_lat = np.array([r.latency_s * 1e3 for r in h_reqs])
        s_lat = np.array([r.latency_s * 1e3 for r in s_reqs])
        snap = srv.stats_snapshot()
        srv.shutdown()
        return {
            "wall_s": wall,
            "rows_s": total_rows / wall,
            "heavy_p50_ms": float(np.percentile(h_lat, 50)),
            "heavy_p99_ms": float(np.percentile(h_lat, 99)),
            "small_p50_ms": float(np.percentile(s_lat, 50)),
            "small_p99_ms": float(np.percentile(s_lat, 99)),
            "retraces_after_warmup": retraces,
            "overlap_s": snap["pipeline"]["overlap_s"],
            "overlapped_groups": snap["pipeline"]["overlapped_groups"],
        }

    def leg(pipelined: bool) -> dict:
        passes = [one_pass(pipelined) for _ in range(2)]
        return min(passes, key=lambda r: r["wall_s"])

    eff = parallel_efficiency()
    serial = leg(pipelined=False)
    piped = leg(pipelined=True)

    print("serve_query_mixed,variant,wall_s,rows_per_s,small_p50_ms,"
          "small_p99_ms,heavy_p99_ms,post_warm_retraces")
    for name, r in (("serial", serial), ("pipelined", piped)):
        print(f"serve_query_mixed,{name},{r['wall_s']:.3f},"
              f"{r['rows_s']:.0f},{r['small_p50_ms']:.2f},"
              f"{r['small_p99_ms']:.2f},{r['heavy_p99_ms']:.2f},"
              f"{r['retraces_after_warmup']}")
    speedup = serial["wall_s"] / piped["wall_s"]
    print(f"serve_query_mixed,speedup,pipelined vs serial = {speedup:.2f}x "
          f"at parallel_efficiency={eff:.2f} "
          f"(overlap {piped['overlap_s']:.2f}s across "
          f"{piped['overlapped_groups']} groups; small-query p99 "
          f"{serial['small_p99_ms']:.1f} -> {piped['small_p99_ms']:.1f} ms "
          f"at a {small_target_ms:.0f} ms target)")
    if eff < 1.4:
        print("serve_query_mixed,note,this machine grants <1.4x concurrent "
              "CPU — host/device overlap cannot express a wall-clock win "
              "here; see parallel_efficiency in the JSON")
    return {
        "mixed_rows": total_rows,
        "mixed_parallel_efficiency": eff,
        "mixed_serial_s": serial["wall_s"],
        "mixed_pipelined_s": piped["wall_s"],
        "mixed_serial_rows_s": serial["rows_s"],
        "mixed_pipelined_rows_s": piped["rows_s"],
        "mixed_speedup_pipelined": speedup,
        "mixed_small_target_ms": small_target_ms,
        "mixed_small_p99_serial_ms": serial["small_p99_ms"],
        "mixed_small_p99_pipelined_ms": piped["small_p99_ms"],
        "mixed_heavy_p99_pipelined_ms": piped["heavy_p99_ms"],
        "mixed_pipelined_retraces_after_warmup": piped["retraces_after_warmup"],
        "mixed_overlap_s": piped["overlap_s"],
        "mixed_overlapped_groups": piped["overlapped_groups"],
    }


def _wide_table(n_rows: int, n_num: int, n_cat: int, card: int, seed: int = 0):
    """Wide synthetic featurization workload: ``n_num`` numerics to scale,
    ``n_cat`` categoricals to one-hot (``card`` categories each)."""
    rng = np.random.default_rng(seed)
    cols = {
        f"f{i}": rng.normal(size=n_rows) * (i + 1) for i in range(n_num)
    }
    for j in range(n_cat):
        cols[f"c{j}"] = rng.integers(0, card, size=n_rows).astype(np.int64)
    label = (
        sum(cols[f"f{i}"] for i in range(min(4, n_num)))
        + (cols["c0"] if n_cat else 0) > 1.0
    ).astype(np.int64)
    return cols, label


def run_featurize(quick: bool = False) -> dict:
    """Part 5: the wide-row featurize+tree workload where partial MLtoDNN +
    the fused featurize kernel erase the host boundary outright."""
    from repro.ml import GradientBoostingClassifier
    from repro.ml.pipeline import fit_pipeline, run_pipeline

    n_rows = 8_192 if quick else 32_768
    n_num, n_cat, card = 32, 12, 8
    cols, label = _wide_table(n_rows, n_num, n_cat, card)
    numeric = [f"f{i}" for i in range(n_num)]
    categorical = [f"c{j}" for j in range(n_cat)]
    cats = {c: np.arange(card) for c in categorical}
    pipe = fit_pipeline(
        cols, label, numeric, categorical,
        GradientBoostingClassifier(n_estimators=8, max_depth=3),
        categories=cats,
    )

    dbw = raven.connect({"wide": cols}, stats="auto")
    dbw.register_model("w", pipe)
    sqlw = (
        "SELECT * FROM PREDICT(model='w', data=wide) AS p "
        "WHERE score >= :t"
    )
    sizes = [1024, 2000, 4096] if quick else [1024, 2000, 4096, 8192]
    reps = 2 if quick else 4
    batches = [
        {k: v[:n] for k, v in _wide_table(n, n_num, n_cat, card, seed=30 + i)[0].items()}
        for i, n in enumerate(sizes)
    ]
    total_rows = sum(sizes) * reps

    def leg(transform: str):
        clear_plan_cache()
        prep = dbw.sql(sqlw).prepare(transform=transform, params={"t": -1e9})
        outs = [prep(b) for b in batches]  # warm every shape
        t0 = time.perf_counter()
        for _ in range(reps):
            for b in batches:
                jax.block_until_ready(prep(b)["score"])
        return prep, outs, time.perf_counter() - t0

    host_prep, host_outs, t_host = leg("none")
    fused_prep, fused_outs, t_fused = leg("dnn")

    nb_host = host_prep.compiled.graph.n_host_boundaries
    nb_fused = fused_prep.compiled.graph.n_host_boundaries
    fused_note = any(
        "fused featurize" in n for n in fused_prep.report.notes
    )
    for h, f in zip(host_outs, fused_outs):
        np.testing.assert_allclose(
            f["score"], h["score"], rtol=5e-3, atol=1e-5
        )

    # the ML-runtime floor the paper compares against: op-at-a-time numpy
    in_names = [s.name for s in pipe.inputs]
    t0 = time.perf_counter()
    for _ in range(reps):
        for b in batches:
            run_pipeline(pipe, {k: b[k] for k in in_names})
    t_mlrt = time.perf_counter() - t0

    print("serve_query_featurize,variant,seconds,rows_per_s,host_boundaries")
    print(f"serve_query_featurize,mlruntime,{t_mlrt:.3f},"
          f"{total_rows / t_mlrt:.0f},-")
    print(f"serve_query_featurize,host,{t_host:.3f},"
          f"{total_rows / t_host:.0f},{nb_host}")
    print(f"serve_query_featurize,fused,{t_fused:.3f},"
          f"{total_rows / t_fused:.0f},{nb_fused}")
    print(f"serve_query_featurize,speedup,fused vs host = "
          f"{t_host / t_fused:.1f}x (host boundaries {nb_host} -> "
          f"{nb_fused}; fused featurize kernel engaged: {fused_note})")
    return {
        "featurize_rows": total_rows,
        "featurize_mlruntime_s": t_mlrt,
        "featurize_host_s": t_host,
        "featurize_fused_s": t_fused,
        "featurize_host_rows_s": total_rows / t_host,
        "featurize_fused_rows_s": total_rows / t_fused,
        "featurize_fused_speedup": t_host / t_fused,
        "featurize_host_boundaries_none": nb_host,
        "featurize_host_boundaries_fused": nb_fused,
        "featurize_fused_kernel": bool(fused_note),
    }


def _relational_workload(n_rows: int, m_dim: int, seed: int):
    """Star schema with dyadic-rational values (small ints × 0.25): f32
    sums are exact and order-free, so every leg must agree bit-for-bit."""
    rng = np.random.default_rng(seed)

    def dy(shape):
        return (rng.integers(-40, 40, size=shape) * 0.25).astype(np.float32)

    dim = {"k": np.arange(m_dim, dtype=np.int64)}
    for j in range(2):
        dim[f"v{j}"] = dy(m_dim)
    fact = {
        # some keys miss the dim table, so the join actually filters
        "fk": rng.integers(0, m_dim + m_dim // 4, size=n_rows).astype(np.int64),
        "x": dy(n_rows),
    }
    return fact, dim


def _relational_plan():
    from repro.relational.engine import Aggregate, Filter, Join, Scan
    from repro.relational.expr import Bin, Col, Const

    # the dashboard shape: full stats (sum/avg/min/max) over each measure.
    # The legacy composition recomputes a segmented reduction PER AGGREGATE;
    # the kernel computes each statistic once per column and the aggregates
    # just index into them
    measures = ["x", "v0", "v1"]
    aggs = [("n", "count", "x")]
    for c in measures:
        aggs += [
            (f"sum_{c}", "sum", c), (f"avg_{c}", "mean", c),
            (f"min_{c}", "min", c), (f"max_{c}", "max", c),
        ]
    return Aggregate(
        Filter(
            Join(Scan("f", ["fk", "x"]), "d", "fk", "k", ["v0", "v1"]),
            Bin("gt", Col("x"), Const(0.0)),
        ),
        aggs,
    )


def _relational_host(fact, dim):
    """The numpy oracle: filter→join→aggregate with f32-exact arithmetic."""
    pos = np.searchsorted(dim["k"], np.clip(fact["fk"], 0, dim["k"][-1]))
    pos = np.clip(pos, 0, len(dim["k"]) - 1)
    mask = (dim["k"][pos] == fact["fk"]) & (fact["x"] > 0)
    p = pos[mask]
    n = np.float32(mask.sum())
    one = np.float32(1)

    def s(v):  # dyadic data: the f64 sum is exactly representable in f32
        return np.float32(v.astype(np.float64).sum())

    out = {"n": n}
    for c in ("x", "v0", "v1"):
        v = fact["x"][mask] if c == "x" else dim[c][p]
        out[f"sum_{c}"] = s(v)
        out[f"avg_{c}"] = s(v) / max(n, one)
        out[f"min_{c}"] = v.min() if len(v) else np.float32(0)
        out[f"max_{c}"] = v.max() if len(v) else np.float32(0)
    return out


def run_relational(quick: bool = False) -> dict:
    """Part 6: filter→join→group-by A/B — numpy host oracle vs the legacy
    jnp stage composition (RAVEN_KERNELS=off) vs the relational kernel ops
    (RAVEN_KERNELS=on)."""
    from repro.relational.engine import PLAN_CACHE_STATS as _stats

    sizes = [2048, 4096] if quick else [4096, 8192, 16384]
    reps = 3 if quick else 5
    m_dim = 1024
    batches = [_relational_workload(n, m_dim, seed=60 + i)
               for i, n in enumerate(sizes)]
    total_rows = sum(sizes) * reps
    agg_names = [a[0] for a in _relational_plan().aggs]

    def jax_leg(mode: str):
        """Best-of-3 timed passes over all batches in one RAVEN_KERNELS
        mode; returns (seconds, results, post-warm retraces)."""
        prev = os.environ.get("RAVEN_KERNELS")
        os.environ["RAVEN_KERNELS"] = mode
        try:
            clear_plan_cache()
            cp = compile_plan(_relational_plan(), cache=False)
            dbs = [{"f": {k: jax.numpy.asarray(v) for k, v in fact.items()},
                    "d": {k: jax.numpy.asarray(v) for k, v in dim.items()}}
                   for fact, dim in batches]
            outs = []
            for env in dbs:  # warm every shape
                res = cp.run(env).table.to_numpy(compact=True)
                outs.append({k: np.asarray(res[k], np.float32).reshape(-1)[0]
                             for k in agg_names})
            warm = _stats.traces
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                for _ in range(reps):
                    last = [cp.run(env).table.columns for env in dbs]
                for cols in last:
                    jax.block_until_ready(cols)
                best = min(best, time.perf_counter() - t0)
            return best, outs, _stats.traces - warm
        finally:
            if prev is None:
                os.environ.pop("RAVEN_KERNELS", None)
            else:
                os.environ["RAVEN_KERNELS"] = prev
            clear_plan_cache()

    # host oracle leg (numpy, best-of-3)
    t_host = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            host_outs = [_relational_host(fact, dim) for fact, dim in batches]
        t_host = min(t_host, time.perf_counter() - t0)

    t_jnp, jnp_outs, jnp_retraces = jax_leg("off")
    t_kern, kern_outs, kern_retraces = jax_leg("on")

    bitwise = True
    for h, j, k in zip(host_outs, jnp_outs, kern_outs):
        for name in agg_names:
            vals = [np.float32(h[name]), np.float32(j[name]),
                    np.float32(k[name])]
            bits = {v.view(np.uint32).item() for v in vals}
            if len(bits) != 1:
                bitwise = False
                print(f"serve_query_relational,MISMATCH,{name},"
                      f"host={vals[0]!r},jnp={vals[1]!r},kernel={vals[2]!r}")

    print("serve_query_relational,variant,seconds,rows_per_s,"
          "post_warm_retraces")
    print(f"serve_query_relational,host,{t_host:.3f},"
          f"{total_rows / t_host:.0f},-")
    print(f"serve_query_relational,jnp,{t_jnp:.3f},"
          f"{total_rows / t_jnp:.0f},{jnp_retraces}")
    print(f"serve_query_relational,kernel,{t_kern:.3f},"
          f"{total_rows / t_kern:.0f},{kern_retraces}")
    print(f"serve_query_relational,speedup,kernel vs jnp = "
          f"{t_jnp / t_kern:.2f}x, kernel vs host = "
          f"{t_host / t_kern:.2f}x (bitwise_equal={bitwise})")
    return {
        "relational_rows": total_rows,
        "relational_host_s": t_host,
        "relational_jnp_s": t_jnp,
        "relational_kernel_s": t_kern,
        "relational_host_rows_s": total_rows / t_host,
        "relational_jnp_rows_s": total_rows / t_jnp,
        "relational_kernel_rows_s": total_rows / t_kern,
        "relational_kernel_vs_jnp": t_jnp / t_kern,
        "relational_bitwise_equal": bitwise,
        "relational_warm_retraces": jnp_retraces + kern_retraces,
    }


def run_hotswap(quick: bool = False) -> dict:
    """Part 7: hot-swap A/B — the model-lifecycle payoff.

    Continuous threaded load against one served query while the registry
    publishes, warm-compiles, and atomically cuts over to a new model
    version. Per-request latency is bucketed into three windows — steady
    state on v1 (*before*), the publish→warm→cutover interval (*during*),
    and steady state on v2 (*after*) — so the headline is visible directly:
    zero dropped requests, zero cutover re-traces, and a *during* p99 in
    the same regime as steady state (the swap happens under the scheduler
    hold, not under a compile)."""
    reqs_per_phase = 24 if quick else 96
    train, _ = make_dataset("hospital", 20_000)
    pipe1 = train_model(train, "gb")
    pipe2 = train_model(train, "dt")
    db = raven.connect(train.tables, stats="auto")
    db.models.publish("m", pipe1)
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p"
    ).prepare(transform="sql")
    prep.serve("hotswap")
    batch = make_hospital(512, seed=77).tables["patients"]
    for _ in range(3):  # prime the bucket ladder on v1
        r = prep.submit(batch)
        db.flush()
        r.wait(30)

    records: list[tuple[str, float, str]] = []  # (phase, latency_ms, label)
    errors: list[BaseException] = []
    lock = threading.Lock()
    phase = ["before"]
    stop = threading.Event()

    def worker():
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                r = prep.submit(batch)
                db.flush()
                r.wait(60)
            except BaseException as e:  # noqa: BLE001 — dropped == failure
                with lock:
                    errors.append(e)
                return
            with lock:
                records.append(
                    (phase[0], (time.perf_counter() - t0) * 1e3, r.served_by)
                )

    def drained(want_phase: str, n: int) -> None:
        while True:
            with lock:
                if sum(1 for p, _, _ in records if p == want_phase) >= n:
                    return
            time.sleep(0.002)

    workers = [threading.Thread(target=worker) for _ in range(2)]
    t_bench = time.perf_counter()
    for w in workers:
        w.start()
    drained("before", reqs_per_phase)

    with lock:
        phase[0] = "during"
    db.models.publish("m", pipe2, warm="sync")  # stage + ladder replay
    traces_warm = db.server.recompiles()
    db.models.cutover("m", 2)
    with lock:
        phase[0] = "after"

    drained("after", reqs_per_phase)
    stop.set()
    for w in workers:
        w.join(timeout=120)
    db.flush()
    elapsed = time.perf_counter() - t_bench
    cutover_retraces = db.server.recompiles() - traces_warm

    by_phase = {
        p: [ms for ph, ms, _ in records if ph == p]
        for p in ("before", "during", "after")
    }
    p99 = {
        p: float(np.percentile(v, 99)) if v else 0.0
        for p, v in by_phase.items()
    }
    served = {lb: sum(1 for _, _, s in records if s == lb)
              for lb in ("v1", "v2")}
    total_rows = 512 * len(records)
    snap = db.server.route_snapshot("hotswap")

    print("serve_query_hotswap,phase,requests,p99_ms")
    for p in ("before", "during", "after"):
        print(f"serve_query_hotswap,{p},{len(by_phase[p])},{p99[p]:.2f}")
    print(f"serve_query_hotswap,summary,dropped={len(errors)},"
          f"cutover_retraces={cutover_retraces},"
          f"served_v1={served['v1']},served_v2={served['v2']},"
          f"deficit={snap['last_cutover_deficit']},"
          f"rows_s={total_rows / elapsed:.0f}")
    return {
        "hotswap_requests": len(records),
        "hotswap_dropped": len(errors),
        "hotswap_p99_before_ms": p99["before"],
        "hotswap_p99_during_ms": p99["during"],
        "hotswap_p99_after_ms": p99["after"],
        "hotswap_cutover_retraces": int(cutover_retraces),
        "hotswap_cutover_deficit": int(snap["last_cutover_deficit"]),
        "hotswap_served_v1": served["v1"],
        "hotswap_served_v2": served["v2"],
        "hotswap_rows_s": total_rows / elapsed,
    }


def run_faultdrill(quick: bool = False) -> dict:
    """Part 8: fault drill — the fault-tolerance payoff.

    Three legs against the same served query. *Transient*: a seeded
    FaultPlan injects dispatch + stage failures mid-traffic; the scheduler
    requeues the failed groups whole and every request completes with
    results bitwise-equal to the clean baseline (0 dropped, 0 wrong).
    *Rollback*: publish v2, cut over, roll back under the same cutover
    machinery — 0 dropped requests, 0 re-traces. *Recovery*: kill the
    session after journaled traffic; a fresh session over the same cache
    dir restores the route and answers the same shapes with 0 new traces.
    """
    from repro.exec.faults import FaultPlan

    n_requests = 6 if quick else 16
    train, _ = make_dataset("hospital", 20_000)
    pipe1 = train_model(train, "gb")
    pipe2 = train_model(train, "dt")
    sizes = _request_sizes(n_requests, seed=9)
    batches = [make_hospital(n, seed=900 + i).tables["patients"]
               for i, n in enumerate(sizes)]
    total_rows = sum(sizes)
    sql = "SELECT * FROM PREDICT(model='m', data=patients) AS p"
    retry = raven.RetryPolicy(max_attempts=4, backoff_ms=0.5)

    def connect_serving(faults=None, cache_dir=None):
        db = raven.connect(
            train.tables, stats="auto",
            options=raven.ConnectOptions(faults=faults, cache_dir=cache_dir),
        )
        db.models.publish("m", pipe1)
        prep = db.sql(sql).prepare(transform="sql")
        prep.serve("drill", options=raven.ServeOptions(retry=retry))
        return db, prep

    def traffic(db, prep):
        """Submit the whole ladder; returns (scores-or-None, dropped)."""
        outs, dropped = [], 0
        reqs = [prep.submit(b) for b in batches]
        db.flush()
        for r in reqs:
            try:
                outs.append(np.asarray(r.wait(timeout=120)["score"]))
            except Exception:  # noqa: BLE001 — a drop is the failure mode
                outs.append(None)
                dropped += 1
        return outs, dropped

    # -- clean baseline: the ground truth every leg must reproduce -----------
    db, prep = connect_serving()
    base, base_dropped = traffic(db, prep)
    db.close()

    # -- transient-fault leg -------------------------------------------------
    plan = FaultPlan(
        {"stage": {"times": 2}, "dispatch": {"times": 1}}, seed=13,
    )
    db, prep = connect_serving(faults=plan)
    t0 = time.perf_counter()
    outs, dropped = traffic(db, prep)
    t_fault = time.perf_counter() - t0
    dropped += base_dropped
    wrong = sum(
        1 for a, b in zip(base, outs)
        if a is None or b is None or not np.array_equal(a, b)
    )
    injected = sum(plan.injected().values())
    retries = db.cache_stats()["server"]["retries"]
    db.close()

    # -- rollback drill ------------------------------------------------------
    db, prep = connect_serving()
    traffic(db, prep)
    db.models.publish("m", pipe2, warm="sync")
    db.models.cutover("m", 2)
    traffic(db, prep)
    recompiles = db.cache_stats()["server"]["recompiles"]
    db.models.rollback("m", reason="drill")
    rb_outs, rb_dropped = traffic(db, prep)
    rb_retraces = db.cache_stats()["server"]["recompiles"] - recompiles
    rb_wrong = sum(
        1 for a, b in zip(base, rb_outs)
        if a is None or b is None or not np.array_equal(a, b)
    )
    db.close()

    # -- crash-recovery drill ------------------------------------------------
    with tempfile.TemporaryDirectory() as cache:
        db, prep = connect_serving(cache_dir=cache)
        traffic(db, prep)
        db.artifact_store.drain()
        db.close()  # the journal survives; pretend this was a crash
        db2 = raven.connect(
            train.tables, stats="auto",
            options=raven.ConnectOptions(cache_dir=cache),
        )
        counts = db2.recover()
        traces0 = db2.cache_stats()["traces"]
        prep2 = db2.sql(sql).prepare(transform="sql")
        prep2.serve("drill")
        rec_outs, rec_dropped = traffic(db2, prep2)
        rec_traces = db2.cache_stats()["traces"] - traces0
        db2.close()
    rec_wrong = sum(
        1 for a, b in zip(base, rec_outs)
        if a is None or b is None or not np.array_equal(a, b)
    )

    print("serve_query_faultdrill,leg,rows_per_s,injected,dropped,"
          "wrong_results")
    print(f"serve_query_faultdrill,transient,{total_rows / t_fault:.0f},"
          f"{injected},{dropped},{wrong} (retries={retries})")
    print(f"serve_query_faultdrill,rollback,-,-,{rb_dropped},{rb_wrong} "
          f"(retraces={rb_retraces})")
    print(f"serve_query_faultdrill,recovery,-,-,{rec_dropped},{rec_wrong} "
          f"(new_traces={rec_traces},routes={counts.get('routes', 0)})")
    return {
        "faultdrill_rows_s": total_rows / t_fault,
        "faultdrill_injected": injected,
        "faultdrill_retries": retries,
        "faultdrill_dropped": dropped,
        "faultdrill_wrong_results": wrong,
        "faultdrill_rollback_dropped": rb_dropped,
        "faultdrill_rollback_wrong_results": rb_wrong,
        "faultdrill_rollback_retraces": int(rb_retraces),
        "faultdrill_recovery_dropped": rec_dropped,
        "faultdrill_recovery_wrong_results": rec_wrong,
        "faultdrill_recovery_traces": int(rec_traces),
        "faultdrill_recovered_routes": int(counts.get("routes", 0)),
    }


def run(quick: bool = False):
    n_requests = 8 if quick else 24
    sizes = _request_sizes(n_requests)
    train, _ = make_dataset("hospital", 20_000)
    pipe = train_model(train, "gb")
    batches = [make_hospital(n, seed=100 + i).tables["patients"]
               for i, n in enumerate(sizes)]
    total_rows = sum(sizes)

    db = raven.connect(train.tables, stats="auto")
    db.register_model("m", pipe)
    sql = (
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= :t"
    )
    rows = run_pure(db, sql, batches, total_rows, n_requests)

    # same query text, but run_multistage forces transform='none': the score
    # threshold then runs *after* the MLUdf host boundary, which is exactly
    # where the old exact-shape path churned and re-traced
    rows.update(run_multistage(db, sql, batches, total_rows))

    # part 3: cold-process A/B through the artifact store
    from repro.ml.pipeline import save_pipeline

    with tempfile.TemporaryDirectory() as d:
        pipe_path = os.path.join(d, "pipe.npz")
        save_pipeline(pipe, pipe_path)
        rows.update(run_cold(pipe_path))

    # part 4: mixed workload, serial vs pipelined scheduling
    rows.update(run_mixed(db, sql, quick=quick))

    # part 5: wide-row fused featurization (the vanished host boundary)
    rows.update(run_featurize(quick=quick))

    # part 6: relational kernels (filter→join→group-by A/B)
    rows.update(run_relational(quick=quick))

    # part 7: hot-swap A/B (model lifecycle: publish → warm → cutover)
    rows.update(run_hotswap(quick=quick))

    # part 8: fault drill (injection + retry, rollback, crash recovery)
    rows.update(run_faultdrill(quick=quick))
    return rows


def _write_json(rows: dict, argv: list) -> None:
    """Persist the headline numbers when --json [PATH] was requested."""
    if "--json" not in argv:
        return
    i = argv.index("--json")
    path = (
        argv[i + 1]
        if i + 1 < len(argv) and not argv[i + 1].startswith("-")
        else "BENCH_serving.json"
    )
    with open(path, "w") as f:
        json.dump(rows, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def smoke() -> dict:
    """CI sanity run: the quick benchmark end to end, asserting the headline
    invariants (warm serving beats per-call; warm cold-start never traces;
    pipelined mixed serving beats the serial runner without re-tracing)."""
    rows = run(quick=True)
    assert rows["speedup_served"] > 1.0, rows["speedup_served"]
    assert rows["cold_warm_traces"] == 0
    assert rows["cold_warm_disk_hits"] > 0
    assert rows["mixed_pipelined_retraces_after_warmup"] == 0
    if rows["mixed_parallel_efficiency"] >= 1.4:
        # only where the machine actually grants concurrent CPU can overlap
        # express a wall-clock win (a 1-core cgroup just time-slices)
        assert rows["mixed_speedup_pipelined"] > 1.0, rows
    # the partial-MLtoDNN headline: the wide-row featurize workload's host
    # boundary vanishes and the fused kernel path carries the plan
    assert rows["featurize_host_boundaries_none"] >= 1
    assert rows["featurize_host_boundaries_fused"] == 0, rows
    assert rows["featurize_fused_kernel"], rows
    # the relational-kernel headline: bitwise-equal results, zero warm
    # retraces, and the kernel leg at least matching the jnp stage baseline
    assert rows["relational_bitwise_equal"], rows
    assert rows["relational_warm_retraces"] == 0, rows
    assert (
        rows["relational_kernel_rows_s"] >= rows["relational_jnp_rows_s"]
    ), rows
    # the model-lifecycle headline: an atomic hot swap under load drops
    # nothing and re-traces nothing
    assert rows["hotswap_dropped"] == 0, rows
    assert rows["hotswap_cutover_retraces"] == 0, rows
    assert rows["hotswap_cutover_deficit"] == 0, rows
    assert rows["hotswap_served_v1"] > 0 and rows["hotswap_served_v2"] > 0
    # the fault-tolerance headline: injected faults recover bitwise-equal
    # with nothing dropped; rollback and crash recovery change nothing
    assert rows["faultdrill_injected"] >= 1, rows
    assert rows["faultdrill_dropped"] == 0, rows
    assert rows["faultdrill_wrong_results"] == 0, rows
    assert rows["faultdrill_rollback_dropped"] == 0, rows
    assert rows["faultdrill_rollback_retraces"] == 0, rows
    assert rows["faultdrill_recovery_traces"] == 0, rows
    assert rows["faultdrill_recovered_routes"] >= 1, rows
    print(f"smoke ok: served {rows['speedup_served']:.1f}x, "
          f"staged {rows['speedup_staged']:.1f}x, "
          f"warm cold-start {rows['cold_speedup_warm']:.1f}x, "
          f"pipelined mixed {rows['mixed_speedup_pipelined']:.1f}x, "
          f"fused featurize {rows['featurize_fused_speedup']:.1f}x "
          f"(host boundaries {rows['featurize_host_boundaries_none']} -> "
          f"{rows['featurize_host_boundaries_fused']}), "
          f"relational kernel {rows['relational_kernel_vs_jnp']:.2f}x vs "
          f"jnp (bitwise equal, 0 retraces), "
          f"hot swap p99 {rows['hotswap_p99_before_ms']:.1f}/"
          f"{rows['hotswap_p99_during_ms']:.1f}/"
          f"{rows['hotswap_p99_after_ms']:.1f} ms "
          f"(0 dropped, 0 retraces), "
          f"fault drill {rows['faultdrill_injected']} injected / "
          f"{rows['faultdrill_retries']} retried "
          f"(0 dropped, 0 wrong, rollback+recovery clean)")
    return rows


if __name__ == "__main__":
    if "--cold-child" in sys.argv:
        i = sys.argv.index("--cold-child")
        _cold_child(sys.argv[i + 1], sys.argv[i + 2])
    elif "--smoke" in sys.argv:
        _write_json(smoke(), sys.argv)
    else:
        _write_json(run(quick="--quick" in sys.argv), sys.argv)
