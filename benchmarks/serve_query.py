"""Serving-layer benchmark: cold per-call execution vs the warm cached path,
driven through the session front door (connect -> sql -> prepare -> serve).

Measures the MLtoSQL-lowered hospital query under three regimes:

  percall — compile_plan(cache=False) + execute on every request: the
            pre-serving behavior (re-lower, re-jit, re-trace per call).
  cached  — PreparedQuery one-shot calls through the module-level
            compiled-plan cache (compile once, jit reuses shape-specialized
            programs).
  served  — PreparedQuery.serve(): power-of-two row buckets and
            micro-batched submits on the session server — the steady-state
            hot path.

Reports throughput (rows/s), per-request latency, and XLA recompile counts;
the served/percall ratio is the headline (target: >= 5x warm speedup).

    PYTHONPATH=src:. python benchmarks/serve_query.py [--quick]
"""
from __future__ import annotations

import time

import numpy as np

import jax

import repro as raven
from benchmarks.common import make_dataset, train_model
from repro.data.datasets import make_hospital
from repro.relational.engine import (
    PLAN_CACHE_STATS,
    clear_plan_cache,
    compile_plan,
)


def _request_sizes(n_requests: int, seed: int = 0) -> list[int]:
    """Mixed request sizes, the shape churn a real endpoint sees."""
    rng = np.random.default_rng(seed)
    return [int(n) for n in rng.integers(200, 4096, size=n_requests)]


def run(quick: bool = False):
    n_requests = 8 if quick else 24
    sizes = _request_sizes(n_requests)
    train, _ = make_dataset("hospital", 20_000)
    pipe = train_model(train, "gb")
    batches = [make_hospital(n, seed=100 + i).tables["patients"]
               for i, n in enumerate(sizes)]
    total_rows = sum(sizes)

    db = raven.connect(train.tables, stats="auto")
    db.register_model("m", pipe)
    sql = (
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= :t"
    )
    prep = db.sql(sql).prepare(transform="sql", params={"t": 0.6})

    # -- percall: compile + execute from scratch every request ---------------
    clear_plan_cache()
    t0 = time.perf_counter()
    for b in batches:
        db_np = dict(train.tables)
        db_np["patients"] = b
        out = compile_plan(prep.plan, cache=False)(
            {t: {c: np.asarray(v) for c, v in cols.items()}
             for t, cols in db_np.items()},
            params=prep.params,
        )
        jax.block_until_ready(out.columns)
    t_percall = time.perf_counter() - t0
    percall_traces = PLAN_CACHE_STATS.traces

    # -- cached: one-shot PreparedQuery calls through the plan cache ---------
    clear_plan_cache()
    prep = db.sql(sql).prepare(transform="sql", params={"t": 0.6})
    prep(batches[0])  # warm the compile
    t0 = time.perf_counter()
    for b in batches:
        prep(b)
    t_cached = time.perf_counter() - t0
    cached_traces = PLAN_CACHE_STATS.traces

    # -- served: bucketed + micro-batched session server ---------------------
    clear_plan_cache()
    prep = db.sql(sql).prepare(transform="sql", params={"t": 0.6}).serve("hot")
    prep.submit(batches[0])
    db.flush()  # warm one bucket
    warm_traces = db.server.recompiles()
    t0 = time.perf_counter()
    reqs = [prep.submit(b) for b in batches]
    db.flush()
    t_served = time.perf_counter() - t0
    assert all(r.done for r in reqs)

    rows = {
        "requests": n_requests,
        "rows": total_rows,
        "percall_s": t_percall,
        "cached_s": t_cached,
        "served_s": t_served,
        "percall_rows_s": total_rows / t_percall,
        "cached_rows_s": total_rows / t_cached,
        "served_rows_s": total_rows / t_served,
        "percall_recompiles": percall_traces,
        "cached_recompiles": cached_traces,
        "served_recompiles_after_warmup": db.server.recompiles() - warm_traces,
        "speedup_cached": t_percall / t_cached,
        "speedup_served": t_percall / t_served,
    }
    print("serve_query,variant,seconds,rows_per_s,recompiles")
    print(f"serve_query,percall,{t_percall:.3f},{rows['percall_rows_s']:.0f},"
          f"{percall_traces}")
    print(f"serve_query,cached,{t_cached:.3f},{rows['cached_rows_s']:.0f},"
          f"{cached_traces}")
    print(f"serve_query,served,{t_served:.3f},{rows['served_rows_s']:.0f},"
          f"{db.server.recompiles() - warm_traces} (after warmup)")
    print(f"serve_query,speedup,served vs percall = "
          f"{rows['speedup_served']:.1f}x, cached vs percall = "
          f"{rows['speedup_cached']:.1f}x")
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
