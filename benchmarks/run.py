"""Benchmark driver: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6,fig10,...]

Prints CSV blocks per figure (the same rows each module prints standalone)
and finishes with the §Roofline table from the dry-run records.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

HEADERS = {
    "fig4": "fig4,strategy,accuracy,speedup_median,speedup_p25,speedup_min",
    "fig6": "fig6,dataset,model,rows,noopt_s,none_s,sql_s,dnn_s,best,speedup",
    "fig7": "fig7,model,rows,noopt_s,raven_s,speedup",
    "fig8": "fig8,model,rows,dop1_s,dop8_s,identical",
    "fig9": "fig9,alpha,zero_weights,noopt_s,modelproj_s,mltosql_s,both_s,speedup",
    "fig10": "fig10,depth,noopt_s,modelproj_s,mltosql_s,mltodnn_s,verdict",
    "fig11": "fig11,depth,partition,noopt_s,nopart_s,part_s,avg_pruned,speedup",
    "fig12": "fig12,estimators,depth,interp_s,dnn_s,speedup",
}

ALL = ["fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig4"]


def _module(name: str) -> str:
    return {
        "fig4": "fig4_strategies",
        "fig6": "fig6_end_to_end",
        "fig7": "fig7_scalability",
        "fig8": "fig8_dop",
        "fig9": "fig9_lr_sparsity",
        "fig10": "fig10_tree_depth",
        "fig11": "fig11_data_induced",
        "fig12": "fig12_mltodnn",
    }[name]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced scales (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated figure list")
    args = ap.parse_args()

    todo = args.only.split(",") if args.only else ALL
    failures = 0
    t_all = time.time()
    for name in todo:
        mod = __import__(f"benchmarks.{_module(name)}", fromlist=["run"])
        print(f"\n# === {name} {'(quick)' if args.quick else ''} ===")
        print(HEADERS[name])
        t0 = time.time()
        try:
            mod.run(quick=args.quick)
        except Exception:
            traceback.print_exc()
            failures += 1
        print(f"# {name} done in {time.time()-t0:.1f}s")

    print("\n# === roofline (single-pod, from dry-run records) ===")
    try:
        from benchmarks.roofline import report

        print(report("sp"))
    except Exception:
        traceback.print_exc()
        failures += 1
    print(f"\n# all benchmarks done in {time.time()-t_all:.1f}s; "
          f"{failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
