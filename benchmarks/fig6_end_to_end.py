"""Fig. 6 analog: end-to-end prediction-query runtime, 4 datasets × 3 models.

Variants per cell:
  noopt   — Raven (no-opt): full scan, interpreted ML runtime through the
            UDF host boundary (the paper's baseline).
  raven   — all logical optimizations + strategy-free best physical pick
            (we report all three transforms; 'raven' = min, like the
            classification strategy would choose with an oracle corpus).
"""
from __future__ import annotations

from benchmarks.common import NOOPT, build_query, make_dataset, run_variant, train_model

CELLS = [
    ("credit_card", "lr", {}), ("credit_card", "dt", {}), ("credit_card", "gb", {}),
    ("hospital", "lr", {}), ("hospital", "dt", {}), ("hospital", "gb", {}),
    ("expedia", "lr", {"n_iter": 40}), ("expedia", "dt", {}), ("expedia", "gb", {}),
    ("flights", "lr", {"n_iter": 40}), ("flights", "dt", {}), ("flights", "gb", {}),
]

SCALES = {"credit_card": 400_000, "hospital": 400_000,
          "expedia": 100_000, "flights": 50_000}


def run(quick: bool = False):
    rows = []
    for name, kind, kw in CELLS[:4] if quick else CELLS:
        scale = 20_000 if quick else SCALES[name]
        train, infer = make_dataset(name, scale)
        pipe = train_model(train, kind, **kw)
        q = build_query(infer, pipe)
        t_noopt = run_variant(q, infer.tables, **NOOPT)
        per = {}
        for tr in ("none", "sql", "dnn"):
            per[tr] = run_variant(q, infer.tables, transform=tr)
        best = min(per, key=per.get)
        rows.append({
            "dataset": name, "model": kind, "rows": scale,
            "noopt_s": t_noopt, **{f"{k}_s": v for k, v in per.items()},
            "best": best, "speedup": t_noopt / per[best],
        })
        print(
            f"fig6,{name},{kind},{scale},{t_noopt:.3f},{per['none']:.3f},"
            f"{per['sql']:.3f},{per['dnn']:.3f},{best},{t_noopt/per[best]:.2f}x"
        )
    return rows


if __name__ == "__main__":
    print("fig6,dataset,model,rows,noopt_s,none_s,sql_s,dnn_s,best,speedup")
    run()
