"""Fig. 9 analog: L1-sparsity sweep on Credit Card LR × rule combinations.

Reproduces: ModelProj alone tracks sparsity (20%→>100% of baseline time as
alpha grows); MLtoSQL alone is a constant fraction; the combination wins.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    NOOPT, build_query, make_dataset, run_variant, train_model,
)

ALPHAS = [0.05, 0.02, 0.01, 0.003, 0.0]


def run(quick: bool = False):
    rows = []
    scale = 20_000 if quick else 300_000
    train, infer = make_dataset("credit_card", scale)
    for alpha in (ALPHAS[:2] if quick else ALPHAS):
        pipe = train_model(train, "lr", alpha=alpha, n_iter=150)
        lin = pipe.model_nodes()[0]
        nz = int(np.sum(np.asarray(lin.attrs["weights"]) == 0.0))
        q = build_query(infer, pipe)
        t0 = run_variant(q, infer.tables, **NOOPT)
        t_proj = run_variant(
            q, infer.tables, predicate_pruning=False, data_induced=False,
            transform="none",
        )
        t_sql = run_variant(
            q, infer.tables, predicate_pruning=False, data_induced=False,
            projection_pushdown=False, transform="sql",
        )
        t_both = run_variant(q, infer.tables, transform="sql")
        rows.append({"alpha": alpha, "zero_w": nz, "noopt_s": t0,
                     "proj_s": t_proj, "sql_s": t_sql, "both_s": t_both})
        print(
            f"fig9,{alpha},{nz},{t0:.3f},{t_proj:.3f},{t_sql:.3f},{t_both:.3f},"
            f"{t0/t_both:.2f}x"
        )
    return rows


if __name__ == "__main__":
    print("fig9,alpha,zero_weights,noopt_s,modelproj_s,mltosql_s,both_s,speedup")
    run()
