"""Shared benchmark plumbing: timing, dataset/pipeline builders, runners."""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.core.ir import PredictionQuery, TableStats
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.data.datasets import DATASETS
from repro.ml import (
    DecisionTreeClassifier,
    GradientBoostingClassifier,
    LogisticRegression,
    RandomForestClassifier,
    fit_pipeline,
)
from repro.relational.engine import compile_plan
from repro.sql.parser import parse_prediction_query


def timed(fn: Callable, repeats: int = 3) -> float:
    """Trimmed wall time: best-effort analog of the paper's trimmed mean of
    5 (we run 1 warmup + ``repeats``, dropping min/max when repeats >= 3)."""
    fn()  # warmup: jit compile / model load, like the paper's warm runs
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    if len(ts) >= 3:
        ts = sorted(ts)[1:-1]
    return float(np.mean(ts))


_TRAIN_ROWS = 4096  # models are trained small; inference scale varies


def make_dataset(name: str, n_rows: int, seed: int = 0):
    """Training-scale dataset + inference-scale replica (paper §7 scales
    datasets by replication; our generators draw more rows directly)."""
    train = DATASETS[name](_TRAIN_ROWS, seed=seed)
    infer = DATASETS[name](n_rows, seed=seed)
    return train, infer


ESTIMATORS = {
    "lr": lambda **kw: LogisticRegression(
        alpha=kw.get("alpha", 0.001), n_iter=kw.get("n_iter", 120)
    ),
    "dt": lambda **kw: DecisionTreeClassifier(max_depth=kw.get("depth", 8)),
    "gb": lambda **kw: GradientBoostingClassifier(
        n_estimators=kw.get("n_estimators", 20), max_depth=kw.get("depth", 3)
    ),
    "rf": lambda **kw: RandomForestClassifier(
        n_estimators=kw.get("n_estimators", 10), max_depth=kw.get("depth", 6)
    ),
}


def train_model(train_ds, kind: str, **kw):
    joined = train_ds.joined_columns()
    return fit_pipeline(
        joined, train_ds.label, train_ds.numeric, train_ds.categorical,
        ESTIMATORS[kind](**kw), categories=train_ds.categories(),
    )


def build_query(ds, pipe, where: str = "", agg: str = "COUNT(*), AVG(score)",
                partition_col: Optional[str] = None) -> PredictionQuery:
    sql = (
        f"SELECT {agg} FROM PREDICT(model='m', data={ds.fact}"
        + "".join(f" JOIN {d} ON {fk} = {dk}" for fk, d, dk in ds.join_keys)
        + ") AS p"
        + (f" WHERE {where}" if where else "")
    )
    stats = {
        ds.fact: TableStats.of(ds.tables[ds.fact], partition_col=partition_col)
    }
    return parse_prediction_query(sql, {"m": pipe}, ds.tables, stats=stats)


def run_variant(query, tables, repeats: int = 3, **opts) -> float:
    """Optimize once, execute repeatedly; returns seconds (warm)."""
    plan, _ = RavenOptimizer(options=OptimizerOptions(**opts)).optimize(query)
    runner = compile_plan(plan)
    import jax
    import jax.numpy as jnp

    db = {
        t: {c: jnp.asarray(v) for c, v in cols.items()}
        for t, cols in tables.items()
    }

    def go():
        out = runner(db)
        jax.block_until_ready(out.columns)

    return timed(go, repeats)


NOOPT = {
    "predicate_pruning": False, "projection_pushdown": False,
    "data_induced": False, "transform": "none",
}
