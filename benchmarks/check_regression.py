"""Bench-regression guard: compare a fresh serving-benchmark run against the
committed baseline.

    python benchmarks/check_regression.py CURRENT.json [BASELINE.json]
                                          [--threshold 0.30]

Every throughput key (``*_rows_s``) present in BOTH files is compared; the
guard fails (exit 1) if any current value falls more than ``--threshold``
(default 30%) below the baseline. Keys present in only one file are reported
but never fail the guard — benchmarks come and go across PRs, and a renamed
key should not masquerade as a regression. Improvements are printed so the
nightly log doubles as a coarse perf history.

CI wiring (nightly job): the smoke run writes its numbers to a scratch path,
then this guard compares them against the checked-in ``BENCH_serving.json``.
The baseline is refreshed deliberately — by committing a new
``BENCH_serving.json`` — never silently by CI.
"""
from __future__ import annotations

import argparse
import json
import sys

# serving invariants: these must be exactly zero on every run — a nonzero
# value is a correctness regression (dropped requests, cold cutovers,
# re-traces on warm paths), not a throughput wobble, so no threshold applies
ZERO_INVARIANTS = (
    "cold_warm_traces",
    "mixed_pipelined_retraces_after_warmup",
    "hotswap_dropped",
    "hotswap_cutover_retraces",
    "hotswap_cutover_deficit",
    "faultdrill_dropped",
    "faultdrill_wrong_results",
    "faultdrill_rollback_dropped",
    "faultdrill_rollback_retraces",
    "faultdrill_recovery_traces",
)


def compare(current: dict, baseline: dict, threshold: float) -> list[str]:
    """Return a list of human-readable failures (empty == guard passes)."""
    failures: list[str] = []
    for k in ZERO_INVARIANTS:
        if k in current and current[k] != 0:
            failures.append(f"{k}: expected 0, got {current[k]!r}")
            print(f"  FAIL  {k}: {current[k]!r} (must be 0)")
    keys = sorted(k for k in baseline if k.endswith("_rows_s"))
    for k in keys:
        base = baseline[k]
        if k not in current:
            print(f"  skip  {k}: present only in baseline")
            continue
        cur = current[k]
        if not (
            isinstance(base, (int, float)) and isinstance(cur, (int, float))
        ) or base <= 0:
            print(f"  skip  {k}: non-numeric or non-positive baseline")
            continue
        ratio = cur / base
        tag = "ok   "
        if ratio < 1.0 - threshold:
            tag = "FAIL "
            failures.append(
                f"{k}: {cur:,.0f} rows/s is {1 - ratio:.0%} below the "
                f"baseline {base:,.0f} rows/s (threshold {threshold:.0%})"
            )
        elif ratio > 1.0 + threshold:
            tag = "up   "
        print(f"  {tag} {k}: {cur:,.0f} vs baseline {base:,.0f} "
              f"({ratio:.2f}x)")
    for k in sorted(current):
        if k.endswith("_rows_s") and k not in baseline:
            print(f"  new   {k}: {current[k]:,.0f} rows/s (no baseline yet)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if any *_rows_s key regresses vs the baseline, or a zero-invariant (drops/retraces) is nonzero"
    )
    ap.add_argument("current", help="JSON written by the fresh benchmark run")
    ap.add_argument(
        "baseline", nargs="?", default="BENCH_serving.json",
        help="committed baseline JSON (default: BENCH_serving.json)",
    )
    ap.add_argument(
        "--threshold", type=float, default=0.30,
        help="allowed fractional drop before failing (default 0.30)",
    )
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    print(f"bench regression guard: {args.current} vs {args.baseline} "
          f"(threshold {args.threshold:.0%})")
    failures = compare(current, baseline, args.threshold)
    if failures:
        print(f"\nREGRESSION: {len(failures)} failing key(s):",
              file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("guard passed: invariants hold, no throughput key "
          "regressed beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
