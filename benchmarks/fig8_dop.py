"""Fig. 8 analog: degree-of-parallelism — 1-shard vs 8-shard shard_map
execution of the fused (MLtoSQL) plan vs the un-optimized plan.

The paper's DOP1/DOP16 comparison on SQL Server shows the *fused* plan
benefits more from parallelism than the UDF plan (the UDF host boundary
serializes). We reproduce the mechanism with the data-parallel engine: the
fused plan shards rows over the `data` mesh axis with one psum at the
aggregate. This container exposes one physical core, so 8 'devices' measure
partitioning overhead rather than speedup — the record of interest is that
the sharded fused plan produces identical results with per-shard work 1/8,
plus the wall-time ratio on real parallel hardware (noted in EXPERIMENTS).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _measure(devices: int, rows: int, kind: str) -> str:
    code = f"""
        import time
        import numpy as np, jax, jax.numpy as jnp
        from benchmarks.common import NOOPT, build_query, make_dataset, train_model
        from repro.core.optimizer import OptimizerOptions, RavenOptimizer
        from repro.relational.engine import compile_plan, compile_plan_sharded

        train, infer = make_dataset('hospital', {rows})
        pipe = train_model(train, {kind!r})
        q = build_query(infer, pipe, agg='COUNT(*), SUM(score)')
        plan, _ = RavenOptimizer(options=OptimizerOptions(transform='sql')).optimize(q)
        mesh = jax.make_mesh(({devices},), ('data',))
        run = compile_plan_sharded(plan, mesh, fact_table='patients')
        db = {{t: {{c: jnp.asarray(v) for c, v in cols.items()}}
              for t, cols in infer.tables.items()}}
        out = run(db)  # warmup/compile
        ts = []
        for _ in range(3):
            t0 = time.perf_counter(); jax.block_until_ready(run(db).columns)
            ts.append(time.perf_counter() - t0)
        print('TIME=', min(ts), 'COUNT=', float(np.asarray(out.columns['count_rows'])[0]))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return r.stdout


def run(quick: bool = False):
    rows_n = 20_000 if quick else 200_000
    out = []
    for kind in ("dt",) if quick else ("lr", "dt"):
        r1 = _measure(1, rows_n, kind)
        r8 = _measure(8, rows_n, kind)
        t1 = float(r1.split("TIME=")[1].split()[0])
        t8 = float(r8.split("TIME=")[1].split()[0])
        c1 = float(r1.split("COUNT=")[1].split()[0])
        c8 = float(r8.split("COUNT=")[1].split()[0])
        assert c1 == c8, "sharded plan changed the result"
        out.append({"model": kind, "dop1_s": t1, "dop8_s": t8,
                    "identical": c1 == c8})
        print(f"fig8,{kind},{rows_n},{t1:.3f},{t8:.3f},identical={c1 == c8}")
    return out


if __name__ == "__main__":
    print("fig8,model,rows,dop1_s,dop8_s,identical")
    run()
